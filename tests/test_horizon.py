"""Multi-step fused decode horizon + non-blocking async executor step.

Fast tier: decode-horizon packing, adaptive-K selection under
flowing-decode budgets (prefill pressure, drain barriers, TPOT
headroom, HBM watermark, allocator grants), horizon token-timestamp
spreading, and the async dispatch/commit cluster pipeline on the
simulator's token oracle.

Slow tier: greedy token-exact parity of the K-step horizon against the
K=1 oracle on BOTH tensor paths (paged and packed-dense), including EOS
mid-horizon, preemption-by-recompute, a migration round trip (with the
pipeline-flush guard), single-token requests, the readbacks-per-token
<= 1/K acceptance hook, and an async live serving run that survives a
drain-and-flip role change with token parity."""
import numpy as np
import pytest

from repro.core.cluster import Cluster
from repro.core.instance import HORIZON_HBM_GUARD, Instance
from repro.core.latency import SLO
from repro.core.policies import Sliders
from repro.engine import batching
from repro.engine.engine import ImmediateStep, SimExecutor
from repro.engine.request import Request, State
from repro.sim.simulator import ServingConfig, build_cluster
from repro.sim.workload import SHAREGPT

BAL = SLO(ttft=1.5, tpot=0.030)


# ---------------------------------------------------------------------------
# fast tier: packing
# ---------------------------------------------------------------------------

def _table(bids, width=16):
    row = np.full(width, -1, np.int32)
    row[:len(bids)] = bids
    return row


def test_pack_decode_buckets_batch_and_tables():
    packed = batching.pack_decode(
        last_tokens=[7, 9, 3], positions=[4, 60, 17],
        budgets=[8, 8, 2],
        table_rows=[_table([2]), _table([7, 1, 3, 11, 4]),
                    _table([5, 6])],
        max_blocks=16, block_size=16)
    assert packed.tokens.shape == (4,)            # B pow2 padded
    # row 1's end-of-horizon frontier 60+8 needs 5 blocks -> NB pow2 = 8
    assert packed.tables.shape == (4, 8)
    np.testing.assert_array_equal(packed.tokens, [7, 9, 3, 0])
    np.testing.assert_array_equal(packed.start, [4, 60, 17, 0])
    np.testing.assert_array_equal(packed.budget, [8, 8, 2, 0])
    assert (packed.tables[3] == -1).all()         # pad row frozen+dropped


def test_pack_decode_nb_capped_at_max_blocks():
    packed = batching.pack_decode(
        last_tokens=[1], positions=[250], budgets=[8],
        table_rows=[_table(list(range(16)))], max_blocks=16,
        block_size=16)
    # frontier 258 would need 17 blocks; positions clamp on-device, so
    # the table caps at max_blocks instead of raising
    assert packed.tables.shape == (1, 16)


# ---------------------------------------------------------------------------
# fast tier: adaptive-K selection (flowing-decode budget)
# ---------------------------------------------------------------------------

def _sim_instance(max_horizon=8, hbm_blocks=4096, chunk=256, **kw):
    from repro.configs import get_config
    from repro.core.estimator import CostModel
    from repro.core.hw import InstanceSpec
    cost = CostModel(get_config("qwen2.5-14b"), InstanceSpec(tp=4))
    return Instance(0, "D", chunk, cost, SimExecutor(),
                    hbm_blocks=hbm_blocks, max_horizon=max_horizon, **kw)


def _fake_decoding(inst, n=2, cur_tpot=None, out_len=8):
    """Install decoding requests with a controlled current_tpot."""
    for _ in range(n):
        r = Request(prompt_len=32, max_new_tokens=64)
        r.output_len = out_len
        r.tpot_reset_time = 0.0
        r.first_token_time = 0.0
        r.last_token_time = ((out_len - 1) * cur_tpot
                             if cur_tpot is not None else None)
        inst.decoding[r.rid] = r
        r.state = State.DECODE
    return list(inst.decoding.values())


def test_pick_horizon_pow2_ladder_and_idle():
    inst = _sim_instance(max_horizon=6)          # non-pow2 cap -> 4
    assert inst._pick_horizon() == 1             # no decodes
    _fake_decoding(inst)
    assert inst._pick_horizon() == 4
    inst.max_horizon = 8
    assert inst._pick_horizon() == 8
    inst.max_horizon = 1
    assert inst._pick_horizon() == 1


def test_pick_horizon_prefill_work_forces_one():
    inst = _sim_instance()
    _fake_decoding(inst)
    inst.prefill_queue.append(Request(prompt_len=64, max_new_tokens=8))
    assert inst._pick_horizon() == 1, \
        "a queued chunked prefill must not wait K steps"


def test_pick_horizon_drain_barrier_forces_one():
    inst = _sim_instance()
    _fake_decoding(inst)
    inst.begin_flip("P", 512)
    assert inst._pick_horizon() == 1, \
        "drain-and-flip needs per-step scheduling to evacuate"


def test_pick_horizon_hbm_guard():
    inst = _sim_instance(hbm_blocks=100)
    _fake_decoding(inst)
    inst.allocator.allocate(999, int(16 * 100 * HORIZON_HBM_GUARD) + 32)
    assert inst.allocator.utilization() > HORIZON_HBM_GUARD
    assert inst._pick_horizon() == 1, \
        "near the watermark, degradation must flow per-step"


def test_pick_horizon_tpot_headroom_bands():
    inst = _sim_instance(tpot_slo=0.030, tpot_alpha=1.0)
    _fake_decoding(inst, cur_tpot=0.010)         # 33% of threshold
    assert inst._pick_horizon(now=1.0) == 8
    inst.decoding.clear()
    _fake_decoding(inst, cur_tpot=0.020)         # ~67%
    assert inst._pick_horizon(now=1.0) == 4
    inst.decoding.clear()
    _fake_decoding(inst, cur_tpot=0.024)         # 80%
    assert inst._pick_horizon(now=1.0) == 2
    inst.decoding.clear()
    _fake_decoding(inst, cur_tpot=0.029)         # ~97%: about to flow
    assert inst._pick_horizon(now=1.0) == 1


def test_build_plan_budgets_capped_by_remaining_output():
    inst = _sim_instance()
    reqs = _fake_decoding(inst, n=2, out_len=8)
    reqs[0].max_new_tokens = 11                  # 3 tokens left
    reqs[0].hidden_output_len = None
    for r in reqs:
        inst.allocator.allocate(r.rid, r.context_len + 64)
    plan = inst.build_plan()
    assert plan.horizon == 8
    by_rid = dict(zip([r.rid for r in plan.decode_reqs],
                      plan.decode_budgets))
    assert by_rid[reqs[0].rid] == 3
    assert by_rid[reqs[1].rid] == 8


def test_build_plan_horizon_collapses_to_max_grant():
    inst = _sim_instance()
    reqs = _fake_decoding(inst, n=2, out_len=8)
    for r in reqs:
        r.max_new_tokens = 9                     # 1 token left each
        r.hidden_output_len = None
        inst.allocator.allocate(r.rid, r.context_len + 64)
    plan = inst.build_plan()
    assert plan.horizon == 1, \
        "no row can use K>1 — don't compile/waste an 8-step loop"


def test_horizon_timestamps_spread_like_k1(monkeypatch):
    """A K-horizon's tokens are stamped at the per-step modeled times,
    summing to the K=1 schedule's total — the in-flight TPOT signal
    then reads per-step latency, not duration/1."""
    inst = _sim_instance(max_horizon=4)
    req = Request(prompt_len=32, max_new_tokens=64, hidden_output_len=64,
                  prompt_tokens=list(range(1, 33)))
    inst.enqueue_prefill(req)
    inst.run_iteration(0.0)                      # prefill + first token
    inst.admit_decode(req)
    sink = []
    inst.token_sink = lambda r, t: sink.append(t)
    dur, _, _ = inst.run_iteration(1.0)
    assert inst.last_horizon == 4 and len(sink) == 4
    assert all(b > a for a, b in zip(sink, sink[1:]))
    assert sink[-1] == pytest.approx(1.0 + dur)
    # per-step gaps equal the cost model's single-iteration times
    ctx = req.context_len - 4
    exp = [inst.cost.iteration_time([], [ctx + s]) for s in range(4)]
    gaps = [b - a for a, b in zip([1.0] + sink, sink)]
    assert gaps == pytest.approx(exp)
    assert req.current_tpot(sink[-1]) == pytest.approx(
        (sink[-1] - req.first_token_time) / (req.output_len - 1))


def test_sim_executor_step_async_contract():
    step = SimExecutor().step_async(plan=None)
    assert isinstance(step, ImmediateStep)
    assert step.ready() and not step.resolved
    assert step.resolve() == {} and step.resolved


# ---------------------------------------------------------------------------
# fast tier: async dispatch/commit cluster pipeline (sim oracle)
# ---------------------------------------------------------------------------

def _run_cluster(async_exec, horizon, qps=60, n=150, seed=0):
    sc = ServingConfig(policy="taichi",
                       sliders=Sliders(2, 2, 1024, 256),
                       hbm_blocks=8192)
    cluster = build_cluster(sc, BAL, seed=seed, async_exec=async_exec)
    if horizon > 1:
        cluster.set_horizon(horizon)
    reqs = SHAREGPT.sample_requests(n, qps, seed=seed)
    cluster.run(reqs)
    return cluster, reqs


def test_async_cluster_completes_all_requests():
    cluster, reqs = _run_cluster(async_exec=True, horizon=8)
    assert all(r.state == State.FINISHED for r in reqs)
    assert all(r.output_len == r.target_output_len for r in reqs)
    assert all(r.first_token_time <= r.last_token_time for r in reqs)
    assert any(i.horizon_peak > 1 for i in cluster.instances), \
        "the horizon never engaged"


def test_async_cluster_token_totals_match_sync():
    _, sync_reqs = _run_cluster(async_exec=False, horizon=1)
    _, async_reqs = _run_cluster(async_exec=True, horizon=8)
    assert (sum(r.output_len for r in sync_reqs)
            == sum(r.output_len for r in async_reqs))


def test_async_cluster_survives_role_flip():
    sc = ServingConfig(policy="taichi", sliders=Sliders(1, 1, 1024, 256),
                       hbm_blocks=8192)
    cluster = build_cluster(sc, BAL, async_exec=True)
    cluster.set_horizon(8)
    reqs = SHAREGPT.sample_requests(80, 40, seed=3)
    for r in reqs:
        cluster.submit(r)
    d_inst = next(i for i in cluster.instances if i.itype == "D")
    flipped = False
    while cluster.peek_time() is not None:
        cluster.step()
        if not flipped and d_inst.decoding:
            assert cluster.request_role_flip(d_inst, "P", 1024)
            flipped = True
    assert flipped and d_inst.itype == "P"
    assert all(r.state == State.FINISHED for r in reqs)
    assert all(r.output_len == r.target_output_len for r in reqs)


def test_async_serving_loop_telemetry_consistent():
    from repro.serving import ServingLoop
    sc = ServingConfig(policy="taichi", sliders=Sliders(2, 2, 1024, 256),
                       hbm_blocks=8192)
    cluster = build_cluster(sc, BAL, async_exec=True)
    cluster.set_horizon(8)
    arrivals = SHAREGPT.iter_requests(40, seed=1)
    loop = ServingLoop(cluster, BAL,
                       arrivals=(r for r, _ in zip(arrivals, range(60))))
    loop.run()
    assert all(r.state in (State.FINISHED, State.REJECTED)
               for r in loop.requests)
    done = [r for r in loop.requests if r.state == State.FINISHED]
    # every emitted token reached the telemetry sink, exactly once
    assert loop.telemetry.total_tokens == sum(r.output_len for r in done)
    assert loop.telemetry.total_finished == len(done)
    snap = loop.telemetry.snapshot(cluster.now, cluster.instances)
    assert {"horizon", "inflight"} <= set(snap["instances"][0])


# ---------------------------------------------------------------------------
# slow tier: token-exact parity on the real engine
# ---------------------------------------------------------------------------

jax = pytest.importorskip("jax")

from repro.configs import reduced_config                      # noqa: E402
from repro.core.estimator import CostModel                    # noqa: E402
from repro.core.hw import InstanceSpec                        # noqa: E402
from repro.core.instance import D_HEAVY, P_HEAVY              # noqa: E402
from repro.engine.engine import JaxExecutor                   # noqa: E402
from repro.models import transformer as tf                    # noqa: E402


@pytest.fixture(scope="module")
def setup():
    cfg = reduced_config("smollm-135m")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    cost = CostModel(cfg, InstanceSpec(tp=1))
    return cfg, params, cost


def _prompts(cfg, seed, lengths=(13, 29, 7, 40)):
    rng = np.random.default_rng(seed)
    return [[int(x) for x in rng.integers(1, cfg.vocab_size, size=n)]
            for n in lengths]


def _generate(cfg, params, cost, prompts, n_out, *, max_horizon,
              paged=None, batched=True, eos_id=None, chunk=32,
              preempt_after=None, n_tokens=None):
    ex = JaxExecutor(cfg, params, n_slots=len(prompts) + 1, max_seq=256,
                     batched=batched, paged=paged, eos_id=eos_id,
                     t_buckets=(8, 16, 32))
    inst = Instance(0, D_HEAVY, chunk, cost, ex, hbm_blocks=512,
                    max_horizon=max_horizon)
    reqs = [Request(prompt_len=len(p),
                    max_new_tokens=n_tokens[i] if n_tokens else n_out,
                    hidden_output_len=None if eos_id is not None
                    else (n_tokens[i] if n_tokens else n_out),
                    prompt_tokens=list(p))
            for i, p in enumerate(prompts)]
    for r in reqs:
        inst.enqueue_prefill(r)
    preempted = False
    now, guard = 0.0, 0
    while not all(r.done() or r.state == State.FINISHED for r in reqs) \
            and guard < 500:
        dur, done, _ = inst.run_iteration(now)
        now += dur
        guard += 1
        for r in done:
            inst.admit_decode(r)
        if preempt_after is not None and not preempted:
            victim = reqs[0]
            if victim.rid in inst.decoding \
                    and victim.output_len >= preempt_after:
                inst._preempt(victim)
                preempted = True
    assert all(r.done() or r.state == State.FINISHED for r in reqs)
    if preempt_after is not None:
        assert preempted
    return [r.output_tokens for r in reqs], ex


@pytest.mark.slow
@pytest.mark.parametrize("paged", [True, False],
                         ids=["paged", "dense-packed"])
def test_horizon_k8_greedy_parity_vs_k1_oracle(setup, paged):
    cfg, params, cost = setup
    prompts = _prompts(cfg, 0)
    base, _ = _generate(cfg, params, cost, prompts, 24, max_horizon=1,
                        paged=paged)
    hor, ex = _generate(cfg, params, cost, prompts, 24, max_horizon=8,
                        paged=paged)
    assert hor == base, "K-step horizon must be greedy token-exact"
    assert ex.horizon_calls > 0, "the fused loop never ran"
    # the rowwise oracle agrees too
    ref, _ = _generate(cfg, params, cost, prompts, 24, max_horizon=1,
                       batched=False, paged=False)
    assert hor == ref


@pytest.mark.slow
def test_horizon_eos_mid_horizon_freezes_row(setup):
    cfg, params, cost = setup
    prompts = _prompts(cfg, 2, lengths=(17, 23))
    base, _ = _generate(cfg, params, cost, prompts, 20, max_horizon=1)
    # pick a token the first request emits mid-stream as EOS: the K=8
    # loop must freeze that row at the same step the K=1 oracle stops
    eos = base[0][10]
    k1, _ = _generate(cfg, params, cost, prompts, 20, max_horizon=1,
                      eos_id=eos)
    k8, _ = _generate(cfg, params, cost, prompts, 20, max_horizon=8,
                      eos_id=eos)
    assert k8 == k1
    assert len(k8[0]) <= 11 and k8[0][-1] == eos


@pytest.mark.slow
def test_horizon_single_token_and_uneven_budgets(setup):
    """max_new_tokens=1 finishes at prefill (never decodes); a 2-token
    request gets a 1-step budget inside a K=8 schedule."""
    cfg, params, cost = setup
    prompts = _prompts(cfg, 3, lengths=(9, 21, 33))
    n_tokens = [1, 2, 24]
    base, _ = _generate(cfg, params, cost, prompts, None,
                        max_horizon=1, n_tokens=n_tokens)
    hor, _ = _generate(cfg, params, cost, prompts, None,
                       max_horizon=8, n_tokens=n_tokens)
    assert hor == base
    assert [len(t) for t in hor] == n_tokens


@pytest.mark.slow
def test_horizon_preemption_recompute_parity(setup):
    cfg, params, cost = setup
    prompts = _prompts(cfg, 4, lengths=(23, 41))
    base, _ = _generate(cfg, params, cost, prompts, 16, max_horizon=1)
    pre, _ = _generate(cfg, params, cost, prompts, 16, max_horizon=8,
                       preempt_after=6)
    assert pre == base, (
        "preemption-by-recompute under a K-step horizon must recover "
        "the exact greedy stream (recompute_offset semantics)")


@pytest.mark.slow
def test_horizon_migration_round_trip_and_flush_guard(setup):
    cfg, params, cost = setup
    prompts = _prompts(cfg, 5, lengths=(19,))
    base, _ = _generate(cfg, params, cost, prompts, 40, max_horizon=1)

    def mk():
        ex = JaxExecutor(cfg, params, n_slots=2, max_seq=256, paged=True,
                         t_buckets=(8, 16, 32))
        return ex, Instance(0, D_HEAVY, 32, cost, ex, hbm_blocks=512,
                            max_horizon=8)
    ex_a, a = mk()
    ex_b, b = mk()
    req = Request(prompt_len=19, max_new_tokens=40, hidden_output_len=40,
                  prompt_tokens=list(prompts[0]))
    a.enqueue_prefill(req)
    now, guard = 0.0, 0
    while req.output_len < 7 and guard < 100:
        dur, done, _ = a.run_iteration(now)
        now += dur
        guard += 1
        for r in done:
            a.admit_decode(r)
    # pipeline-flush guard: an eject mid-flight must fail loudly
    assert a.dispatch_iteration(now) is not None
    with pytest.raises(RuntimeError, match="in flight"):
        ex_a.extract_state(req)
    res = a.commit_iteration()          # flush: now ejecting is legal
    assert res.duration > 0
    state = a.eject(req)
    b.inject(req, state)
    guard = 0
    while not req.done() and guard < 100:
        dur, _, _ = b.run_iteration(now)
        now += dur
        guard += 1
    assert req.output_tokens == base[0], (
        "migration between horizon engines must preserve the stream")
    assert ex_b.horizon_calls > 0


@pytest.mark.slow
def test_readbacks_per_token_bounded_by_horizon(setup):
    """Acceptance hook: in the decode phase, host readbacks per
    generated token <= 1/K."""
    cfg, params, cost = setup
    prompts = _prompts(cfg, 6, lengths=(11, 17, 23, 29))
    ex = JaxExecutor(cfg, params, n_slots=5, max_seq=256, paged=True,
                     t_buckets=(8, 16, 32))
    inst = Instance(0, D_HEAVY, 64, cost, ex, hbm_blocks=512,
                    max_horizon=8)
    reqs = [Request(prompt_len=len(p), max_new_tokens=33,
                    hidden_output_len=33, prompt_tokens=list(p))
            for p in prompts]
    for r in reqs:
        inst.enqueue_prefill(r)
    now, guard = 0.0, 0
    while any(r.prefill_remaining > 0 for r in reqs) and guard < 100:
        dur, done, _ = inst.run_iteration(now)
        now += dur
        guard += 1
        for r in done:
            inst.admit_decode(r)
    rb0, tok0 = ex.host_readbacks, inst.decode_token_count
    while not all(r.done() for r in reqs) and guard < 300:
        dur, _, _ = inst.run_iteration(now)
        now += dur
        guard += 1
    tokens = inst.decode_token_count - tok0
    readbacks = ex.host_readbacks - rb0
    # a few decode tokens may land before the window while other rows
    # still prefill; the bound is about the measured window itself
    assert tokens >= 100
    assert readbacks * 8 <= tokens, (
        f"{readbacks} readbacks for {tokens} tokens breaks the <=1/K "
        "acceptance bound")


@pytest.mark.slow
def test_async_live_loop_role_flip_token_parity():
    """The full stack — ServingLoop + async dispatch/commit cluster +
    K=8 horizons on the real engine — streams every token, survives a
    drain-and-flip, and matches the synchronous K=1 run token-for-
    token."""
    from repro.launch import serve
    from repro.serving import ServingLoop

    cfg = reduced_config("smollm-135m")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    slo = SLO(ttft=5.0, tpot=0.5)

    def live_loop(async_exec, horizon, sink=None):
        sc = ServingConfig(model="smollm-135m", tp=1, policy="taichi",
                           sliders=Sliders(n_p=1, n_d=1, s_p=64, s_d=32),
                           hbm_blocks=512)
        factory = lambda: JaxExecutor(cfg, params, n_slots=8, max_seq=512)
        cluster = build_cluster(sc, slo, executor_factory=factory,
                                async_exec=async_exec)
        cluster.set_horizon(horizon)
        arrivals = serve.TINY.iter_requests(4.0, seed=0,
                                            max_new_tokens=24, limit=8)
        return ServingLoop(cluster, slo, arrivals=arrivals,
                           on_token=sink)

    streamed = {}
    loop = live_loop(True, 8,
                     sink=lambda r, t, tok:
                     streamed.setdefault(r.rid, []).append(tok))
    cluster = loop.cluster
    d_inst = next(i for i in cluster.instances if i.itype == D_HEAVY)
    guard = 0
    while not d_inst.decoding and guard < 4000:
        assert loop.run(max_steps=5) > 0 or loop._arrivals is not None
        guard += 1
    assert loop.flip_role(d_inst, P_HEAVY, 64)
    loop.run()
    assert d_inst.itype == P_HEAVY and cluster.role_flip_count == 1
    assert all(r.state == State.FINISHED for r in loop.requests)
    for r in loop.requests:
        assert streamed[r.rid] == r.output_tokens

    base = live_loop(False, 1)
    base.run()
    assert len(base.requests) == len(loop.requests)
    for a, b in zip(loop.requests, base.requests):
        assert a.prompt_tokens == b.prompt_tokens
        assert a.output_tokens == b.output_tokens, (
            "async horizon pipeline must not perturb greedy streams")
    assert sum(getattr(i.executor, "horizon_calls", 0)
               for i in cluster.instances) > 0
