"""Cross-format migration guards and preemption-by-recompute on the
real tensor paths.

Dense<->paged cross-migration is unsupported (states are
format-homogeneous); ``insert_state`` must fail with a
``MigrationFormatError`` that names BOTH formats instead of a KeyError
deep in the landing code.  Preemption-by-recompute re-prefills via the
negative-``prefill_pos`` semantics inherited from the sim; the slow
tests assert greedy-token parity with an unpreempted run on both the
paged and dense engines (ROADMAP flagged this untested beyond the
sim)."""
import pytest

jax = pytest.importorskip("jax")

import numpy as np                                            # noqa: E402

from repro.configs import reduced_config                      # noqa: E402
from repro.core.estimator import CostModel                    # noqa: E402
from repro.core.hw import InstanceSpec                        # noqa: E402
from repro.core.instance import D_HEAVY, Instance             # noqa: E402
from repro.engine.engine import (JaxExecutor,                 # noqa: E402
                                 MigrationFormatError)
from repro.engine.request import Request, State               # noqa: E402
from repro.models import transformer as tf                    # noqa: E402


@pytest.fixture(scope="module")
def setup():
    cfg = reduced_config("smollm-135m")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    cost = CostModel(cfg, InstanceSpec(tp=1))
    return cfg, params, cost


# ---------------------------------------------------------------------------
# MigrationFormatError: clear failure on dense<->paged cross-migration
# ---------------------------------------------------------------------------

def test_dense_state_into_paged_executor_raises(setup):
    cfg, params, _ = setup
    dst = JaxExecutor(cfg, params, n_slots=2, max_seq=64, paged=True)
    req = Request(prompt_len=8, max_new_tokens=4,
                  prompt_tokens=list(range(1, 9)))
    dense_state = {"row": object(), "pos": 8, "last_token": 3}
    with pytest.raises(MigrationFormatError) as ei:
        dst.insert_state(req, dense_state)
    msg = str(ei.value)
    assert "dense" in msg and "paged" in msg
    assert "like engines" in msg


def test_paged_state_into_dense_executor_raises(setup):
    cfg, params, _ = setup
    dst = JaxExecutor(cfg, params, n_slots=2, max_seq=64, paged=False)
    req = Request(prompt_len=8, max_new_tokens=4,
                  prompt_tokens=list(range(1, 9)))
    paged_state = {"paged_blocks": object(), "n_blocks": 1, "pos": 8,
                   "last_token": 3, "prompt_tokens": list(range(1, 9))}
    with pytest.raises(MigrationFormatError) as ei:
        dst.insert_state(req, paged_state)
    msg = str(ei.value)
    assert "dense" in msg and "paged" in msg


def test_format_error_is_a_value_error(setup):
    # callers that caught ValueError for the old message keep working
    assert issubclass(MigrationFormatError, ValueError)


# ---------------------------------------------------------------------------
# preemption-by-recompute parity on the tensor paths (slow)
# ---------------------------------------------------------------------------

def _generate(cfg, params, cost, prompts, n_out, *, paged, batched=True,
              preempt_after=None, chunk=32):
    ex = JaxExecutor(cfg, params, n_slots=len(prompts) + 1, max_seq=256,
                     batched=batched, paged=paged, t_buckets=(8, 16, 32))
    inst = Instance(0, D_HEAVY, chunk, cost, ex, hbm_blocks=512)
    reqs = [Request(prompt_len=len(p), max_new_tokens=n_out,
                    hidden_output_len=n_out, prompt_tokens=list(p))
            for p in prompts]
    for r in reqs:
        inst.enqueue_prefill(r)
    preempted = False
    now, guard = 0.0, 0
    while not all(r.done() for r in reqs) and guard < 400:
        dur, done, _ = inst.run_iteration(now)
        now += dur
        guard += 1
        for r in done:
            inst.admit_decode(r)
        if preempt_after is not None and not preempted:
            victim = reqs[0]
            if victim.rid in inst.decoding \
                    and victim.output_len >= preempt_after:
                inst._preempt(victim)
                preempted = True
                assert victim.prefill_pos < 0
                assert victim.recompute_offset == victim.output_len
    assert all(r.done() for r in reqs)
    if preempt_after is not None:
        assert preempted, "the victim never reached the preemption point"
        assert inst.preemptions == 0, "test preempts manually, not OOM"
    return [r.output_tokens for r in reqs]


@pytest.mark.slow
@pytest.mark.parametrize("paged", [True, False],
                         ids=["paged", "dense-packed"])
def test_preempt_mid_decode_token_parity(setup, paged):
    cfg, params, cost = setup
    rng = np.random.default_rng(0)
    prompts = [[int(x) for x in rng.integers(1, cfg.vocab_size, size=n)]
               for n in (23, 41)]
    base = _generate(cfg, params, cost, prompts, 16, paged=paged)
    pre = _generate(cfg, params, cost, prompts, 16, paged=paged,
                    preempt_after=6)
    assert pre == base, (
        "preemption-by-recompute must be greedy-token-exact vs. the "
        "unpreempted run")


@pytest.mark.slow
def test_preempt_twice_token_parity(setup):
    """A second preemption after the first recompute completes must
    still recover the exact stream (recompute_offset is re-derived)."""
    cfg, params, cost = setup
    rng = np.random.default_rng(1)
    prompts = [[int(x) for x in rng.integers(1, cfg.vocab_size, size=31)]]
    base = _generate(cfg, params, cost, prompts, 18, paged=True)

    ex = JaxExecutor(cfg, params, n_slots=2, max_seq=256, paged=True,
                     t_buckets=(8, 16, 32))
    inst = Instance(0, D_HEAVY, 32, cost, ex, hbm_blocks=512)
    req = Request(prompt_len=31, max_new_tokens=18, hidden_output_len=18,
                  prompt_tokens=list(prompts[0]))
    inst.enqueue_prefill(req)
    hits = []
    now, guard = 0.0, 0
    while not req.done() and guard < 500:
        dur, done, _ = inst.run_iteration(now)
        now += dur
        guard += 1
        for r in done:
            inst.admit_decode(r)
        if req.rid in inst.decoding and req.output_len in (5, 11) \
                and req.output_len not in hits:
            hits.append(req.output_len)
            inst._preempt(req)
    assert req.done() and len(hits) == 2
    assert req.output_tokens == base[0]
