"""Estimator tests: the phenomenological facts the paper's scheduling
relies on (Obs 2 linearity, Obs 3 capacity) must hold in the cost model."""
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config
from repro.core.estimator import CostModel
from repro.core.hw import InstanceSpec


@pytest.fixture(scope="module")
def cm():
    return CostModel(get_config("qwen2.5-14b"), InstanceSpec(tp=4))


def test_obs2_tpot_linear_in_interference(cm):
    xs = np.array([0, 128, 256, 512, 1024, 2048, 4096])
    ys = np.array([cm.decode_iteration_time(16, 1024, int(c)) for c in xs])
    slope, intercept = np.polyfit(xs, ys, 1)
    r2 = 1 - ((ys - (slope * xs + intercept)) ** 2).sum() / \
        ((ys - ys.mean()) ** 2).sum()
    assert r2 > 0.98, r2
    assert slope > 0 and intercept > 0


def test_obs3_capacity_grows_with_chunk(cm):
    caps = [cm.prefill_capacity(c, decode_batch=16)
            for c in (128, 256, 512, 1024, 2048)]
    assert all(a < b + 1e-6 for a, b in zip(caps, caps[1:])), caps


def test_decode_time_grows_with_batch_and_context(cm):
    assert cm.decode_iteration_time(64, 1024) > \
        cm.decode_iteration_time(8, 1024)
    assert cm.decode_iteration_time(16, 8192) > \
        cm.decode_iteration_time(16, 512)


def test_transfer_time_linear_in_context(cm):
    t1, t2 = cm.transfer_time(1024), cm.transfer_time(4096)
    assert 3.5 <= t2 / t1 <= 4.5


def test_ssm_migration_cheaper_than_attention():
    """DESIGN §4: flowing an SSM request moves O(1) state; an attention
    request moves O(context) KV."""
    ssm = CostModel(get_config("mamba2-1.3b"), InstanceSpec(tp=1))
    att = CostModel(get_config("qwen2.5-3b"), InstanceSpec(tp=1))
    assert ssm.state_bytes(16384) < att.state_bytes(16384) / 10
    # and SSM transfer time is ~independent of context
    assert abs(ssm.transfer_time(16384) - ssm.transfer_time(1024)) < 1e-4


@pytest.mark.parametrize("arch", ASSIGNED)
def test_costs_finite_for_all_archs(arch):
    cm = CostModel(get_config(arch), InstanceSpec(tp=4))
    t = cm.iteration_time([(512, 1024)], [1024] * 8)
    assert np.isfinite(t) and t > 0
    assert cm.prefill_time(2048, 512) > 0
    assert cm.state_bytes(2048) > 0


def test_tp_reduces_iteration_time():
    cfg = get_config("qwen2.5-14b")
    t1 = CostModel(cfg, InstanceSpec(tp=2)).decode_iteration_time(16, 1024)
    t4 = CostModel(cfg, InstanceSpec(tp=4)).decode_iteration_time(16, 1024)
    assert t4 < t1
