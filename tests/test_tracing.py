"""Request-lifecycle tracing: span-partition invariants under chaos,
tracing-off bit-identicality, SLO attribution arithmetic, the Chrome
trace / JSONL / Prometheus exporters, the controller decision audit
trail, the sync-path watchdog heartbeat, and telemetry snapshot
consistency under concurrent readers."""
import itertools
import json
import threading

import pytest

from repro.core.latency import SLO
from repro.core.policies import Sliders
from repro.engine.request import Request, State, TERMINAL_STATES
from repro.serving import (ControllerConfig, ServingLoop, SliderController,
                           TelemetryWindow, TraceConfig, Tracer,
                           WatchdogConfig, prometheus_text)
from repro.serving.faults import STALL, Fault, FaultInjector
from repro.serving.tracing import PHASES
from repro.sim.simulator import ServingConfig, build_cluster
from repro.sim.workload import DRIFT, SHAREGPT

BAL = SLO(ttft=1.5, tpot=0.030)
LOOSE = SLO(ttft=10.0, tpot=1.0)


def _mk_loop(policy="taichi", sliders=Sliders(2, 2, 1024, 256),
             blocks=4096, slo=LOOSE, ft=None, async_exec=False, **kw):
    sc = ServingConfig(policy=policy, sliders=sliders, hbm_blocks=blocks)
    cluster = build_cluster(sc, slo, ft=ft, async_exec=async_exec)
    return ServingLoop(cluster, slo, **kw)


def _outcome(loop):
    """Per-request outcome signature for bit-identicality checks."""
    return [(r.rid, r.state.value, r.finish_time, r.output_len,
             r.first_token_time) for r in loop.requests]


# ---------------------------------------------------------------------------
# span partition property (chaos included)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2])
def test_span_partition_under_chaos(seed):
    """Every terminal request has a trace whose spans form a contiguous,
    non-overlapping partition of [t_begin, t_end] with known phase
    names, and the breakdown sums exactly to end-to-end latency — even
    under a randomized fault schedule (preemption, recompute recovery,
    transfer retries, stalls)."""
    reqs = SHAREGPT.sample_requests(100, 60.0, seed=100 + seed)
    t_end = max(r.arrival for r in reqs)
    inj = FaultInjector.random_schedule(
        seed, [0, 1, 2, 3], t_end=t_end, n_crashes=1, n_stalls=1,
        n_exec_errors=1, stall_duration=0.5, recover_after=0.8,
        transfer_drop_p=0.05)
    loop = _mk_loop(arrivals=iter(reqs), steal=False, faults=inj,
                    async_exec=True, tracing=TraceConfig(),
                    watchdog=WatchdogConfig(heartbeat_timeout=0.4,
                                            probation=0.5,
                                            check_every=0.05))
    loop.run()
    tr = loop.tracer
    terminal = [r for r in loop.requests if r.state in TERMINAL_STATES]
    assert terminal and len(tr) >= len(terminal)
    for r in terminal:
        t = tr.get(r.rid)
        assert t is not None, f"terminal request {r.rid} has no trace"
        assert t.done
        assert t.spans[0].t0 == t.t_begin
        for sp in t.spans:
            assert sp.phase in PHASES
            assert sp.t1 is not None and sp.t1 >= sp.t0
        for a, b in zip(t.spans, t.spans[1:]):
            assert a.t1 == b.t0, "spans must share endpoints"
        assert t.spans[-1].t1 == t.t_end
        bd = tr.breakdown(r.rid)
        assert abs(sum(bd.values()) - t.e2e()) < 1e-6
    # the chaos run actually exercised the interesting paths
    assert sum(inj.fired.values()) >= 1
    names = {n for _, n, _ in tr.global_events}
    assert names, "cluster-scoped events must be recorded under faults"


def test_finished_requests_reach_decode_and_ttft_clips():
    reqs = SHAREGPT.sample_requests(60, 40.0, seed=5)
    loop = _mk_loop(arrivals=iter(reqs), steal=False, slo=BAL,
                    tracing=TraceConfig())
    loop.run()
    tr = loop.tracer
    fin = [r for r in loop.requests if r.state == State.FINISHED]
    assert fin
    for r in fin:
        t = tr.get(r.rid)
        phases = [sp.phase for sp in t.spans]
        assert phases[0] == "queue"
        assert "prefill" in phases and "decode" in phases
        tb = tr.ttft_breakdown(r.rid)
        assert abs(sum(tb.values())
                   - (r.first_token_time - t.t_begin)) < 1e-6
        # prefill chunk events carry the cache-hit offset
        chunk = [a for tt, n, a in t.events if n == "prefill_chunk"]
        assert chunk and all("cached" in a and "take" in a for a in chunk)


def test_tracing_off_is_bit_identical():
    """tracing=None (the default) must not perturb a single outcome —
    the tracer is observational only."""
    outs = []
    for tracing in (None, TraceConfig()):
        reqs = SHAREGPT.sample_requests(80, 50.0, seed=9)
        loop = _mk_loop(arrivals=iter(reqs), steal=False, slo=BAL,
                        tracing=tracing)
        loop.run()
        outs.append(_outcome(loop))
    ids0 = [o[1:] for o in outs[0]]
    ids1 = [o[1:] for o in outs[1]]
    assert ids0 == ids1
    assert any(o[1] == "finished" for o in outs[0])


def test_trace_eviction_bound_and_degenerate_finish():
    reqs = SHAREGPT.sample_requests(40, 60.0, seed=3)
    loop = _mk_loop(arrivals=iter(reqs), steal=False,
                    tracing=TraceConfig(max_requests=8))
    loop.run()
    tr = loop.tracer
    assert len(tr._done) <= 8
    assert tr.dropped_traces >= len(reqs) - 8
    # a request finish()ed without ever begin()ing still gets a trace
    ghost = Request(prompt_len=4, max_new_tokens=2, arrival=1.0)
    tr.finish(ghost, 2.5)
    g = tr.get(ghost.rid)
    assert g is not None and g.done and g.t_begin == 1.0
    assert abs(sum(tr.breakdown(ghost.rid).values()) - 1.5) < 1e-6


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------

def _traced_loop(tmp_path=None):
    reqs = SHAREGPT.sample_requests(50, 40.0, seed=7)
    loop = _mk_loop(arrivals=iter(reqs), steal=False, slo=BAL,
                    tracing=TraceConfig())
    loop.run()
    return loop


def test_chrome_trace_schema(tmp_path):
    loop = _traced_loop()
    doc = loop.tracer.to_chrome_trace()
    assert set(doc) == {"traceEvents", "displayTimeUnit"}
    evs = doc["traceEvents"]
    assert any(e["ph"] == "M" and e["args"]["name"] == "requests"
               for e in evs)
    for e in evs:
        assert e["ph"] in ("M", "X", "i")
        assert {"pid", "tid", "name"} <= set(e)
        if e["ph"] == "X":
            assert e["ts"] >= 0 and e["dur"] >= 0
            assert e["name"] in PHASES
        if e["ph"] == "i":
            assert "ts" in e
    # file dump round-trips as JSON
    out = tmp_path / "trace.json"
    loop.tracer.dump_chrome(str(out))
    assert json.loads(out.read_text())["traceEvents"]


def test_jsonl_export_parses(tmp_path):
    loop = _traced_loop()
    out = tmp_path / "trace.jsonl"
    loop.tracer.dump_jsonl(str(out))
    kinds = set()
    rids = set()
    for line in out.read_text().splitlines():
        rec = json.loads(line)
        kinds.add(rec["kind"])
        if "rid" in rec:
            rids.add(rec["rid"])
    assert {"meta", "span"} <= kinds
    fin = {r.rid for r in loop.requests if r.state == State.FINISHED}
    assert fin <= rids


def test_violation_report_attributes_budget():
    # SLO so tight every finished request violates TTFT and TPOT
    tight = SLO(ttft=1e-6, tpot=1e-9)
    reqs = SHAREGPT.sample_requests(40, 40.0, seed=11)
    loop = _mk_loop(arrivals=iter(reqs), steal=False, slo=BAL,
                    tracing=TraceConfig())
    loop.run()
    rep = loop.tracer.violation_report(tight)
    assert rep["finished"] > 0
    assert rep["ttft"]["violations"] == rep["finished"]
    assert rep["ttft"]["mean_excess_s"] > 0
    assert rep["tpot"]["violations"] > 0
    assert set(rep["ttft"]["mean_phase_s"]) <= set(PHASES)
    assert set(rep["tpot"]["mean_phase_s"]) <= set(PHASES)
    # a loose SLO attributes nothing
    clean = loop.tracer.violation_report(SLO(ttft=1e9, tpot=1e9))
    assert clean["ttft"]["violations"] == 0
    assert clean["tpot"]["mean_phase_s"] == {}


def test_prometheus_text_renders_snapshot():
    reqs = SHAREGPT.sample_requests(50, 40.0, seed=13)
    loop = _mk_loop(arrivals=iter(reqs), steal=False, slo=BAL)
    loop.run()
    text = prometheus_text(loop.snapshot())
    lines = text.splitlines()
    assert "# TYPE taichi_finished_total counter" in lines
    assert "# TYPE taichi_goodput_rps gauge" in lines
    # per-instance series carry iid/itype labels
    assert any(l.startswith("taichi_instance_hbm_util{")
               and 'iid="0"' in l for l in lines)
    # horizon histogram exports one series per K
    assert any(l.startswith("taichi_instance_horizon_hist{")
               and 'k="1"' in l for l in lines)
    # every sample line parses as "name{labels} value"
    for l in lines:
        if l.startswith("#"):
            continue
        name, _, val = l.rpartition(" ")
        float(val)
        assert name


def test_prometheus_handles_admission_and_health_labels():
    snap = {
        "finished_total": 3,
        "admission": {"depth": 2,
                      "depth_by_class": {"interactive": 1, "batch": 1},
                      "released_by_class": {"interactive": 5},
                      "released_total": 5},
        "instances": [{"iid": 0, "itype": "P", "hbm_util": 0.5,
                       "health": "quarantined",
                       "exec": {"host_readbacks": 7, "jit_compiles": 3}}],
    }
    text = prometheus_text(snap)
    assert 'taichi_admission_depth{cls="interactive"} 1' in text
    assert ('taichi_admission_released_by_class_total'
            '{cls="interactive"} 5') in text
    assert ('taichi_instance_health{health="quarantined",iid="0",'
            'itype="P"} 1') in text
    assert ('taichi_instance_exec_host_readbacks{iid="0",itype="P"} 7'
            in text)


# ---------------------------------------------------------------------------
# controller decision audit trail
# ---------------------------------------------------------------------------

def test_controller_audit_explains_every_move():
    ctl = SliderController(ControllerConfig(
        epoch=0.5, cooldown=1, min_evidence=2))
    reqs = itertools.islice(DRIFT.iter_requests(60.0, seed=21), 260)
    loop = _mk_loop(arrivals=reqs, steal=False, slo=BAL, controller=ctl,
                    window=3.0, tracing=TraceConfig())
    loop.run()
    assert ctl.audit, "epochs ran, audit must have records"
    for rec in ctl.audit:
        sig = rec["signals"]
        assert {"ttft_att", "tpot_att", "ttft_bad", "tpot_bad", "s_d",
                "s_p", "n_p", "n_d", "evidence"} <= set(sig)
        # an epoch either acted or says why it held (or which guards
        # blocked the starved branch it took)
        assert rec["actions"] or "hold" in rec or "guards" in rec
    # every recorded move appears in exactly one epoch's action list
    audited = [a for rec in ctl.audit for a in rec["actions"]]
    assert audited == ctl.moves
    assert all("why" in m for m in ctl.moves)
    # all but the trailing epoch closed the loop with the observed effect
    assert all("observed" in rec for rec in ctl.audit[:-1])
    assert ctl.moves, "drift workload should force at least one move"
    # controller actuations also land in the cluster-scoped trace log
    names = [n for _, n, _ in loop.tracer.global_events]
    assert any(n.startswith("controller_") for n in names)


def test_controller_audit_bounded_and_optional():
    ctl = SliderController(ControllerConfig(
        epoch=0.5, audit_max_epochs=4))
    loop = _mk_loop(arrivals=iter(SHAREGPT.sample_requests(
        120, 30.0, seed=2)), steal=False, slo=BAL, controller=ctl)
    loop.run()
    assert len(ctl.audit) <= 4
    off = SliderController(ControllerConfig(epoch=0.5, audit=False))
    loop2 = _mk_loop(arrivals=iter(SHAREGPT.sample_requests(
        60, 30.0, seed=2)), steal=False, slo=BAL, controller=off)
    loop2.run()
    assert off.audit == []


# ---------------------------------------------------------------------------
# sync-path watchdog heartbeat (dispatch-time overrun)
# ---------------------------------------------------------------------------

def test_sync_executor_stall_trips_watchdog():
    """With async_exec=False the dispatch/commit split is atomic, so
    ``step_deadline`` is never observable mid-step — the dispatch-time
    ``overrun`` gauge is the heartbeat signal instead."""
    reqs = SHAREGPT.sample_requests(120, 60.0, seed=10)
    inj = FaultInjector([Fault(0.3, STALL, 0, duration=5.0)])
    wd = WatchdogConfig(heartbeat_timeout=0.3, probation=0.5,
                        check_every=0.05)
    loop = _mk_loop(arrivals=iter(reqs), steal=False, async_exec=False,
                    faults=inj, watchdog=wd)
    loop.run()
    assert inj.fired[STALL] == 1
    assert loop.cluster.quarantines >= 1, \
        "sync-path stall must trip the watchdog heartbeat"
    assert loop.cluster.instance_recoveries >= 1
    kinds = [e["kind"] for e in loop.log.events]
    assert "quarantine" in kinds and "readmit" in kinds
    assert all(r.state == State.FINISHED for r in loop.requests)
    assert loop.cluster.instances[0].overrun == 0.0  # reset on recovery


# ---------------------------------------------------------------------------
# telemetry consistency under concurrent snapshots
# ---------------------------------------------------------------------------

def test_snapshot_consistent_under_concurrent_mutation():
    tw = TelemetryWindow(SLO(ttft=1e9, tpot=1e9), window=1e9)
    stop = threading.Event()
    bad = []

    def reader():
        while not stop.is_set():
            snap = tw.snapshot(50.0)
            # every on_finish here is SLO-ok, so a torn read is the
            # only way these can ever differ
            if snap["finished_total"] != snap["slo_ok_total"]:
                bad.append(snap)

    th = threading.Thread(target=reader)
    th.start()
    for i in range(4000):
        r = Request(prompt_len=4, max_new_tokens=2, arrival=0.0)
        r.record_token(0.1)
        r.record_token(0.2)
        tw.on_token(r, 0.1)
        tw.on_finish(r, 0.2)
    stop.set()
    th.join()
    assert not bad, f"torn snapshot: {bad[0]}"
    assert tw.snapshot(50.0)["finished_total"] == 4000


def test_instance_gauges_surface_executor_counters():
    reqs = SHAREGPT.sample_requests(30, 40.0, seed=4)
    loop = _mk_loop(arrivals=iter(reqs), steal=False, slo=BAL)
    loop.run()
    snap = loop.snapshot()
    for g in snap["instances"]:
        # SimExecutor has no hot-path counters: sim snapshots keep shape
        assert "exec" not in g
    busy = [g for g in snap["instances"] if g.get("horizon_hist")]
    assert busy, "instances that planned iterations export the histogram"

    class FakeExec:
        host_readbacks = 11
        host_syncs = 2
        horizon_calls = 5
        horizon_tokens = 40

        @staticmethod
        def jit_compiles():
            return 9

    inst = loop.cluster.instances[0]
    real_ex = inst.executor
    try:
        inst.executor = FakeExec()
        g = TelemetryWindow._instance_gauges(inst)
        assert g["exec"] == {"host_readbacks": 11, "host_syncs": 2,
                             "horizon_calls": 5, "horizon_tokens": 40,
                             "jit_compiles": 9}
    finally:
        inst.executor = real_ex


def test_admission_released_by_class_counter():
    from repro.frontend import AdmissionConfig, AdmissionQueue
    q = AdmissionQueue(AdmissionConfig())
    q.push(Request(prompt_len=4, max_new_tokens=2), "interactive", 0.0)
    q.push(Request(prompt_len=4, max_new_tokens=2), "batch", 0.0)
    q.pop()
    assert q.released_by_class["interactive"] == 1
    assert q.released_by_class["batch"] == 0
    g = q.gauges(1.0)
    assert g["released_by_class"]["interactive"] == 1
