"""Direct unit tests for Proxy.schedule_prefill: the early-rejection
path, the random infeasible-fallback path (with counter accounting),
and the feasible-set selection rule — previously only exercised
indirectly through test_autotune.py."""
import pytest

from repro.configs import get_config
from repro.core.estimator import CostModel
from repro.core.hw import InstanceSpec
from repro.core.instance import D_HEAVY, Instance, P_HEAVY
from repro.core.proxy import Proxy
from repro.engine.engine import SimExecutor
from repro.engine.request import Request


@pytest.fixture(scope="module")
def cost():
    return CostModel(get_config("qwen2.5-14b"), InstanceSpec(tp=4))


def make_pool(cost, chunks=(1024, 1024, 256, 256)):
    types = [P_HEAVY, P_HEAVY, D_HEAVY, D_HEAVY]
    return [Instance(i, t, c, cost, SimExecutor(), hbm_blocks=1024)
            for i, (t, c) in enumerate(zip(types, chunks))]


def req(plen=300):
    return Request(prompt_len=plen, max_new_tokens=64)


# ---------------------------------------------------------------------------
# feasible path
# ---------------------------------------------------------------------------

def test_picks_fewest_queued_tokens(cost):
    insts = make_pool(cost)
    proxy = Proxy(insts, cost, ttft_slo=1e9)
    insts[0].enqueue_prefill(req(500))
    insts[1].enqueue_prefill(req(200))
    insts[2].enqueue_prefill(req(100))
    insts[3].enqueue_prefill(req(400))
    chosen = proxy.schedule_prefill(req(), now=0.0)
    assert chosen is insts[2]
    assert proxy.infeasible_count == 0 and proxy.rejected_count == 0


def test_tie_breaks_toward_d_heavy(cost):
    insts = make_pool(cost)                  # all queues empty: 4-way tie
    proxy = Proxy(insts, cost, ttft_slo=1e9)
    assert proxy.schedule_prefill(req(), now=0.0).itype == D_HEAVY


def test_pure_decode_instances_excluded(cost):
    insts = make_pool(cost, chunks=(1024, 1024, 0, 0))
    proxy = Proxy(insts, cost, ttft_slo=1e9)
    for _ in range(8):
        assert proxy.schedule_prefill(req(), now=0.0).chunk_size > 0


def test_infeasible_instance_filtered_by_slo(cost):
    """SLO between D-heavy (slow small-chunk) and P-heavy prefill time:
    only P-heavy instances are feasible despite D's shorter queue."""
    insts = make_pool(cost)
    t_p = cost.prefill_time(3000, 1024) + cost.transfer_time(3000)
    t_d = cost.prefill_time(3000, 256)
    assert t_p < t_d
    proxy = Proxy(insts, cost, ttft_slo=(t_p + t_d) / 2)
    chosen = proxy.schedule_prefill(req(3000), now=0.0)
    assert chosen.itype == P_HEAVY
    assert proxy.infeasible_count == 0


# ---------------------------------------------------------------------------
# infeasible: random fallback (default) vs early rejection
# ---------------------------------------------------------------------------

def test_random_fallback_assigns_and_counts(cost):
    insts = make_pool(cost)
    proxy = Proxy(insts, cost, ttft_slo=1e-9, seed=7)
    hits = set()
    for i in range(24):
        r = req()
        chosen = proxy.schedule_prefill(r, now=float(i))
        assert chosen is not None and chosen.chunk_size > 0
        assert r in chosen.prefill_queue
        hits.add(chosen.iid)
    assert proxy.infeasible_count == 24
    assert proxy.rejected_count == 0             # fallback, not rejection
    assert len(hits) > 1                         # actually random across pool


def test_random_fallback_skips_pure_decode(cost):
    insts = make_pool(cost, chunks=(1024, 1024, 0, 0))
    proxy = Proxy(insts, cost, ttft_slo=1e-9, seed=3)
    for i in range(16):
        assert proxy.schedule_prefill(req(), now=float(i)).chunk_size > 0


def test_random_fallback_deterministic_per_seed(cost):
    def route(seed):
        proxy = Proxy(make_pool(cost), cost, ttft_slo=1e-9, seed=seed)
        return [proxy.schedule_prefill(req(), now=0.0).iid
                for _ in range(12)]
    assert route(11) == route(11)
    assert route(11) != route(12)


def test_early_rejection_returns_none_and_counts(cost):
    insts = make_pool(cost)
    proxy = Proxy(insts, cost, ttft_slo=1e-9, early_rejection=True)
    for i in range(5):
        assert proxy.schedule_prefill(req(), now=float(i)) is None
    assert proxy.rejected_count == 5
    assert proxy.infeasible_count == 5           # rejections ARE infeasible
    assert all(not i.prefill_queue for i in insts)


def test_early_rejection_inactive_when_feasible(cost):
    proxy = Proxy(make_pool(cost), cost, ttft_slo=1e9,
                  early_rejection=True)
    assert proxy.schedule_prefill(req(), now=0.0) is not None
    assert proxy.rejected_count == 0 and proxy.infeasible_count == 0


# ---------------------------------------------------------------------------
# destination-aware transfer term: the P-heavy T charge is computed
# against the best decode-placement candidate's cached prefix
# ---------------------------------------------------------------------------

def _pool_with_cached_d(cost, tokens):
    """2 P-heavy + 2 D-heavy; one D-heavy holds ``tokens`` in its
    prefix cache (committed, refcount released)."""
    from repro.cache.prefix_cache import PrefixCache
    insts = make_pool(cost)
    pc = PrefixCache(4096, 16)
    assert pc.acquire(999, tokens, 0, len(tokens) + 16)
    pc.commit(999, tokens)
    pc.release(999)
    holder = insts[2]                       # a D-heavy instance
    holder.prefix_cache = pc
    return insts, holder


def test_transfer_charge_shrinks_with_destination_prefix(cost):
    tokens = list(range(1, 1025))
    insts, holder = _pool_with_cached_d(cost, tokens)
    proxy = Proxy(insts, cost, ttft_slo=1e9)
    r_hit = Request(prompt_len=1024, max_new_tokens=8,
                    prompt_tokens=list(tokens))
    r_miss = Request(prompt_len=1024, max_new_tokens=8,
                     prompt_tokens=[7] * 1024)
    p_inst = insts[0]
    t_hit = proxy._transfer_time(p_inst, r_hit)
    t_miss = proxy._transfer_time(p_inst, r_miss)
    assert t_miss == cost.transfer_time(1024)
    assert t_hit < t_miss, \
        "a cached prefix on the decode destination must shrink T"
    cached = holder.peek_migration_prefix(r_hit)
    assert cached > 0
    assert t_hit == cost.transfer_time(1024 - cached)


def test_transfer_charge_tracks_least_loaded_candidate(cost):
    tokens = list(range(1, 1025))
    insts, holder = _pool_with_cached_d(cost, tokens)
    proxy = Proxy(insts, cost, ttft_slo=1e9)
    r = Request(prompt_len=1024, max_new_tokens=8,
                prompt_tokens=list(tokens))
    # make the holder the more loaded D candidate: the OTHER D-heavy is
    # now the placement choice, and it caches nothing -> full charge
    holder.allocator.allocate(1, 64 * 16)
    assert proxy._transfer_time(insts[0], r) == cost.transfer_time(1024)
    # draining excludes a candidate entirely
    other_d = insts[3]
    other_d.draining = True
    assert proxy._transfer_time(insts[0], r) == \
        cost.transfer_time(1024 - holder.peek_migration_prefix(r))


def test_transfer_charge_zero_for_d_heavy_placement(cost):
    insts = make_pool(cost)
    proxy = Proxy(insts, cost, ttft_slo=1e9)
    r = Request(prompt_len=512, max_new_tokens=8)
    assert proxy._transfer_time(insts[2], r) == 0.0
