"""Property-based tests (hypothesis) for the paged-KV block allocator and
slot table invariants — the substrate Algorithm 1's watermark reads."""
import pytest

pytest.importorskip("hypothesis")

import hypothesis.strategies as st
from hypothesis import given, settings

from repro.engine.kvcache import BlockAllocator, OutOfBlocks, SlotTable


ops = st.lists(
    st.tuples(st.sampled_from(["alloc", "extend", "free"]),
              st.integers(0, 15),            # rid
              st.integers(1, 600)),          # tokens
    min_size=1, max_size=200)


@given(ops=ops, num_blocks=st.integers(4, 64),
       block_size=st.integers(1, 32))
@settings(max_examples=200, deadline=None)
def test_allocator_invariants(ops, num_blocks, block_size):
    a = BlockAllocator(num_blocks, block_size)
    shadow = {}                                   # rid -> blocks held
    for op, rid, tokens in ops:
        need = a.blocks_for(tokens)
        if op == "alloc":
            if rid in shadow:
                continue
            if need <= a.free_blocks:
                a.allocate(rid, tokens)
                shadow[rid] = need
            else:
                try:
                    a.allocate(rid, tokens)
                    assert False, "allocate should have raised"
                except OutOfBlocks:
                    pass
        elif op == "extend":
            if rid not in shadow:
                continue
            if a.can_extend(rid, tokens):
                a.extend(rid, tokens)
                shadow[rid] = max(shadow[rid], need)
        else:
            freed = a.free(rid)
            assert freed == shadow.pop(rid, 0)
        # global invariants after every op
        assert a.used_blocks == sum(shadow.values())
        assert a.free_blocks + a.used_blocks == num_blocks
        assert 0 <= a.utilization() <= 1.0


@given(ops=st.lists(st.tuples(st.booleans(), st.integers(0, 20)),
                    min_size=1, max_size=100),
       n_slots=st.integers(1, 8))
@settings(max_examples=200, deadline=None)
def test_slot_table_invariants(ops, n_slots):
    t = SlotTable(n_slots)
    held = {}
    for acquire, rid in ops:
        if acquire:
            if rid in held or t.free_slots == 0:
                continue
            s = t.acquire(rid)
            assert s not in held.values(), "slot double-assigned"
            assert 0 <= s < n_slots
            held[rid] = s
        else:
            s = t.release(rid)
            if rid in held:
                assert s == held.pop(rid)
            else:
                assert s is None
        assert t.free_slots == n_slots - len(held)


@given(tokens=st.integers(1, 10_000), bs=st.integers(1, 64))
def test_blocks_for_covers_tokens(tokens, bs):
    a = BlockAllocator(1, bs)
    assert a.blocks_for(tokens) * bs >= tokens
    assert (a.blocks_for(tokens) - 1) * bs < tokens
