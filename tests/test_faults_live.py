"""Fault tolerance on the real JAX engine (slow tier): an instance
crash mid-run with evacuation-by-recompute, and lossy KV transfers with
retry + checksum verification — in both cases every request finishes
and the greedy token streams are EXACT against a fault-free oracle run
(the acceptance bar: recovery must be invisible in the output)."""
import pytest

jax = pytest.importorskip("jax")

from repro.core.cluster import FaultToleranceConfig      # noqa: E402
from repro.core.instance import HEALTH_DEAD, HEALTH_OK   # noqa: E402
from repro.core.latency import SLO                       # noqa: E402
from repro.core.policies import Sliders                  # noqa: E402
from repro.engine.engine import JaxExecutor              # noqa: E402
from repro.engine.request import State                   # noqa: E402
from repro.launch import serve                           # noqa: E402
from repro.models import transformer as tf               # noqa: E402
from repro.serving import ServingLoop                    # noqa: E402
from repro.serving.faults import (CRASH, RECOVER, Fault,  # noqa: E402
                                  FaultInjector)
from repro.sim.simulator import ServingConfig, build_cluster  # noqa: E402

BAL = SLO(ttft=5.0, tpot=0.5)          # loose: these tests are about tokens
N_REQ = 8


@pytest.fixture(scope="module")
def setup():
    from repro.configs import reduced_config
    cfg = reduced_config("smollm-135m")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _live_loop(cfg, params, policy="taichi", faults=None, ft=None,
               recovery=None):
    sc = ServingConfig(model="smollm-135m", tp=1, policy=policy,
                       sliders=Sliders(n_p=1, n_d=1, s_p=64, s_d=32),
                       hbm_blocks=512)
    factory = lambda: JaxExecutor(cfg, params, n_slots=16, max_seq=512)
    cluster = build_cluster(sc, BAL, executor_factory=factory, ft=ft,
                            recovery=recovery)
    if faults is not None:
        cluster.attach_faults(faults)
    arrivals = serve.TINY.iter_requests(4.0, seed=0, max_new_tokens=24,
                                        limit=N_REQ)
    return ServingLoop(cluster, BAL, arrivals=arrivals)


def _oracle(cfg, params, policy="taichi"):
    loop = _live_loop(cfg, params, policy=policy)
    loop.run()
    assert all(r.state == State.FINISHED for r in loop.requests)
    return [list(r.output_tokens) for r in loop.requests]


@pytest.mark.slow
def test_live_crash_recovery_is_token_exact(setup):
    cfg, params = setup
    base = _oracle(cfg, params)
    inj = FaultInjector([Fault(0.6, CRASH, 0), Fault(1.6, RECOVER, 0)])
    loop = _live_loop(cfg, params, faults=inj)
    loop.run()
    cluster = loop.cluster
    assert inj.fired[CRASH] == 1, "the crash never fired"
    assert cluster.instance_failures == 1
    assert cluster.instances[0].health == HEALTH_OK   # RECOVER landed
    assert all(r.state == State.FINISHED for r in loop.requests)
    # recovery re-prefills on the survivor: the replayed stream must be
    # greedy-identical to the undisturbed run, token for token
    assert [list(r.output_tokens) for r in loop.requests] == base
    for inst in cluster.instances:
        assert inst.allocator.used_blocks == 0


@pytest.mark.slow
def test_live_crash_fail_stop_resolves_terminally(setup):
    cfg, params = setup
    inj = FaultInjector([Fault(0.6, CRASH, 0)])
    loop = _live_loop(cfg, params, faults=inj,
                      ft=FaultToleranceConfig.fail_stop())
    loop.run()
    cluster = loop.cluster
    assert cluster.instances[0].health == HEALTH_DEAD
    states = {r.state for r in loop.requests}
    assert states <= {State.FINISHED, State.FAILED}
    assert any(r.state == State.FAILED for r in loop.requests) or \
        all(r.state == State.FINISHED for r in loop.requests)
    for r in loop.requests:
        assert r.finish_time is not None
    for inst in cluster.instances:
        assert inst.allocator.used_blocks == 0


@pytest.mark.slow
def test_live_warm_recovery_is_token_exact(setup):
    """Warm recovery on the real engine: victims resume from the latest
    checkpoint (materialized KV or partial re-prefill), and the greedy
    streams still match the fault-free oracle token for token."""
    from repro.serving.recovery import RecoveryConfig
    cfg, params = setup
    base = _oracle(cfg, params)
    # crash the decode instance (iid 1 under n_p=1/n_d=1) while the
    # t~0.43 arrival burst is mid-decode there, so the victims carry
    # checkpointed progress to resume from
    inj = FaultInjector([Fault(0.47, CRASH, 1), Fault(1.0, RECOVER, 1)])
    loop = _live_loop(cfg, params, faults=inj,
                      recovery=RecoveryConfig(enable=True,
                                              checkpoint_tokens=4,
                                              materialize_kv=True))
    loop.run()
    cluster = loop.cluster
    assert inj.fired[CRASH] == 1, "the crash never fired"
    assert all(r.state == State.FINISHED for r in loop.requests)
    assert [list(r.output_tokens) for r in loop.requests] == base
    rc = cluster.recovery_counters()
    assert rc["checkpoints"] > 0
    # at least one victim must have resumed warm (restore or a planned
    # restore that fell back still proves the path was exercised; a
    # zero on both means the crash caught nobody mid-flight)
    assert rc["warm_restores"] + rc["warm_fallbacks"] > 0
    for inst in cluster.instances:
        assert inst.allocator.used_blocks == 0


@pytest.mark.slow
def test_live_lossy_transfers_retry_token_exact(setup):
    cfg, params = setup
    base = _oracle(cfg, params, policy="disaggregation")
    inj = FaultInjector(seed=3, transfer_drop_p=0.3,
                        transfer_corrupt_p=0.15)
    loop = _live_loop(cfg, params, policy="disaggregation", faults=inj)
    loop.run()
    cluster = loop.cluster
    assert cluster.transfer_retries > 0, "no transfer fault ever fired"
    assert all(r.state == State.FINISHED for r in loop.requests)
    assert [list(r.output_tokens) for r in loop.requests] == base
    for inst in cluster.instances:
        assert inst.allocator.used_blocks == 0
