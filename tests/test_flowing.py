"""Fast-tier (pure-Python, no JAX execution) invariants of Algorithm 1's
selection logic — flowing decode scheduling.  These duplicate the
hypothesis-free core of tests/test_scheduler.py so the invariants stay
covered on a bare interpreter (the scheduler module skips entirely when
hypothesis isn't installed)."""
import pytest

from repro.configs import get_config
from repro.core import flowing
from repro.core.estimator import CostModel
from repro.core.hw import InstanceSpec
from repro.core.instance import D_HEAVY, P_HEAVY, Instance
from repro.engine.engine import SimExecutor
from repro.engine.request import Request

COST = CostModel(get_config("qwen2.5-14b"), InstanceSpec(tp=4))


def _inst(iid=0, itype=D_HEAVY, chunk=256, blocks=64, block_size=16):
    return Instance(iid, itype, chunk, COST, SimExecutor(),
                    hbm_blocks=blocks, block_size=block_size)


def _decoding_request(inst, prompt=100, out_len=5, now=0.0,
                      tpot: float = 0.02):
    r = Request(prompt_len=prompt, max_new_tokens=512,
                hidden_output_len=400)
    r.prefill_pos = prompt
    r.output_len = out_len
    r.first_token_time = now
    r.tpot_reset_time = now
    r.last_token_time = now + tpot * max(out_len - 1, 0)
    inst.allocator.allocate(r.rid, r.context_len)
    inst.decoding[r.rid] = r
    return r


# ---------------------------------------------------------------------------
# select_degrade (D-heavy, Algorithm 1 lines 4-12)
# ---------------------------------------------------------------------------

def test_degrade_stops_exactly_at_watermark():
    """The loop must stop at the FIRST point projected usage <= M — no
    over-selection once enough memory is released."""
    inst = _inst(blocks=1000)
    reqs = [_decoding_request(inst, prompt=300, out_len=o)
            for o in (10, 20, 30, 40)]
    used = inst.allocator.used_blocks
    longest = max(reqs, key=lambda r: r.output_len)
    release = inst.allocator.blocks_for(longest.context_len)
    # watermark satisfiable by releasing exactly the single longest request
    watermark = (used - release) / inst.allocator.num_blocks
    sel = flowing.select_degrade(inst, watermark)
    assert [r.rid for r in sel] == [longest.rid]


def test_degrade_never_repeats_and_exhausts_candidates():
    """Unsatisfiable watermark: every decoding request selected exactly
    once, then the loop terminates on candidate exhaustion."""
    inst = _inst(blocks=10_000)
    reqs = [_decoding_request(inst, prompt=200, out_len=o)
            for o in (1, 2, 3, 4, 5)]
    sel = flowing.select_degrade(inst, watermark=0.0)
    rids = [r.rid for r in sel]
    assert len(rids) == len(set(rids)) == len(reqs)
    assert set(rids) == {r.rid for r in reqs}
    # longest-first order
    assert [r.output_len for r in sel] == sorted(
        (r.output_len for r in reqs), reverse=True)


def test_degrade_noop_when_usage_below_watermark():
    inst = _inst(blocks=1000)
    _decoding_request(inst)
    assert flowing.select_degrade(inst, watermark=0.95) == []


def test_degrade_empty_instance():
    inst = _inst(blocks=16)
    assert flowing.select_degrade(inst, watermark=0.0) == []


# ---------------------------------------------------------------------------
# select_backflow (P-heavy, Algorithm 1 lines 1-3)
# ---------------------------------------------------------------------------

def test_backflow_returns_only_tpot_violators():
    inst = _inst(itype=P_HEAVY)
    tpot_slo, alpha = 0.1, 0.9
    fast = _decoding_request(inst, out_len=10, tpot=0.02)
    slow = _decoding_request(inst, out_len=10, tpot=0.095)
    border = _decoding_request(inst, out_len=10, tpot=tpot_slo * alpha)
    out = flowing.select_backflow(inst, tpot_slo, alpha, now=1.0)
    assert [r.rid for r in out] == [slow.rid]
    assert fast.rid not in {r.rid for r in out}
    # boundary: current_tpot == alpha * slo must NOT flow back (strict >)
    assert border.rid not in {r.rid for r in out}
    for r in out:
        assert r.current_tpot(1.0) > alpha * tpot_slo


def test_backflow_skips_requests_without_tpot_window():
    """n <= 1 tokens since reset -> current_tpot is None -> never selected."""
    inst = _inst(itype=P_HEAVY)
    r = _decoding_request(inst, out_len=1, tpot=9.9)
    assert flowing.select_backflow(inst, 0.1, 0.9, now=1.0) == []
    r2 = _decoding_request(inst, out_len=20, tpot=9.9)
    r2.tpot_reset_len = 19          # just flowed back: effectively new
    out = flowing.select_backflow(inst, 0.1, 0.9, now=1.0)
    assert r2.rid not in {x.rid for x in out}
    assert r.rid not in {x.rid for x in out}


def test_backflow_requires_p_heavy_and_degrade_requires_d_heavy():
    with pytest.raises(AssertionError):
        flowing.select_backflow(_inst(itype=D_HEAVY), 0.1, 0.9, now=0.0)
    with pytest.raises(AssertionError):
        flowing.select_degrade(_inst(itype=P_HEAVY), 0.5)
