"""Training substrate: optimizer math, convergence, checkpoint roundtrip."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.models import transformer as tf
from repro.training.checkpoint import load_checkpoint, save_checkpoint
from repro.training.optimizer import (AdamWConfig, adamw_update,
                                      global_norm, init_opt_state, schedule)
from repro.training.train import train_loop

# slow tier: full JAX model/engine execution (run with `pytest -m slow`)
pytestmark = pytest.mark.slow


def test_adamw_first_step_is_signed_lr():
    """After one step with beta-corrected moments, |delta| ~ lr for a
    constant gradient (AdamW property)."""
    cfg = AdamWConfig(lr=1e-2, weight_decay=0.0, warmup_steps=0,
                      clip_norm=1e9)
    params = {"w": jnp.ones((4, 4))}
    grads = {"w": jnp.full((4, 4), 0.5)}
    state = init_opt_state(params)
    new_p, state, gnorm = adamw_update(cfg, params, grads, state)
    delta = np.asarray(params["w"] - new_p["w"])
    np.testing.assert_allclose(delta, 1e-2, rtol=1e-4)
    assert float(gnorm) == pytest.approx(0.5 * 4, rel=1e-5)


def test_grad_clipping():
    cfg = AdamWConfig(lr=1e-3, clip_norm=1.0, warmup_steps=0)
    params = {"w": jnp.zeros((8,))}
    grads = {"w": jnp.full((8,), 100.0)}
    state = init_opt_state(params)
    _, state, gnorm = adamw_update(cfg, params, grads, state)
    # clipped moments: m = (1-b1) * g * scale, scale = 1/gnorm
    scale = 1.0 / float(gnorm)
    np.testing.assert_allclose(np.asarray(state.m["w"]),
                               0.1 * 100.0 * scale, rtol=1e-4)


def test_schedule_warmup_and_decay():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    assert float(schedule(cfg, jnp.float32(5))) == pytest.approx(0.5)
    assert float(schedule(cfg, jnp.float32(10))) == pytest.approx(1.0)
    end = float(schedule(cfg, jnp.float32(100)))
    assert end == pytest.approx(0.1, rel=1e-3)


def test_loss_decreases_smollm():
    cfg = reduced_config("smollm-135m")
    _, hist = train_loop(cfg, steps=25, batch=4, seq=64, log_every=5)
    assert hist[-1]["loss"] < hist[0]["loss"] * 0.95


def test_checkpoint_roundtrip_with_opt_state():
    cfg = reduced_config("gemma3-1b")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    opt = init_opt_state(params)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "c.npz")
        save_checkpoint(path, params, opt, meta={"arch": cfg.name})
        p2, o2 = load_checkpoint(path, params, opt)
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
            assert np.array_equal(np.asarray(a), np.asarray(b))
        assert int(o2.step) == int(opt.step)
        assert os.path.exists(path + ".meta.json")


def test_global_norm():
    t = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    assert float(global_norm(t)) == pytest.approx(5.0)
