"""Network front-end on the real JAX engine (slow tier): many
concurrent streaming HTTP clients against a live ``FrontendServer``,
with greedy-exact token parity against a direct ``ServingLoop`` run on
the same prompts, proof that tokenize/detokenize ran in worker
processes, burst queueing instead of rejection, and a graceful drain.
"""
import json
import os
import socket
import threading
import time

import pytest

jax = pytest.importorskip("jax")

from repro.configs import reduced_config                  # noqa: E402
from repro.core.latency import SLO                        # noqa: E402
from repro.core.policies import Sliders                   # noqa: E402
from repro.engine.engine import JaxExecutor               # noqa: E402
from repro.engine.request import Request, State           # noqa: E402
from repro.frontend import (AdmissionConfig, ByteTokenizer,   # noqa: E402
                            FrontendConfig, FrontendServer)
from repro.serving import ServingLoop                     # noqa: E402
from repro.sim.simulator import ServingConfig, build_cluster  # noqa: E402

BAL = SLO(ttft=5.0, tpot=0.5)          # loose: this test is about tokens
N_CLIENTS = 32
MAX_TOKENS = 8


def _live_loop(admission=None):
    cfg = reduced_config("smollm-135m")
    from repro.models import transformer as tf
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    sc = ServingConfig(model="smollm-135m", tp=1, policy="taichi",
                       sliders=Sliders(n_p=1, n_d=1, s_p=64, s_d=32),
                       hbm_blocks=512)
    factory = lambda: JaxExecutor(cfg, params, n_slots=8, max_seq=512)
    cluster = build_cluster(sc, BAL, executor_factory=factory)
    return ServingLoop(cluster, BAL, admission=admission)


def _stream_request(port, prompt, out, idx):
    s = socket.create_connection(("127.0.0.1", port), timeout=120)
    body = json.dumps({"prompt": prompt, "max_tokens": MAX_TOKENS,
                       "stream": True}).encode()
    s.sendall((f"POST /v1/completions HTTP/1.1\r\nHost: t\r\n"
               f"Content-Length: {len(body)}\r\n"
               "Connection: close\r\n\r\n").encode() + body)
    data = b""
    while chunk := s.recv(65536):
        data += chunk
    s.close()
    out[idx] = data


def _parse_stream(data):
    head, _, payload = data.partition(b"\r\n\r\n")
    status = int(head.split(b" ", 2)[1])
    if status != 200:                  # plain error body, not chunked
        return status, "", False
    body, rest = b"", payload
    while rest:                        # de-chunk
        size, _, rest = rest.partition(b"\r\n")
        n = int(size, 16)
        if n == 0:
            break
        body += rest[:n]
        rest = rest[n + 2:]
    text, finished, errored = "", False, False
    for ev in body.split(b"\n\n"):
        if not ev.startswith(b"data: "):
            continue
        if ev == b"data: [DONE]":
            finished = True
            continue
        obj = json.loads(ev[len(b"data: "):])
        if "choices" not in obj:       # mid-stream cancellation notice
            errored = True
            continue
        choice = obj["choices"][0]
        text += choice["text"] or ""
        if choice["finish_reason"]:
            assert choice["finish_reason"] == "length"
    return status, text, (finished and not errored)


@pytest.mark.slow
def test_live_concurrent_streaming_with_greedy_parity():
    loop = _live_loop(admission=AdmissionConfig(max_depth=128,
                                                max_inflight=8))
    srv = FrontendServer(loop, FrontendConfig(port=0, tok_workers=2))
    th = threading.Thread(target=srv.run, daemon=True)
    th.start()
    assert srv.started.wait(timeout=60)

    prompts = [f"live client {i}: the quick brown fox #{i}"
               for i in range(N_CLIENTS)]
    out = {}
    clients = [threading.Thread(target=_stream_request,
                                args=(srv.port, p, out, i), daemon=True)
               for i, p in enumerate(prompts)]
    for c in clients:
        c.start()
    for c in clients:
        c.join(timeout=300)
    assert len(out) == N_CLIENTS, "every client must get a response"
    streamed = {}
    for i in range(N_CLIENTS):
        status, text, finished = _parse_stream(out[i])
        assert status == 200 and finished
        streamed[i] = text

    # burst behaviour: max_inflight=8 forces queueing, none displaced
    snap = loop.snapshot()
    assert snap["admission"]["enqueued_total"] >= N_CLIENTS
    assert snap["admission"]["displaced_total"] == 0
    assert loop.shed_rejections == 0
    assert "queue_wait" in snap, "queue wait must be a telemetry span"
    assert snap["wire"]["frames"] > 0

    # string work demonstrably ran in the worker processes
    assert srv.seen_worker_pids
    assert os.getpid() not in srv.seen_worker_pids

    # every request on the server side finished with real tokens
    by_prompt = {}
    for r in loop.requests:
        assert r.state == State.FINISHED
        assert len(r.output_tokens) == MAX_TOKENS
        by_prompt[tuple(r.prompt_tokens)] = r

    srv.shutdown()
    th.join(timeout=120)
    assert not th.is_alive(), "graceful shutdown must terminate run()"

    # greedy parity: a direct ServingLoop pass over the SAME prompts
    # must produce byte-identical token streams — the HTTP/pipeline
    # path may not perturb what the engine computes (same in-flight cap:
    # 32 unqueued submissions would overrun the executor's 8 slots)
    direct = _live_loop(admission=AdmissionConfig(max_depth=128,
                                                  max_inflight=8))
    handles = []
    for p in prompts:
        ids = ByteTokenizer.encode(p)
        handles.append(direct.submit(Request(
            prompt_len=len(ids), max_new_tokens=MAX_TOKENS,
            prompt_tokens=list(ids))))
    direct.run()
    for p, h in zip(prompts, handles):
        r = h.result()
        served = by_prompt[tuple(r.prompt_tokens)]
        assert served.output_tokens == r.output_tokens, (
            f"greedy divergence for prompt {p!r}")
        # and the SSE text is exactly the detokenization of those ids
        from repro.frontend import IncrementalDetokenizer
        detok = IncrementalDetokenizer()
        want = "".join(detok.feed(t) for t in r.output_tokens)
        want += detok.flush()
        assert streamed[prompts.index(p)] == want


@pytest.mark.slow
def test_live_graceful_drain_finishes_inflight():
    loop = _live_loop(admission=AdmissionConfig(max_depth=64,
                                                max_inflight=4))
    srv = FrontendServer(loop, FrontendConfig(port=0, tok_workers=0))
    th = threading.Thread(target=srv.run, daemon=True)
    th.start()
    assert srv.started.wait(timeout=60)
    out = {}
    clients = [threading.Thread(target=_stream_request,
                                args=(srv.port, f"drain {i}", out, i),
                                daemon=True)
               for i in range(6)]
    for c in clients:
        c.start()
    # shut down while work is in flight: accepted requests must either
    # finish with real tokens or resolve cancelled — never hang
    deadline_guard = threading.Timer(240.0, srv.shutdown)
    deadline_guard.start()
    while not any(i.decoding for i in loop.cluster.instances) \
            and th.is_alive():
        time.sleep(0.05)
    srv.shutdown()
    for c in clients:
        c.join(timeout=120)
    th.join(timeout=120)
    deadline_guard.cancel()
    assert not th.is_alive()
    assert len(out) == 6
    finished = cancelled = 0
    for i in range(6):
        status, text, done = _parse_stream(out[i])
        if status == 200 and done:
            finished += 1
        else:
            cancelled += 1
    assert finished >= 1, "in-flight work must run to completion"
    for r in loop.requests:
        assert r.state in (State.FINISHED, State.CANCELLED)
