"""Live-engine multi-tier KV tests: int8 paged quantization parity vs
the fp oracle, effective-capacity accounting, host-spill round trips
with real tensor payloads, cross-format migration refusal, and
replicated prefix blocks decoding token-exact on the destination."""
import jax
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.core.estimator import CostModel
from repro.core.hw import InstanceSpec
from repro.core.instance import D_HEAVY, Instance
from repro.engine.engine import JaxExecutor, MigrationFormatError
from repro.engine.paged import PagedKVCache
from repro.engine.request import Request
from repro.models import attention
from repro.models import transformer as tf

# slow tier: full JAX model/engine execution (run with `pytest -m slow`)
pytestmark = pytest.mark.slow


@pytest.fixture(scope="module")
def setup():
    cfg = reduced_config("smollm-135m")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    cost = CostModel(cfg, InstanceSpec(tp=1))
    return cfg, params, cost


def _make(cfg, params, cost, *, quant=None, spill=0, hbm_blocks=None,
          chunk=32, n_slots=4):
    ex = JaxExecutor(cfg, params, n_slots=n_slots, max_seq=256,
                     batched=True, t_buckets=(8, 16, 32), paged=True,
                     prefix_cache=True, hbm_blocks=hbm_blocks,
                     kv_quant=quant, kv_spill_blocks=spill)
    inst = Instance(0, D_HEAVY, chunk, cost, ex, hbm_blocks=512)
    return ex, inst


def _drive(inst, reqs, guard=300):
    now, g = 0.0, 0
    while not all(r.done() for r in reqs) and g < guard:
        dur, done, _ = inst.run_iteration(now)
        now += dur
        g += 1
        for r in done:
            inst.admit_decode(r)
    assert all(r.done() for r in reqs)


def _req(prompt, n_out=5):
    return Request(prompt_len=len(prompt), max_new_tokens=n_out,
                   hidden_output_len=n_out, prompt_tokens=list(prompt))


# ---------------------------------------------------------------------------
# int8 paged blocks
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kernels", [False, True], ids=["jnp", "pallas"])
def test_int8_greedy_parity_vs_fp_oracle(setup, kernels):
    """Per-token-scale int8 KV must not flip a single greedy token vs
    the full-precision paged engine — on both the gather reference and
    the Pallas kernel (interpret) decode/prefill paths."""
    cfg, params, cost = setup
    rng = np.random.default_rng(21)
    prompts = [list(rng.integers(1, cfg.vocab_size, size=n))
               for n in (11, 30, 46)]
    prev = attention._USE_KERNELS
    attention.use_kernels(kernels)
    try:
        def gen(quant):
            ex, inst = _make(cfg, params, cost, quant=quant)
            assert ex.kv.quant == quant
            reqs = [_req(p, 6) for p in prompts]
            for r in reqs:
                inst.enqueue_prefill(r)
            _drive(inst, reqs)
            return [r.output_tokens for r in reqs]

        assert gen("int8") == gen(None)
    finally:
        attention.use_kernels(prev)


def test_int8_effective_capacity_ratio(setup):
    """The point of quantizing: >=1.8x tokens per HBM byte (int8 + f32
    per-token scales vs the fp pool at the model's own KV dtype)."""
    cfg, params, cost = setup
    fp = PagedKVCache.token_bytes_for(cfg)
    q = PagedKVCache.token_bytes_for(cfg, quant="int8")
    assert fp / q >= 1.8
    ex, _ = _make(cfg, params, cost, quant="int8")
    assert ex.kv.effective_capacity_ratio() == pytest.approx(fp / q)


# ---------------------------------------------------------------------------
# host spill tier with real tensor payloads
# ---------------------------------------------------------------------------

def test_spill_prefetch_decode_token_exact(setup):
    """Evict a committed prefix out of a tiny HBM pool into host RAM,
    promote it back on the next hit, and decode — tokens must match a
    never-evicted run exactly (the payload round trip is lossless and
    the promoted blocks land where the block table says they do)."""
    cfg, params, cost = setup
    rng = np.random.default_rng(22)
    hot = list(rng.integers(1, cfg.vocab_size, size=64))      # 4 blocks
    cold = list(rng.integers(1, cfg.vocab_size, size=128))    # 8 blocks

    # control: ample pool, nothing ever evicted
    ex0, inst0 = _make(cfg, params, cost)
    ctl = _req(hot)
    inst0.enqueue_prefill(ctl)
    _drive(inst0, [ctl])

    # pressured pool: 12 blocks = one 8-block admission short of two
    ex, inst = _make(cfg, params, cost, spill=16, hbm_blocks=12)
    pc = ex.prefix_cache_obj
    a = _req(hot)
    inst.enqueue_prefill(a)
    _drive(inst, [a])
    assert pc.match_tokens(hot + [0]) == 64       # committed + resident
    b = _req(cold)                                # needs the whole pool
    inst.enqueue_prefill(b)
    _drive(inst, [b])
    assert pc.spilled_blocks >= 4                 # hot prefix pushed out
    assert pc.match_tokens(hot + [0]) == 0
    c = _req(hot)
    inst.enqueue_prefill(c)
    _drive(inst, [c])
    # admission prefetched from the host tier instead of recomputing
    assert pc.spill.promoted >= 3
    assert inst.spill_promoted_tokens >= 48
    assert c.cached_prefix_len >= 48
    assert c.output_tokens == a.output_tokens == ctl.output_tokens
    # conservation held under the spill/promote churn
    al = pc.allocator
    assert al.free_blocks + al.cached_blocks + al.used_blocks == 12


# ---------------------------------------------------------------------------
# cross-format migration refusal
# ---------------------------------------------------------------------------

def test_migration_format_mismatch_raises(setup):
    cfg, params, cost = setup
    ex_q, inst_q = _make(cfg, params, cost, quant="int8")
    ex_f, inst_f = _make(cfg, params, cost)
    rng = np.random.default_rng(23)
    req = _req(list(rng.integers(1, cfg.vocab_size, size=24)), 8)
    inst_q.enqueue_prefill(req)
    now = 0.0
    while req.prefill_remaining > 0:
        dur, _, _ = inst_q.run_iteration(now)
        now += dur
    inst_q.admit_decode(req)
    for _ in range(2):
        dur, _, _ = inst_q.run_iteration(now)
        now += dur
    state = inst_q.eject(req)
    assert state["kv_format"] == "int8"
    with pytest.raises(MigrationFormatError):
        inst_f.inject(req, state)


# ---------------------------------------------------------------------------
# replication payloads decode token-exact on the destination
# ---------------------------------------------------------------------------

def test_replicated_prefix_blocks_decode_token_exact(setup):
    cfg, params, cost = setup
    rng = np.random.default_rng(24)
    shared = list(rng.integers(1, cfg.vocab_size, size=48))   # 3 blocks
    tail = list(rng.integers(1, cfg.vocab_size, size=13))

    ex_src, inst_src = _make(cfg, params, cost)
    warm = _req(shared + tail)
    inst_src.enqueue_prefill(warm)
    _drive(inst_src, [warm])
    state = ex_src.export_prefix_blocks(shared)
    assert state is not None and state["n_blocks"] == 3
    assert state["kv_format"] == "fp"

    ex_dst, inst_dst = _make(cfg, params, cost)
    assert ex_dst.import_prefix_blocks(state) == 3
    assert ex_dst.prefix_cache_obj.match_tokens(shared + [0]) == 48

    # control for the destination's exact prompt, computed cache-free
    ex0, inst0 = _make(cfg, params, cost)
    probe0 = _req(shared + tail[:5])
    inst0.enqueue_prefill(probe0)
    _drive(inst0, [probe0])

    probe = _req(shared + tail[:5])
    inst_dst.enqueue_prefill(probe)
    _drive(inst_dst, [probe])
    assert probe.cached_prefix_len == 48          # replica actually used
    assert probe.output_tokens == probe0.output_tokens
    # format guard: an int8 destination refuses fp replica payloads
    ex_q, _ = _make(cfg, params, cost, quant="int8")
    with pytest.raises(MigrationFormatError):
        ex_q.import_prefix_blocks(state)
