"""Per-arch smoke tests (reduced configs) + chunked-prefill equivalence —
the numerical foundation of differentiated-capability instances."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config, reduced_config
from repro.models import transformer as tf

# slow tier: full JAX model/engine execution (run with `pytest -m slow`)
pytestmark = pytest.mark.slow

KEY = jax.random.PRNGKey(0)
B, T = 2, 32


def _modal_kwargs(cfg):
    kw = {}
    if cfg.family == "vlm":
        kw["image_embeds"] = jax.random.normal(
            KEY, (B, 8, cfg.vision_dim), jnp.float32)
    if cfg.family == "audio":
        kw["audio_embeds"] = jax.random.normal(
            KEY, (B, 16, cfg.d_model), jnp.float32)
    return kw


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_forward_and_train_step(arch):
    """Reduced variant: one forward + one train step, output shapes +
    no NaNs (assignment requirement)."""
    cfg = reduced_config(arch)
    assert cfg.num_layers <= 8 and cfg.d_model <= 512
    if cfg.family == "moe":
        assert cfg.num_experts <= 4
    params = tf.init_params(KEY, cfg)
    tokens = jax.random.randint(KEY, (B, T), 0, cfg.vocab_size)
    kw = _modal_kwargs(cfg)
    logits, _, aux = tf.forward(params, cfg, tokens, **kw)
    exp_t = T + (8 if cfg.family == "vlm" else 0)
    assert logits.shape == (B, exp_t, cfg.vocab_size)
    assert not np.any(np.isnan(np.asarray(logits, np.float32)))

    # one train step
    from repro.training.optimizer import AdamWConfig, init_opt_state
    from repro.training.train import make_train_step
    batch = {"tokens": tokens, "labels": tokens, **kw}
    step = jax.jit(make_train_step(cfg, AdamWConfig()))
    p2, opt2, metrics = step(params, init_opt_state(params), batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_prefill_decode(arch):
    cfg = reduced_config(arch)
    params = tf.init_params(KEY, cfg)
    tokens = jax.random.randint(KEY, (B, T), 0, cfg.vocab_size)
    kw = _modal_kwargs(cfg)
    cache = tf.init_cache(cfg, B, 64, cross_len=16)
    last, cache = tf.prefill(params, cfg, tokens, cache,
                             jnp.zeros((B,), jnp.int32), **kw)
    assert last.shape == (B, cfg.vocab_size)
    nxt = jnp.argmax(last, -1)[:, None].astype(jnp.int32)
    pos0 = T + (8 if cfg.family == "vlm" else 0)
    lg, cache = tf.decode_step(params, cfg, nxt, cache,
                               jnp.full((B,), pos0, jnp.int32))
    assert lg.shape == (B, cfg.vocab_size)
    assert not np.any(np.isnan(np.asarray(lg, np.float32)))


EQUIV_ARCHS = ["smollm-135m", "gemma3-1b", "mamba2-1.3b", "zamba2-7b",
               "qwen3-14b", "qwen2.5-3b"]


@pytest.mark.parametrize("arch", EQUIV_ARCHS)
def test_chunked_prefill_equals_full_forward(arch):
    """Chunk-size-differentiated instances are semantically equivalent:
    4 chunks of 16 == one full causal pass (paper's hybrid architecture
    relies on this)."""
    cfg = reduced_config(arch)
    params = tf.init_params(jax.random.PRNGKey(1), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, 64), 0,
                                cfg.vocab_size)
    full_logits, _, _ = tf.forward(params, cfg, tokens)
    ref = np.asarray(full_logits[:, -1], np.float32)
    cache = tf.init_cache(cfg, B, 128)
    for c in range(4):
        start = jnp.full((B,), c * 16, jnp.int32)
        last, cache = tf.prefill(params, cfg, tokens[:, c*16:(c+1)*16],
                                 cache, start)
    got = np.asarray(last, np.float32)
    err = np.max(np.abs(got - ref)) / (np.max(np.abs(ref)) + 1e-9)
    assert err < 2e-3, err


@pytest.mark.parametrize("arch", ["arctic-480b", "granite-moe-3b-a800m"])
def test_moe_chunked_prefill_no_drop_equivalence(arch):
    """With a no-drop capacity factor MoE chunked prefill is exact; with
    dropping it may differ (documented property of dropping MoEs)."""
    cfg = reduced_config(arch)
    cfg = dataclasses.replace(cfg,
                              capacity_factor=cfg.num_experts / cfg.top_k)
    params = tf.init_params(jax.random.PRNGKey(1), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, 64), 0,
                                cfg.vocab_size)
    full_logits, _, _ = tf.forward(params, cfg, tokens)
    ref = np.asarray(full_logits[:, -1], np.float32)
    cache = tf.init_cache(cfg, B, 128)
    for c in range(4):
        start = jnp.full((B,), c * 16, jnp.int32)
        last, cache = tf.prefill(params, cfg, tokens[:, c*16:(c+1)*16],
                                 cache, start)
    err = (np.max(np.abs(np.asarray(last, np.float32) - ref))
           / (np.max(np.abs(ref)) + 1e-9))
    assert err < 2e-3, err


def test_full_prefill_scan_matches_stepwise():
    """full_prefill (the dry-run's scan-over-chunks) == manual chunk loop."""
    cfg = reduced_config("smollm-135m")
    params = tf.init_params(KEY, cfg)
    tokens = jax.random.randint(KEY, (B, 64), 0, cfg.vocab_size)
    cache1 = tf.init_cache(cfg, B, 64)
    last1, _ = tf.full_prefill(params, cfg, tokens, cache1, 16)
    cache2 = tf.init_cache(cfg, B, 64)
    for c in range(4):
        last2, cache2 = tf.prefill(params, cfg, tokens[:, c*16:(c+1)*16],
                                   cache2, jnp.full((B,), c*16, jnp.int32))
    np.testing.assert_allclose(np.asarray(last1, np.float32),
                               np.asarray(last2, np.float32),
                               atol=1e-4)


def test_sliding_window_ring_buffer_never_reads_stale():
    """gemma3-style local attention with a ring cache smaller than the
    sequence: decode after long prefill must match the full forward."""
    cfg = reduced_config("gemma3-1b")      # window=32, 8 layers
    params = tf.init_params(KEY, cfg)
    S = 96                                  # 3x the window
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    full_logits, _, _ = tf.forward(params, cfg, tokens)
    ref = np.asarray(full_logits[:, -1], np.float32)
    cache = tf.init_cache(cfg, B, 128)
    for c in range(6):
        last, cache = tf.prefill(params, cfg, tokens[:, c*16:(c+1)*16],
                                 cache, jnp.full((B,), c*16, jnp.int32))
    err = (np.max(np.abs(np.asarray(last, np.float32) - ref))
           / (np.max(np.abs(ref)) + 1e-9))
    assert err < 2e-3, err


def test_param_counts_match_assignment_scale():
    expected = {"zamba2-7b": 7, "arctic-480b": 480, "qwen2.5-3b": 3,
                "qwen3-14b": 14, "llava-next-34b": 34, "gemma3-1b": 1,
                "mamba2-1.3b": 1.3, "smollm-135m": 0.135,
                "granite-moe-3b-a800m": 3}
    for arch, bn in expected.items():
        got = get_config(arch).param_count() / 1e9
        assert 0.55 * bn <= got <= 1.65 * bn, (arch, got, bn)


def test_exact_assigned_hyperparams():
    c = get_config("qwen3-14b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
            c.d_ff, c.vocab_size) == (40, 5120, 40, 8, 17408, 151936)
    assert c.qk_norm
    c = get_config("arctic-480b")
    assert (c.num_experts, c.top_k, c.dense_residual) == (128, 2, True)
    c = get_config("gemma3-1b")
    assert (c.local_global_ratio, c.vocab_size) == (5, 262144)
    c = get_config("mamba2-1.3b")
    assert (c.ssm_state, c.d_ff, c.num_heads) == (128, 0, 0)
    c = get_config("granite-moe-3b-a800m")
    assert (c.num_experts, c.top_k, c.vocab_size) == (40, 8, 49155)
