"""Offline slider search (paper §3.1) + early rejection (paper §3.4)."""
import pytest

from repro.core.autotune import search_sliders
from repro.core.latency import SLO, attainment
from repro.core.policies import Sliders
from repro.engine.request import State
from repro.sim.simulator import ServingConfig, run_sim
from repro.sim.workload import SHAREGPT


def test_offline_search_returns_valid_sliders():
    slo = SLO(ttft=1.5, tpot=0.030)
    res = search_sliders(
        "qwen2.5-14b", slo, SHAREGPT, qps_grid=[60, 100],
        n_requests=60,
        ratios=[(2, 2)], sp_grid=[1024], sd_grid=[128, 256, 1024])
    assert res.sliders.n_p + res.sliders.n_d == 4
    assert res.sliders.s_d <= res.sliders.s_p
    assert res.goodput >= 0
    assert len(res.trials) == 3
    # the searched config must be at least as good as every trial
    assert all(res.goodput >= g for _, g in res.trials)


def test_early_rejection_drops_infeasible_requests():
    # impossible TTFT -> every request rejected at the proxy
    slo = SLO(ttft=1e-6, tpot=10.0)
    sc = ServingConfig(policy="taichi", sliders=Sliders(2, 2, 1024, 256))
    st = run_sim(sc, slo, SHAREGPT, qps=10.0, n_requests=30,
                 taichi_flags={"early_rejection": True})
    rejected = [r for r in st.reqs if r.state == State.REJECTED]
    assert rejected, "expected early rejections under impossible TTFT"
    # rejected requests count as SLO violations
    assert st.slo_attainment < 1.0


def test_no_rejection_by_default():
    slo = SLO(ttft=1e-6, tpot=10.0)
    sc = ServingConfig(policy="taichi", sliders=Sliders(2, 2, 1024, 256))
    st = run_sim(sc, slo, SHAREGPT, qps=10.0, n_requests=30)
    assert all(r.state != State.REJECTED for r in st.reqs)
    assert all(r.state == State.FINISHED for r in st.reqs)
