"""Network front-end, fast tier: protocol parsing and SSE framing, the
byte tokenizer's incremental UTF-8 handling, admission-queue priority /
fairness / displacement / shedding, the serving loop's queue-not-reject
burst behaviour and graceful cancellation, the token pipeline (inline
AND real worker processes), the controller's admission-shed actuator,
and the full HTTP server on a loopback socket over a simulated
cluster."""
import json
import os
import socket
import struct
import threading
import time

import pytest

from repro.core.instance import HEALTH_DEAD
from repro.core.latency import SLO
from repro.engine.engine import SimExecutor
from repro.core.policies import Sliders
from repro.engine.request import Request, State
from repro.frontend import (AdmissionConfig, AdmissionQueue, ByteTokenizer,
                            FrontendConfig, FrontendServer,
                            IncrementalDetokenizer, TokenPipeline, protocol)
from repro.serving import (ControllerConfig, ServingLoop, SliderController,
                           WallClock)
from repro.sim.simulator import ServingConfig, build_cluster

BAL = SLO(ttft=1.5, tpot=0.030)
LOOSE = SLO(ttft=10.0, tpot=1.0)


def _mk_loop(slo=BAL, admission=None, sliders=Sliders(1, 1, 512, 256),
             blocks=4096, executor_factory=None, **kw):
    sc = ServingConfig(sliders=sliders, hbm_blocks=blocks)
    cluster = build_cluster(sc, slo, executor_factory=executor_factory)
    return ServingLoop(cluster, slo, admission=admission, **kw)


# ---------------------------------------------------------------------------
# protocol: request parsing
# ---------------------------------------------------------------------------

def test_parse_completion_and_chat():
    api = protocol.parse_request(
        protocol.COMPLETIONS,
        json.dumps({"model": "m", "prompt": "hi", "max_tokens": 7,
                    "stream": True}).encode())
    assert (api.kind, api.model, api.prompt_text) == ("completion", "m", "hi")
    assert api.max_tokens == 7 and api.stream
    assert api.priority == protocol.DEFAULT_PRIORITY

    api = protocol.parse_request(
        protocol.CHAT_COMPLETIONS,
        json.dumps({"messages": [
            {"role": "system", "content": "be brief"},
            {"role": "user", "content": "hi"}]}).encode())
    assert api.kind == "chat" and not api.stream
    assert api.prompt_text == "system: be brief\nuser: hi\nassistant:"


def test_parse_accepts_single_element_prompt_list():
    api = protocol.parse_request(
        protocol.COMPLETIONS, json.dumps({"prompt": ["one"]}).encode())
    assert api.prompt_text == "one"


@pytest.mark.parametrize("path,body", [
    (protocol.COMPLETIONS, b"{not json"),
    (protocol.COMPLETIONS, b"[1,2]"),
    (protocol.COMPLETIONS, b'{"prompt": "x", "n": 2}'),
    (protocol.COMPLETIONS, b'{"prompt": "x", "max_tokens": 0}'),
    (protocol.COMPLETIONS, b'{"prompt": ""}'),
    (protocol.COMPLETIONS, b'{"prompt": ["a", "b"]}'),
    (protocol.CHAT_COMPLETIONS, b'{"messages": []}'),
    (protocol.CHAT_COMPLETIONS, b'{"messages": [{"role": "user"}]}'),
    ("/v1/embeddings", b"{}"),
])
def test_parse_rejects_malformed(path, body):
    with pytest.raises(protocol.ProtocolError) as ei:
        protocol.parse_request(path, body)
    assert ei.value.status in (400, 404)
    err = json.loads(ei.value.body())
    assert err["error"]["message"]


def test_priority_from_body_and_header():
    api = protocol.parse_request(
        protocol.COMPLETIONS,
        json.dumps({"prompt": "x", "priority": "interactive"}).encode(),
        {"x-priority": "batch"})
    assert api.priority == "interactive"      # body wins
    api = protocol.parse_request(
        protocol.COMPLETIONS, json.dumps({"prompt": "x"}).encode(),
        {"x-priority": "batch"})
    assert api.priority == "batch"


# ---------------------------------------------------------------------------
# protocol: SSE framing + response bodies
# ---------------------------------------------------------------------------

def test_sse_framing():
    frame = protocol.stream_chunk("completion", "cmpl-1", "m", 123, "ab")
    assert frame.startswith(b"data: ") and frame.endswith(b"\n\n")
    obj = json.loads(frame[len(b"data: "):])
    assert obj["choices"][0]["text"] == "ab"
    assert obj["choices"][0]["finish_reason"] is None

    fin = protocol.stream_chunk("chat", "c-1", "m", 123, "", "length")
    obj = json.loads(fin[len(b"data: "):])
    assert obj["object"] == "chat.completion.chunk"
    assert obj["choices"][0]["delta"] == {}
    assert obj["choices"][0]["finish_reason"] == "length"
    assert protocol.SSE_DONE == b"data: [DONE]\n\n"


def test_final_response_usage_math():
    body = protocol.final_response("chat", "c-1", "m", 1, "out",
                                   "length", 11, 5)
    obj = json.loads(body)
    assert obj["choices"][0]["message"]["content"] == "out"
    assert obj["usage"] == {"prompt_tokens": 11, "completion_tokens": 5,
                            "total_tokens": 16}


# ---------------------------------------------------------------------------
# byte tokenizer + incremental detokenizer
# ---------------------------------------------------------------------------

def test_byte_tokenizer_roundtrip():
    for text in ("hello", "héllo wörld", "日本語テスト", "mixed: é日x"):
        ids = ByteTokenizer.encode(text)
        assert all(0 <= i < 256 for i in ids)
        assert ByteTokenizer.decode(ids) == text


def test_incremental_detok_handles_split_utf8():
    text = "a⚡é日"
    ids = ByteTokenizer.encode(text)
    detok = IncrementalDetokenizer()
    pieces = [detok.feed(i) for i in ids]     # one byte at a time
    # multi-byte sequences must be held, not emitted as replacement chars
    assert "".join(pieces) + detok.flush() == text
    assert "�" not in "".join(pieces)


def test_detok_out_of_range_id_renders_marker():
    detok = IncrementalDetokenizer()
    out = detok.feed(300)
    assert "⟨300⟩" in out


# ---------------------------------------------------------------------------
# admission queue
# ---------------------------------------------------------------------------

def _req():
    return Request(prompt_len=8, max_new_tokens=4)


def test_admission_priority_order_and_fifo():
    q = AdmissionQueue(AdmissionConfig(max_depth=16))
    batch = [_req() for _ in range(2)]
    inter = [_req() for _ in range(2)]
    for r in batch:
        q.push(r, "batch", 0.0)
    for r in inter:
        q.push(r, "interactive", 0.0)
    popped = [q.pop().req for _ in range(4)]
    assert popped == inter + batch            # strict priority, then FIFO


def test_admission_stride_fairness_within_rank():
    cfg = AdmissionConfig(max_depth=64, classes={
        "heavy": (0, 3.0), "light": (0, 1.0)}, default_class="heavy")
    q = AdmissionQueue(cfg)
    for _ in range(12):
        q.push(_req(), "heavy", 0.0)
        q.push(_req(), "light", 0.0)
    order = [q.pop().cls for _ in range(8)]
    # 3:1 weighted service, not starvation and not alternation
    assert order.count("heavy") == 6 and order.count("light") == 2


def test_admission_displacement_prefers_low_priority_newest():
    q = AdmissionQueue(AdmissionConfig(max_depth=2))
    q.push(_req(), "batch", 0.0)
    newest_batch = _req()
    q.push(newest_batch, "batch", 1.0)
    ok, displaced = q.push(_req(), "interactive", 2.0)
    assert ok and [e.req for e in displaced] == [newest_batch]
    # a full queue refuses an arrival no better than anything queued
    ok, displaced = q.push(_req(), "batch", 3.0)
    assert not ok and not displaced
    assert q.displaced == 1


def test_admission_shed_drops_back_of_lowest_classes():
    q = AdmissionQueue(AdmissionConfig(max_depth=32))
    inter = [_req() for _ in range(2)]
    batch = [_req() for _ in range(4)]
    for r in inter:
        q.push(r, "interactive", 0.0)
    for i, r in enumerate(batch):
        q.push(r, "batch", float(i))
    out = q.shed(0.5)                         # 3 of 6 queued
    assert len(out) == 3
    assert all(e.cls == "batch" for e in out)
    assert out[0].req is batch[-1]            # newest first
    assert q.shed_count == 3 and len(q) == 3


def test_admission_drain_and_gauges():
    q = AdmissionQueue(AdmissionConfig(max_depth=8))
    for i in range(3):
        q.push(_req(), "standard", float(i))
    g = q.gauges(5.0)
    assert g["depth"] == 3 and g["oldest_wait_s"] == 5.0
    assert g["depth_by_class"]["standard"] == 3
    assert "budget_deferrals_total" not in g   # budgets off: no gauges
    assert len(q.drain()) == 3 and len(q) == 0


def test_admission_token_budget_gates_class():
    # each request charges prompt(8) + max_new(4) = 12 tokens; a
    # 10-token/s budget admits one per window (the gate checks before
    # charging — one overshoot, then the class is ineligible)
    q = AdmissionQueue(AdmissionConfig(
        max_depth=16, token_budgets={"batch": 10.0}, budget_window=1.0))
    for _ in range(3):
        q.push(_req(), "batch", 0.0)
    assert q.pop(0.0) is not None              # 12 charged (overshoot)
    assert q.pop(0.1) is None                  # 12 >= 10: over budget
    assert q.budget_deferrals == 1
    assert len(q) == 2                         # deferred, not dropped
    assert q.pop(1.0) is not None              # window rolled: admits
    g = q.gauges(1.0)
    assert g["budget_deferrals_total"] == 1
    assert g["window_tokens_by_class"]["batch"] == 12.0  # fresh window


def test_admission_budget_skips_to_unbudgeted_class():
    # over-budget batch must not block standard (unlimited) — the gate
    # restricts eligibility, it does not stall the whole queue
    q = AdmissionQueue(AdmissionConfig(
        max_depth=16, token_budgets={"batch": 1.0}, budget_window=1.0))
    q.push(_req(), "batch", 0.0)
    q.push(_req(), "batch", 0.0)
    q.push(_req(), "standard", 0.0)
    assert q.pop(0.0).cls == "standard"        # higher rank serves first
    assert q.pop(0.0).cls == "batch"           # first charge always fits
    assert q.pop(0.0) is None                  # batch over budget: deferred
    # without a timestamp the gate is bypassed (legacy no-clock callers)
    assert q.pop().cls == "batch"


def test_admission_retry_after_tracks_drain_rate():
    q = AdmissionQueue(AdmissionConfig(max_depth=64, max_inflight=4))
    for i in range(20):
        q.push(_req(), "standard", 0.0)
    # no release history yet: falls back to cycle counting
    assert q.retry_after_hint() == int(1 + 20 / 4)
    # drain 10 at 2 per second -> observed rate 2/s, 10 left -> ~5 s
    for i in range(10):
        q.pop(i * 0.5)
    assert q.retry_after_hint() == 5
    assert 1 <= q.retry_after_hint(99.0) <= 60


# ---------------------------------------------------------------------------
# serving loop + admission: bursts queue instead of rejecting
# ---------------------------------------------------------------------------

def test_burst_queues_not_rejects():
    loop = _mk_loop(slo=LOOSE, admission=AdmissionConfig(
        max_depth=64, max_inflight=4))
    reqs = [Request(prompt_len=64, max_new_tokens=8, hidden_output_len=8)
            for _ in range(24)]
    handles = [loop.submit(r) for r in reqs]  # burst: all at t=0
    assert len(loop.admission) == 24 - 4      # excess queued, NOT dropped
    assert loop.shed_rejections == 0
    loop.run()
    assert all(h.done and not h.rejected and not h.cancelled
               for h in handles)
    snap = loop.snapshot()
    assert snap["admission"]["released_total"] == 24
    assert snap["queue_wait"]["releases"] > 0
    assert snap["queue_wait"]["max_s"] > 0.0


def test_admission_displacement_rejects_and_resolves():
    loop = _mk_loop(slo=LOOSE, admission=AdmissionConfig(
        max_depth=2, max_inflight=0))        # nothing ever releases
    low = [loop.submit(Request(prompt_len=8, max_new_tokens=2),
                       priority="batch") for _ in range(2)]
    hi = loop.submit(Request(prompt_len=8, max_new_tokens=2),
                     priority="interactive")
    assert low[-1].rejected and not hi.done  # newest batch displaced
    assert loop.shed_rejections == 1


def test_cancel_queued_resolves_cancelled():
    loop = _mk_loop(slo=LOOSE, admission=AdmissionConfig(
        max_depth=16, max_inflight=1))
    handles = [loop.submit(Request(prompt_len=32, max_new_tokens=4,
                                   hidden_output_len=4))
               for _ in range(5)]
    n = loop.cancel_queued()
    assert n == 4
    assert sum(h.cancelled for h in handles) == 4
    loop.run()                                # the released one finishes
    assert sum(h.done and not h.cancelled for h in handles) == 1
    assert loop.snapshot()["cancelled_total"] == 4


def test_submit_receipt_preserves_arrival():
    loop = _mk_loop(slo=LOOSE)
    loop.submit(Request(prompt_len=32, max_new_tokens=8,
                        hidden_output_len=8))
    loop.run()
    now = loop.cluster.now
    assert now > 0.05
    late = Request(prompt_len=32, max_new_tokens=4, hidden_output_len=4)
    loop.submit(late, receipt=0.01)           # received long before now
    assert late.arrival == 0.01               # receipt is arrival truth
    loop.run()
    assert late.state == State.FINISHED
    # TTFT includes the time the loop ran behind, it is not clamped away
    assert late.ttft() >= now - 0.01


# ---------------------------------------------------------------------------
# token pipeline (inline mode)
# ---------------------------------------------------------------------------

def _collect_sink(frames):
    def sink(rid, payload, done, t_event, pid):
        frames.append((payload, done, pid))
    return sink


def test_pipeline_inline_streaming():
    frames = []
    with TokenPipeline(n_workers=0) as pipe:
        ids = pipe.tokenize("hé!").result(timeout=5)
        assert ids == ByteTokenizer.encode("hé!")
        pipe.open_stream(7, "completion", "cmpl-7", "m", 1, True,
                         _collect_sink(frames))
        for i in ids:
            pipe.push_tokens(7, [i], 0.0)
        pipe.finish(7, "length", len(ids), 0.0)
    done_flags = [d for _, d, _ in frames]
    assert done_flags[-1] and not any(done_flags[:-1])
    text = ""
    for payload, _, _ in frames:
        for line in payload.split(b"\n\n"):
            if line.startswith(b"data: ") and line != b"data: [DONE]":
                obj = json.loads(line[len(b"data: "):])
                text += obj["choices"][0]["text"]
    assert text == "hé!"
    assert frames[-1][0].endswith(protocol.SSE_DONE)


def test_pipeline_inline_nonstream_accumulates():
    frames = []
    with TokenPipeline(n_workers=0) as pipe:
        ids = ByteTokenizer.encode("okay")
        pipe.open_stream(9, "chat", "c-9", "m", 1, False,
                         _collect_sink(frames))
        pipe.push_tokens(9, ids[:2], 0.0)
        pipe.push_tokens(9, ids[2:], 0.0)
        pipe.finish(9, "length", 4, 0.0)
    assert len(frames) == 1 and frames[0][1]  # single done payload
    obj = json.loads(frames[0][0])
    assert obj["choices"][0]["message"]["content"] == "okay"
    assert obj["usage"]["completion_tokens"] == 4


# ---------------------------------------------------------------------------
# token pipeline (real worker processes)
# ---------------------------------------------------------------------------

def test_pipeline_work_happens_in_worker_processes():
    frames = []
    got = threading.Event()

    def sink(rid, payload, done, t_event, pid):
        frames.append((payload, done, pid))
        if done:
            got.set()

    with TokenPipeline(n_workers=1) as pipe:
        ids = pipe.tokenize("worker").result(timeout=30)
        assert ids == ByteTokenizer.encode("worker")
        pipe.open_stream(3, "completion", "cmpl-3", "m", 1, True, sink)
        pipe.push_tokens(3, ids, time.monotonic())
        pipe.finish(3, "length", len(ids), time.monotonic())
        assert got.wait(timeout=30)
    # detokenization + formatting ran OUT of this process
    assert frames and all(pid != os.getpid() for _, _, pid in frames)


# ---------------------------------------------------------------------------
# controller: admission shed actuator
# ---------------------------------------------------------------------------

def _feed_bad_both(tw, now):
    for k in range(6):
        r = Request(prompt_len=10, max_new_tokens=4, arrival=now - 0.5)
        r.record_token(now + 10.0)            # ttft hopeless
        tw.on_token(r, now)
    for k in range(6):
        r = Request(prompt_len=10, max_new_tokens=3, arrival=0.0)
        gap = BAL.tpot * 3.0
        r.record_token(now - 2 * gap)
        r.record_token(now - gap)
        r.record_token(now)
        tw.on_finish(r, now)                  # tpot hopeless


def test_controller_sheds_admission_when_both_starved():
    ctl = SliderController(ControllerConfig(epoch=1.0, cooldown=0,
                                            shed_fraction=0.5))
    loop = _mk_loop(admission=AdmissionConfig(max_depth=32,
                                              max_inflight=0),
                    controller=ctl)
    handles = [loop.submit(Request(prompt_len=8, max_new_tokens=2),
                           priority="batch") for _ in range(8)]
    _feed_bad_both(loop.telemetry, 1.0)
    ctl.on_epoch(1.0)
    assert ctl.moves and ctl.moves[-1]["kind"] == "shed"
    assert ctl.moves[-1]["count"] == 4        # half the queue
    assert sum(h.rejected for h in handles) == 4
    assert loop.admission.shed_count == 4


def test_controller_queue_age_counts_as_ttft_starvation():
    ctl = SliderController(ControllerConfig(epoch=1.0, cooldown=0,
                                            queue_guard=0.5))
    loop = _mk_loop(admission=AdmissionConfig(max_depth=32,
                                              max_inflight=0),
                    controller=ctl)
    for _ in range(4):
        loop.submit(Request(prompt_len=8, max_new_tokens=2))
    # only-good TPOT evidence, nothing TTFT-bad in the window — but the
    # queue's oldest entry has burned > half the TTFT SLO
    for k in range(6):
        r = Request(prompt_len=10, max_new_tokens=3, arrival=0.0)
        gap = BAL.tpot * 0.5
        r.record_token(2.0 - 2 * gap)
        r.record_token(2.0 - gap)
        r.record_token(2.0)
        loop.telemetry.on_finish(r, 2.0)
    ctl.on_epoch(2.0)                         # oldest_wait=2.0 > 0.75
    assert any(m["kind"] in ("chunk", "flip") for m in ctl.moves), \
        "queue pressure must drive a prefill-capacity move"


# ---------------------------------------------------------------------------
# HTTP server end-to-end over the simulated cluster (loopback socket)
# ---------------------------------------------------------------------------

@pytest.fixture()
def server():
    loop = _mk_loop(slo=LOOSE, admission=AdmissionConfig(
        max_depth=64, max_inflight=2))
    srv = FrontendServer(loop, FrontendConfig(port=0, tok_workers=0))
    th = threading.Thread(target=srv.run, daemon=True)
    th.start()
    assert srv.started.wait(timeout=15)
    yield srv
    srv.shutdown()
    th.join(timeout=15)
    assert not th.is_alive()


def _http(port, method, path, body=b"", headers=""):
    s = socket.create_connection(("127.0.0.1", port), timeout=20)
    s.sendall((f"{method} {path} HTTP/1.1\r\nHost: t\r\n{headers}"
               f"Content-Length: {len(body)}\r\nConnection: close\r\n"
               "\r\n").encode() + body)
    data = b""
    while chunk := s.recv(65536):
        data += chunk
    s.close()
    head, _, payload = data.partition(b"\r\n\r\n")
    status = int(head.split(b" ", 2)[1])
    return status, head, payload


def _sse_events(payload):
    """De-chunk a Transfer-Encoding: chunked body, split SSE events."""
    body, rest = b"", payload
    while rest:
        size, _, rest = rest.partition(b"\r\n")
        n = int(size, 16)
        if n == 0:
            break
        body += rest[:n]
        rest = rest[n + 2:]
    return [e for e in body.split(b"\n\n") if e]


def test_http_completion_nonstream(server):
    status, _, payload = _http(
        server.port, "POST", "/v1/completions",
        json.dumps({"prompt": "hello", "max_tokens": 4}).encode())
    assert status == 200
    obj = json.loads(payload)
    assert obj["object"] == "text_completion"
    assert obj["choices"][0]["finish_reason"] == "length"
    assert obj["usage"]["prompt_tokens"] == 5


def test_http_chat_stream_sse(server):
    status, head, payload = _http(
        server.port, "POST", "/v1/chat/completions",
        json.dumps({"messages": [{"role": "user", "content": "hi"}],
                    "max_tokens": 4, "stream": True}).encode())
    assert status == 200
    assert b"text/event-stream" in head
    events = _sse_events(payload)
    assert events[-1] == b"data: [DONE]"
    fin = json.loads(events[-2][len(b"data: "):])
    assert fin["object"] == "chat.completion.chunk"
    assert fin["choices"][0]["finish_reason"] == "length"


def test_http_error_routes(server):
    status, _, payload = _http(server.port, "GET", "/v1/completions")
    assert status == 405
    status, _, payload = _http(server.port, "POST", "/v1/completions",
                               b"{broken")
    assert status == 400
    assert b"JSON" in payload
    status, _, _ = _http(server.port, "POST", "/v1/embeddings", b"{}")
    assert status == 404
    status, _, _ = _http(server.port, "PUT", "/healthz")
    assert status == 404


def test_http_healthz_and_metrics(server):
    status, _, payload = _http(server.port, "GET", "/healthz")
    assert status == 200 and json.loads(payload)["status"] == "ok"
    # push one request through so telemetry has content
    _http(server.port, "POST", "/v1/completions",
          json.dumps({"prompt": "m", "max_tokens": 2}).encode())
    status, _, payload = _http(server.port, "GET", "/metrics")
    assert status == 200
    snap = json.loads(payload)
    assert snap["finished_total"] >= 1
    assert "admission" in snap and snap["admission"]["released_total"] >= 1


def test_http_burst_queues_and_reports_wait(server):
    results = []

    def one(i):
        results.append(_http(
            server.port, "POST", "/v1/completions",
            json.dumps({"prompt": f"burst {i}",
                        "max_tokens": 2}).encode())[0])

    threads = [threading.Thread(target=one, args=(i,)) for i in range(12)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=30)
    # max_inflight=2: the burst queues and drains — every request served
    assert results == [200] * 12
    _, _, payload = _http(server.port, "GET", "/metrics")
    snap = json.loads(payload)
    assert snap["admission"]["enqueued_total"] >= 12
    assert snap["admission"]["displaced_total"] == 0
    assert "queue_wait" in snap


def test_http_priority_header_lands_in_admission(server):
    status, _, _ = _http(
        server.port, "POST", "/v1/completions",
        json.dumps({"prompt": "vip", "max_tokens": 2}).encode(),
        headers="x-priority: interactive\r\n")
    assert status == 200
    reqs = [r for r in server.loop.requests if r.priority is not None]
    assert any(r.priority == "interactive" for r in reqs)


# ---------------------------------------------------------------------------
# request-lifecycle hardening: finish reasons, overload headers,
# per-instance health, disconnect propagation
# ---------------------------------------------------------------------------

def test_protocol_renders_both_finish_reasons():
    for reason in ("stop", "length"):
        fin = protocol.stream_chunk("completion", "cmpl-1", "m", 1, "",
                                    reason)
        obj = json.loads(fin[len(b"data: "):])
        assert obj["choices"][0]["finish_reason"] == reason
        body = protocol.final_response("completion", "cmpl-1", "m", 1,
                                       "txt", reason, 3, 4)
        assert json.loads(body)["choices"][0]["finish_reason"] == reason


def test_eos_before_cap_finishes_stop_at_cap_finishes_length():
    loop = _mk_loop(slo=LOOSE)
    eos = Request(prompt_len=64, max_new_tokens=32, hidden_output_len=8)
    cap = Request(prompt_len=64, max_new_tokens=8, hidden_output_len=100)
    loop.submit(eos)
    loop.submit(cap)
    loop.run()
    assert eos.state == State.FINISHED and cap.state == State.FINISHED
    assert (eos.finish_reason, eos.output_len) == ("stop", 8)
    assert (cap.finish_reason, cap.output_len) == ("length", 8)


def test_http_reject_carries_retry_after():
    # a zero-depth queue refuses every arrival: the client must get a
    # 503 with a Retry-After hint, not a bare error
    loop = _mk_loop(slo=LOOSE, admission=AdmissionConfig(
        max_depth=0, max_inflight=0))
    srv = FrontendServer(loop, FrontendConfig(port=0, tok_workers=0))
    th = threading.Thread(target=srv.run, daemon=True)
    th.start()
    assert srv.started.wait(timeout=15)
    try:
        status, head, payload = _http(
            srv.port, "POST", "/v1/completions",
            json.dumps({"prompt": "nope", "max_tokens": 2}).encode())
        assert status == 503
        assert b"Retry-After:" in head
        assert b"overloaded" in payload
    finally:
        srv.shutdown()
        th.join(timeout=15)


def test_http_healthz_reports_per_instance_health(server):
    status, _, payload = _http(server.port, "GET", "/healthz")
    obj = json.loads(payload)
    assert status == 200 and obj["status"] == "ok"
    insts = obj["instances"]
    assert insts and all(i["health"] == "ok" for i in insts)
    assert {"iid", "itype", "health", "draining"} <= set(insts[0])
    # every instance down: healthz flips to 503 and names the cause
    for inst in server.loop.cluster.instances:
        inst.health = HEALTH_DEAD
    status, _, payload = _http(server.port, "GET", "/healthz")
    obj = json.loads(payload)
    assert status == 503 and obj["status"] == "no healthy instances"
    assert all(i["health"] == "dead" for i in obj["instances"])


class _TokenEchoExecutor(SimExecutor):
    """Sim oracle that also emits one byte token per decode step, so the
    SSE path streams real mid-generation frames (the live-engine shape)
    without any accelerator work."""

    def step_async(self, plan):
        for req in plan.decode_reqs:
            req.output_tokens.append(65)      # "A"
        return super().step_async(plan)


def test_sse_disconnect_aborts_engine_request():
    # paced wall-clock loop: 512 tokens take seconds of real time, so
    # the client can vanish mid-stream and the engine must notice, stop
    # generating into the dead socket, and free the KV blocks
    loop = _mk_loop(slo=LOOSE, clock=WallClock(), pace=True,
                    executor_factory=_TokenEchoExecutor,
                    admission=AdmissionConfig(max_depth=16, max_inflight=4))
    srv = FrontendServer(loop, FrontendConfig(port=0, tok_workers=0))
    th = threading.Thread(target=srv.run, daemon=True)
    th.start()
    assert srv.started.wait(timeout=15)
    try:
        body = json.dumps({"prompt": "never read", "max_tokens": 512,
                           "stream": True}).encode()
        s = socket.create_connection(("127.0.0.1", srv.port), timeout=20)
        s.sendall((f"POST /v1/completions HTTP/1.1\r\nHost: t\r\n"
                   f"Content-Length: {len(body)}\r\n\r\n").encode() + body)
        assert s.recv(1)                  # stream is live
        # RST on close so the server's next frame write fails at once
        s.setsockopt(socket.SOL_SOCKET, socket.SO_LINGER,
                     struct.pack("ii", 1, 0))
        s.close()
        deadline = time.monotonic() + 20
        aborted = None
        while aborted is None and time.monotonic() < deadline:
            aborted = next((r for r in loop.requests
                            if r.state == State.CANCELLED), None)
            time.sleep(0.05)
        assert aborted is not None, "disconnect never propagated"
        assert aborted.finish_reason == "abort"
        assert aborted.output_len < 512   # generation stopped early
        deadline = time.monotonic() + 10
        while (any(i.allocator.holds(aborted.rid)
                   for i in loop.cluster.instances)
               and time.monotonic() < deadline):
            time.sleep(0.05)
        for inst in loop.cluster.instances:
            assert not inst.allocator.holds(aborted.rid), "KV leaked"
        assert loop.aborted_count >= 1
    finally:
        srv.shutdown()
        th.join(timeout=15)


def test_graceful_shutdown_cancels_queued():
    # max_inflight=0: everything stays in the admission queue, so a
    # drain must answer the waiting client with a cancellation, not
    # hang or serve it
    loop = _mk_loop(slo=LOOSE, admission=AdmissionConfig(
        max_depth=16, max_inflight=0))
    srv = FrontendServer(loop, FrontendConfig(port=0, tok_workers=0))
    th = threading.Thread(target=srv.run, daemon=True)
    th.start()
    assert srv.started.wait(timeout=15)
    out = {}

    def client():
        out["resp"] = _http(
            srv.port, "POST", "/v1/completions",
            json.dumps({"prompt": "doomed", "max_tokens": 2}).encode())

    ct = threading.Thread(target=client, daemon=True)
    ct.start()
    deadline = time.monotonic() + 10
    while not loop.admission or len(loop.admission) == 0:
        assert time.monotonic() < deadline, "request never queued"
        time.sleep(0.02)
    srv.shutdown()
    ct.join(timeout=15)
    th.join(timeout=15)
    assert not th.is_alive()
    status, _, payload = out["resp"]
    assert status == 503 and b"cancelled" in payload
    assert loop.cancelled_count == 1
