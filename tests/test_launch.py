"""Launch/dry-run plumbing tests that don't require the 512-device
process: shape applicability, probe configs, and case construction
against fake meshes (the real lowering proof lives in runs/dryrun/)."""
import jax
import pytest

from repro.configs import ASSIGNED, get_config
from repro.launch.specs import (SHAPES, applicable, build_case, probe_cfg,
                                true_periods)
from tests.test_sharding import MULTI, SINGLE


def test_applicability_matrix():
    runs = {(a, s) for a in ASSIGNED for s in SHAPES
            if applicable(a, s)[0]}
    # 10 archs x 4 shapes - 7 long_500k skips = 33
    assert len(runs) == 33
    assert ("mamba2-1.3b", "long_500k") in runs
    assert ("zamba2-7b", "long_500k") in runs
    assert ("gemma3-1b", "long_500k") in runs
    assert ("qwen3-14b", "long_500k") not in runs
    assert ("whisper-base", "long_500k") not in runs


@pytest.mark.parametrize("arch", ASSIGNED)
def test_probe_cfg_preserves_pattern(arch):
    cfg = get_config(arch)
    p1 = probe_cfg(cfg, 1)
    p2 = probe_cfg(cfg, 2)
    assert p1.scan_unroll and p2.scan_unroll
    # probe has exactly d periods of the same first-segment pattern
    assert p1.segments()[0].pattern == cfg.segments()[0].pattern
    assert p1.segments()[0].n_periods == 1
    assert p2.segments()[0].n_periods == 2
    assert true_periods(cfg) >= 1


@pytest.mark.parametrize("arch", ["qwen3-14b", "mamba2-1.3b",
                                  "whisper-base", "llava-next-34b",
                                  "arctic-480b"])
@pytest.mark.parametrize("shape", ["train_4k", "decode_32k"])
def test_build_case_structure(arch, shape):
    case = build_case(arch, shape, SINGLE)
    # args and in_specs must be congruent pytrees
    a = jax.tree.structure(case.args,
                           is_leaf=lambda x: hasattr(x, "shape"))
    assert case.kind == SHAPES[shape]["kind"]
    assert len(case.args) == len(case.in_specs)
    if shape == "train_4k":
        params_abs, opt_abs, batch_abs = case.args
        assert batch_abs["tokens"].shape[0] == 256
        assert batch_abs["tokens"].shape[1] <= 4096
    else:
        assert case.args[2].shape == (128, 1)       # decode tokens


def test_arctic_uses_fsdp():
    case = build_case("arctic-480b", "train_4k", SINGLE)
    assert case.note == "fsdp"
    case = build_case("smollm-135m", "train_4k", SINGLE)
    assert case.note == ""


def test_multipod_batch_axes():
    from jax.sharding import PartitionSpec as P
    case = build_case("qwen3-14b", "train_4k", MULTI)
    bspec = case.in_specs[2]["tokens"]
    assert bspec == P(("pod", "data"), None)
    # long_500k batch=1 must not shard batch
    case = build_case("gemma3-1b", "long_500k", MULTI)
    assert case.in_specs[2] == P(None, None)
