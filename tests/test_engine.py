"""Real-engine tests: generation fidelity across chunked prefill, mixed
batching, and flowing-decode migration (bit-exact vs cache-free gold)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.core.estimator import CostModel
from repro.core.hw import InstanceSpec
from repro.core.instance import D_HEAVY, P_HEAVY, Instance
from repro.engine.engine import JaxExecutor
from repro.engine.request import Request
from repro.models import transformer as tf

# slow tier: full JAX model/engine execution (run with `pytest -m slow`)
pytestmark = pytest.mark.slow


@pytest.fixture(scope="module")
def setup():
    cfg = reduced_config("smollm-135m")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    cost = CostModel(cfg, InstanceSpec(tp=1))
    return cfg, params, cost


def gold_generate(cfg, params, prompt, n_out):
    toks = list(prompt)
    out = []
    for _ in range(n_out):
        logits, _, _ = tf.forward(params, cfg,
                                  jnp.asarray([toks], jnp.int32))
        nxt = int(jnp.argmax(logits[0, -1]))
        out.append(nxt)
        toks.append(nxt)
    return out


def test_engine_generation_matches_gold(setup):
    cfg, params, cost = setup
    ex = JaxExecutor(cfg, params, n_slots=4, max_seq=256)
    inst = Instance(0, D_HEAVY, 16, cost, ex, hbm_blocks=512)
    rng = np.random.default_rng(1)
    prompt = list(rng.integers(1, cfg.vocab_size, size=20))
    req = Request(prompt_len=20, max_new_tokens=6, hidden_output_len=6,
                  prompt_tokens=list(prompt))
    inst.enqueue_prefill(req)
    now = 0.0
    while not req.done():
        dur, _, _ = inst.run_iteration(now)
        now += dur
        if req.prefill_remaining == 0 and req.rid not in inst.decoding \
                and not req.done():
            inst.admit_decode(req)
    assert req.output_tokens == gold_generate(cfg, params, prompt, 6)


def test_migration_preserves_generation(setup):
    cfg, params, cost = setup
    exA = JaxExecutor(cfg, params, n_slots=4, max_seq=256)
    exB = JaxExecutor(cfg, params, n_slots=4, max_seq=256)
    iA = Instance(0, D_HEAVY, 16, cost, exA, hbm_blocks=512)
    iB = Instance(1, P_HEAVY, 16, cost, exB, hbm_blocks=512)
    rng = np.random.default_rng(2)
    prompt = list(rng.integers(1, cfg.vocab_size, size=24))
    req = Request(prompt_len=24, max_new_tokens=8, hidden_output_len=8,
                  prompt_tokens=list(prompt))
    iA.enqueue_prefill(req)
    now = 0.0
    while req.prefill_remaining > 0:
        dur, _, _ = iA.run_iteration(now)
        now += dur
    iA.admit_decode(req)
    for _ in range(3):
        dur, _, _ = iA.run_iteration(now)
        now += dur
    state = iA.eject(req)
    iB.inject(req, state)
    while not req.done():
        dur, _, _ = iB.run_iteration(now)
        now += dur
    assert req.output_tokens == gold_generate(cfg, params, prompt, 8), \
        "migration must not change greedy generation"


def test_concurrent_requests_isolated(setup):
    """Two interleaved requests in one engine produce the same tokens as
    each alone (slot isolation + masking)."""
    cfg, params, cost = setup
    ex = JaxExecutor(cfg, params, n_slots=4, max_seq=256)
    inst = Instance(0, D_HEAVY, 24, cost, ex, hbm_blocks=512)
    rng = np.random.default_rng(3)
    prompts = [list(rng.integers(1, cfg.vocab_size, size=n))
               for n in (12, 17)]
    reqs = [Request(prompt_len=len(p), max_new_tokens=5,
                    hidden_output_len=5, prompt_tokens=list(p))
            for p in prompts]
    for r in reqs:
        inst.enqueue_prefill(r)
    now, guard = 0.0, 0
    while not all(r.done() for r in reqs) and guard < 100:
        dur, done, _ = inst.run_iteration(now)
        now += dur
        guard += 1
        for r in done:
            inst.admit_decode(r)
    for r, p in zip(reqs, prompts):
        assert r.output_tokens == gold_generate(cfg, params, p, 5), r.rid


def test_slot_reuse_no_state_leak(setup):
    cfg, params, cost = setup
    ex = JaxExecutor(cfg, params, n_slots=1, max_seq=256)
    inst = Instance(0, D_HEAVY, 32, cost, ex, hbm_blocks=512)
    rng = np.random.default_rng(4)
    outs = []
    prompt = list(rng.integers(1, cfg.vocab_size, size=16))
    for _ in range(2):        # run the SAME request twice through slot 0
        req = Request(prompt_len=16, max_new_tokens=4, hidden_output_len=4,
                      prompt_tokens=list(prompt))
        inst.enqueue_prefill(req)
        now = 0.0
        while not req.done():
            dur, done, _ = inst.run_iteration(now)
            now += dur
            for r in done:
                inst.admit_decode(r)
        outs.append(req.output_tokens)
    assert outs[0] == outs[1], "slot reuse leaked state between requests"
