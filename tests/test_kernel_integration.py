"""Kernel-integration parity: the Pallas execution path (interpret mode
on CPU) must reproduce the jnp path's generation exactly through the full
model — prefill chunks, decode, and SSD mixers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.models import attention
from repro.models import transformer as tf

# slow tier: full JAX model/engine execution (run with `pytest -m slow`)
pytestmark = pytest.mark.slow


@pytest.fixture(autouse=True)
def _reset_kernels():
    yield
    attention.use_kernels(False)


@pytest.mark.parametrize("arch", ["smollm-135m", "qwen3-14b",
                                  "mamba2-1.3b", "zamba2-7b"])
def test_kernel_path_matches_jnp(arch):
    cfg = reduced_config(arch)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                cfg.vocab_size)

    def run():
        cache = tf.init_cache(cfg, 2, 64)
        outs = []
        for c in range(2):
            last, cache = tf.prefill(params, cfg, tokens[:, c*16:(c+1)*16],
                                     cache,
                                     jnp.full((2,), c*16, jnp.int32))
            outs.append(np.asarray(last, np.float32))
        lg, cache = tf.decode_step(
            params, cfg, jnp.argmax(last, -1)[:, None].astype(jnp.int32),
            cache, jnp.full((2,), 32, jnp.int32))
        outs.append(np.asarray(lg, np.float32))
        return outs

    attention.use_kernels(False)
    ref = run()
    attention.use_kernels(True)
    got = run()
    for a, b in zip(ref, got):
        scale = np.max(np.abs(a)) + 1e-9
        np.testing.assert_allclose(b / scale, a / scale, atol=2e-3)


def test_kernel_path_greedy_tokens_identical():
    cfg = reduced_config("smollm-135m")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    prompt = list(np.random.default_rng(0).integers(1, cfg.vocab_size, 16))

    def gen():
        cache = tf.init_cache(cfg, 1, 64)
        last, cache = tf.prefill(params, cfg,
                                 jnp.asarray([prompt], jnp.int32), cache,
                                 jnp.zeros((1,), jnp.int32))
        toks = [int(jnp.argmax(last[0]))]
        for i in range(5):
            lg, cache = tf.decode_step(
                params, cfg, jnp.asarray([[toks[-1]]], jnp.int32), cache,
                jnp.full((1,), 16 + i, jnp.int32))
            toks.append(int(jnp.argmax(lg[0])))
        return toks

    attention.use_kernels(False)
    ref = gen()
    attention.use_kernels(True)
    got = gen()
    assert got == ref
