"""Property tests for the ref-counted CoW SharedBlockAllocator, in the
style of tests/test_allocator.py: refcounts never negative, free +
cached + used == total under interleaved share/fork/free, and eviction
never drops a block with refcount > 0.

The invariant machine is a plain function over an op list so the same
logic runs as a seeded-random smoke test when hypothesis is missing."""
import random

import pytest

from repro.cache.shared_allocator import SharedBlockAllocator
from repro.engine.kvcache import OutOfBlocks


def run_ops(ops, num_blocks, block_size):
    a = SharedBlockAllocator(num_blocks, block_size)
    shadow = {}                                   # rid -> [bids]
    for op, rid, tokens in ops:
        if op == "alloc":
            if rid in shadow:
                continue
            # share the longest available prefix of some other request's
            # REGISTERED blocks (only cached/held registered blocks are
            # shareable)
            shared = []
            if shadow and rid % 2:
                donor = sorted(shadow)[rid % len(shadow)]
                shared = [b for b in shadow[donor]
                          if a.is_registered(b)][:a.blocks_for(tokens)]
            if a.can_allocate(tokens, shared):
                a.allocate(rid, tokens, shared=shared)
                shadow[rid] = a.owned(rid)
                assert shadow[rid][:len(shared)] == shared
            else:
                with pytest.raises(OutOfBlocks):
                    a.allocate(rid, tokens, shared=shared)
        elif op == "extend":
            if rid in shadow and a.can_extend(rid, tokens):
                a.extend(rid, tokens)
                shadow[rid] = a.owned(rid)
        elif op == "fork":
            if rid in shadow and shadow[rid]:
                idx = tokens % len(shadow[rid])
                old = shadow[rid][idx]
                was_shared = a.refcount(old) > 1
                new = a.fork(rid, idx)
                assert (new != old) == was_shared
                if was_shared:
                    assert a.refcount(old) >= 1   # other readers keep it
                assert a.refcount(new) >= 1
                shadow[rid] = a.owned(rid)
        elif op == "register":
            if rid in shadow and shadow[rid]:
                a.register(shadow[rid][tokens % len(shadow[rid])])
        elif op == "evacuate":
            # instance quarantine: a subset of residents is pulled off
            # and re-routed elsewhere — their blocks must all come back
            victims = sorted(shadow)[::2]
            for v in victims:
                held = shadow.pop(v)
                assert a.free(v) == len(held)
        elif op == "crash":
            # total instance loss: every resident freed, then the whole
            # cached tier wiped (prefix cache gone with the HBM)
            for v in list(shadow):
                a.free(v)
            shadow.clear()
            for bid in list(a._cached):
                a.evict(bid)
            assert a.used_blocks == 0 and a.cached_blocks == 0
        elif op == "retry":
            # transfer-retry landing: the same rid re-allocates after a
            # recompute (no shared prefix — the source's KV is gone)
            if rid not in shadow and a.can_allocate(tokens):
                a.allocate(rid, tokens)
                shadow[rid] = a.owned(rid)
        else:  # free
            held = shadow.pop(rid, [])
            assert a.free(rid) == len(held)
        # global invariants after every op
        distinct = {b for bids in shadow.values() for b in bids}
        assert a.used_blocks == len(distinct)
        assert (a.free_blocks + a.cached_blocks + a.used_blocks
                == num_blocks)
        for bids in shadow.values():
            for b in bids:
                assert a.refcount(b) >= 1, "held block lost its ref"
        assert 0 <= a.utilization() <= 1.0
    # drain: every block returns to circulation
    for rid in list(shadow):
        a.free(rid)
    assert a.used_blocks == 0
    assert a.free_blocks + a.cached_blocks == num_blocks
    # cached blocks are evictable exactly once, never while referenced
    for bid in list(a._cached):
        a.evict(bid)
    assert a.free_blocks == num_blocks


OPS = ("alloc", "extend", "fork", "register", "free")


def random_ops(rng, n):
    return [(rng.choice(OPS), rng.randrange(12), rng.randrange(1, 400))
            for _ in range(n)]


def test_interleaved_share_fork_free_seeded():
    for seed in range(25):
        rng = random.Random(seed)
        run_ops(random_ops(rng, 120), num_blocks=rng.randrange(4, 48),
                block_size=rng.randrange(1, 32))


# fault-tolerance interleavings: crashes wipe, evacuations free in
# bulk, retries re-allocate freed rids — conservation must hold through
# every mix (the allocator-level shadow of Cluster.fail_instance /
# quarantine_instance / transfer-retry recompute)
CHAOS_OPS = OPS + ("evacuate", "crash", "retry")


def random_chaos_ops(rng, n):
    # faults are rare relative to normal traffic, as in the cluster
    weights = [6, 6, 4, 4, 5, 1, 1, 2]
    return [(rng.choices(CHAOS_OPS, weights)[0], rng.randrange(12),
             rng.randrange(1, 400)) for _ in range(n)]


def test_crash_evacuate_retry_interleavings_seeded():
    for seed in range(25):
        rng = random.Random(seed)
        run_ops(random_chaos_ops(rng, 150),
                num_blocks=rng.randrange(4, 48),
                block_size=rng.randrange(1, 32))


try:
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=50, deadline=None)
    @given(st.lists(st.tuples(st.sampled_from(CHAOS_OPS),
                              st.integers(0, 11), st.integers(1, 400)),
                    max_size=120),
           st.integers(4, 48), st.integers(1, 32))
    def test_crash_evacuate_retry_interleavings_hypothesis(
            ops, num_blocks, block_size):
        run_ops(ops, num_blocks, block_size)
except ImportError:                               # pragma: no cover
    pass


def test_eviction_never_drops_referenced():
    a = SharedBlockAllocator(4, block_size=4)
    a.allocate(1, 16)                      # all 4 blocks
    bid = a.owned(1)[0]
    a.register(bid)
    with pytest.raises(ValueError):
        a.evict(bid)                       # refcount 1: refuse
    a.free(1)
    assert a.cached_blocks == 1 and a.free_blocks == 3
    # demand reclaims the cached block transparently
    a.allocate(2, 16)
    assert a.used_blocks == 4 and a.cached_blocks == 0
    assert a.eviction_count == 1


def test_shared_block_freed_only_at_refcount_zero():
    a = SharedBlockAllocator(8, block_size=4)
    a.allocate(1, 8)
    for b in a.owned(1):
        a.register(b)
    pfx = a.owned(1)
    a.allocate(2, 12, shared=pfx)          # 2 shared + 1 fresh
    assert [a.refcount(b) for b in pfx] == [2, 2]
    a.free(1)
    assert [a.refcount(b) for b in pfx] == [1, 1]   # still live via rid 2
    assert a.cached_blocks == 0
    a.free(2)
    assert a.cached_blocks == 2            # registered -> retained, not freed
    assert a.free_blocks == 6


# ---------------------------------------------------------------------------
# pick_eviction is advisory: hostile callbacks must never corrupt the pool
# ---------------------------------------------------------------------------

def _exhaust_then_cache(a, rid=1):
    """Fill the pool via one request, register everything, release —
    every block is now cached (refcount 0, retained)."""
    a.allocate(rid, a.num_blocks * a.block_size)
    for b in a.owned(rid):
        a.register(b)
    a.free(rid)


@pytest.mark.parametrize("victim_fn", [
    lambda a: None,                         # no opinion
    lambda a: 10 ** 9,                      # unknown bid
    lambda a: -1,                           # nonsense bid
    lambda a: next(iter(a._refcount), None),   # REFERENCED block
], ids=["none", "unknown", "negative", "referenced"])
def test_take_fresh_survives_hostile_pick_eviction(victim_fn):
    a = SharedBlockAllocator(4, block_size=4)
    a.pick_eviction = lambda: victim_fn(a)
    _exhaust_then_cache(a)
    # hold one block so the "referenced" callback has a live target
    held = next(iter(a._cached))
    a.pin(held)
    a.allocate(2, 8)                        # forces two demand evictions
    assert held in a._refcount              # pinned block never reclaimed
    assert a.free_blocks + a.cached_blocks + a.used_blocks == 4
    assert a.used_blocks == 3               # 2 allocated + 1 pinned
    a.unpin(held)
    a.free(2)
    assert a.free_blocks + a.cached_blocks == 4


def test_pick_eviction_repeating_stale_victim_falls_back_to_lru():
    """A callback that keeps nominating the SAME bid (stale after its
    first eviction) must not double-free it or spin."""
    a = SharedBlockAllocator(4, block_size=4)
    _exhaust_then_cache(a)
    stale = next(iter(a._cached))
    a.pick_eviction = lambda: stale
    a.allocate(2, 16)                       # 4 evictions, 3 with stale hint
    assert a.used_blocks == 4 and a.cached_blocks == 0
    assert len(set(a.owned(2))) == 4        # no bid handed out twice
    assert a.eviction_count == 4


def test_allocate_rolls_back_partial_increfs_on_stale_shared_bid():
    """A shared bid evicted between the caller's peek and allocate must
    not leak references on the bids incref'd before it."""
    a = SharedBlockAllocator(8, block_size=4)
    a.allocate(1, 12)
    for b in a.owned(1):
        a.register(b)
    pfx = a.owned(1)
    a.free(1)                               # all three cached
    a.evict(pfx[1])                         # middle of the prefix vanishes
    with pytest.raises(KeyError):
        a.allocate(2, 16, shared=pfx)
    # the first incref was rolled back: block 0 is cached again, not live
    assert a.refcount(pfx[0]) == 0
    assert a.used_blocks == 0
    assert a.free_blocks + a.cached_blocks == 8
    # and the allocator still works end to end
    a.allocate(3, 32)
    assert a.used_blocks == 8


def test_adopt_cached_lands_registered_and_evictable():
    a = SharedBlockAllocator(2, block_size=4)
    bid = a.adopt_cached()
    assert a.is_registered(bid) and a.refcount(bid) == 0
    assert a.cached_blocks == 1 and a.free_blocks == 1
    a.pin(bid)
    with pytest.raises(ValueError):
        a.evict(bid)                        # pinned: not reclaimable
    a.unpin(bid)
    a.evict(bid)
    assert a.free_blocks == 2


try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:                # seeded smoke tests above still run
    st = None

if st is not None:
    @given(ops=st.lists(
        st.tuples(st.sampled_from(OPS), st.integers(0, 15),
                  st.integers(1, 600)),
        min_size=1, max_size=200),
        num_blocks=st.integers(4, 64), block_size=st.integers(1, 32))
    @settings(max_examples=200, deadline=None)
    def test_shared_allocator_invariants(ops, num_blocks, block_size):
        run_ops(ops, num_blocks, block_size)
