"""`--engine live` on the real JAX engine: a streamed run where
in-flight requests survive a drain-and-flip role change, with greedy
token parity against an unflipped run (the acceptance bar for the
online serving runtime)."""
import pytest

jax = pytest.importorskip("jax")

from repro.core.instance import D_HEAVY, P_HEAVY          # noqa: E402
from repro.core.latency import SLO                        # noqa: E402
from repro.core.policies import Sliders                   # noqa: E402
from repro.engine.engine import JaxExecutor               # noqa: E402
from repro.engine.request import State                    # noqa: E402
from repro.launch import serve                            # noqa: E402
from repro.models import transformer as tf                # noqa: E402
from repro.serving import ServingLoop                     # noqa: E402
from repro.sim.simulator import ServingConfig, build_cluster  # noqa: E402

BAL = SLO(ttft=5.0, tpot=0.5)          # loose: this test is about tokens
N_REQ = 10


@pytest.fixture(scope="module")
def setup():
    from repro.configs import reduced_config
    cfg = reduced_config("smollm-135m")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _live_loop(cfg, params, on_token=None):
    sc = ServingConfig(model="smollm-135m", tp=1, policy="taichi",
                       sliders=Sliders(n_p=1, n_d=1, s_p=64, s_d=32),
                       hbm_blocks=512)
    factory = lambda: JaxExecutor(cfg, params, n_slots=8, max_seq=512)
    cluster = build_cluster(sc, BAL, executor_factory=factory)
    arrivals = serve.TINY.iter_requests(4.0, seed=0, max_new_tokens=24,
                                        limit=N_REQ)
    return ServingLoop(cluster, BAL, arrivals=arrivals, on_token=on_token)


@pytest.mark.slow
def test_live_streamed_run_survives_role_flip(setup):
    cfg, params = setup
    streamed = {}
    loop = _live_loop(cfg, params,
                      on_token=lambda r, t, tok:
                      streamed.setdefault(r.rid, []).append(tok))
    cluster = loop.cluster
    d_inst = next(i for i in cluster.instances if i.itype == D_HEAVY)

    # drive until the D-heavy instance holds in-flight decodes
    guard = 0
    while not d_inst.decoding and guard < 4000:
        assert loop.run(max_steps=5) > 0 or loop._arrivals is not None
        guard += 1
    inflight = list(d_inst.decoding.values())
    assert inflight, "need in-flight decodes before the flip"
    mid_tokens = {r.rid: len(r.output_tokens) for r in inflight}
    assert loop.flip_role(d_inst, P_HEAVY, 64)
    loop.run()

    # the flip landed, in-flight requests migrated and completed
    assert d_inst.itype == P_HEAVY and cluster.role_flip_count == 1
    assert cluster.drain_count >= len(inflight)
    assert all(r.state == State.FINISHED for r in loop.requests)
    assert all(r.n_migrations >= 1 for r in inflight)
    for r in inflight:
        assert len(r.output_tokens) >= mid_tokens[r.rid]

    # streaming carried the real token ids, in order
    for r in loop.requests:
        assert streamed[r.rid] == r.output_tokens
        assert len(r.output_tokens) == r.output_len

    # greedy parity: the flipped run's tokens match an undisturbed run
    base = _live_loop(cfg, params)
    base.run()
    assert len(base.requests) == len(loop.requests)
    for a, b in zip(loop.requests, base.requests):
        assert a.prompt_tokens == b.prompt_tokens
        assert a.output_tokens == b.output_tokens, (
            "drain-and-flip must not perturb greedy token streams")


@pytest.mark.slow
def test_live_cli_smoke(setup, capsys, monkeypatch):
    monkeypatch.setattr("sys.argv", [
        "serve", "--engine", "live", "--arch", "smollm-135m",
        "--qps", "4", "--n", "6", "--controller",
        "--ttft-slo", "5.0", "--tpot-slo", "0.5"])
    serve.main()
    out = capsys.readouterr().out
    assert '"streamed_tokens"' in out
    assert '"real_tokens"' in out
