"""Per-kernel validation: shape/dtype sweeps, assert_allclose against the
pure-jnp oracles (interpret mode executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.chunked_prefill_attention.ops import (
    chunked_prefill_attention)
from repro.kernels.chunked_prefill_attention.ref import (
    chunked_prefill_attention_ref)
from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_naive_ref
from repro.models.mamba2 import ssd_chunked

KEY = jax.random.PRNGKey(42)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Tq,Hq,Hkv,D,S,prefix", [
    (1, 16, 4, 4, 64, 64, 16),       # MHA
    (2, 16, 4, 2, 64, 64, 32),       # GQA 2:1
    (1, 128, 8, 8, 128, 256, 100),   # MXU-aligned tiles
    (2, 8, 9, 3, 64, 40, 17),        # non-divisible heads + padded S
    (1, 32, 16, 1, 64, 96, 50),      # MQA
    (1, 4, 4, 2, 128, 16, 0),        # zero prefix (fresh prompt)
])
def test_chunked_prefill_attention(B, Tq, Hq, Hkv, D, S, prefix, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Tq, Hq, D), dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), dtype)
    ref = chunked_prefill_attention_ref(q, k, v, prefix)
    got = chunked_prefill_attention(q, k, v, prefix, bq=16, bk=32)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Hq,Hkv,D,S", [
    (2, 4, 2, 64, 128),
    (3, 9, 3, 64, 200),      # padded group + padded S
    (1, 8, 1, 128, 512),     # MQA long
    (4, 4, 4, 64, 64),
])
def test_decode_attention(B, Hq, Hkv, D, S, dtype):
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, Hq, D), dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), dtype)
    lengths = jax.random.randint(ks[3], (B,), 1, S + 1)
    ref = decode_attention_ref(q, k, v, lengths)
    got = decode_attention(q, k, v, lengths, bk=64)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


def _ssd_inputs(b, t, h, p, g, n, key=KEY):
    ks = jax.random.split(key, 6)
    x = jax.random.normal(ks[0], (b, t, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, t, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)))
    B = jax.random.normal(ks[3], (b, t, g, n), jnp.float32)
    C = jax.random.normal(ks[4], (b, t, g, n), jnp.float32)
    s0 = jax.random.normal(ks[5], (b, h, p, n), jnp.float32) * 0.1
    return x, dt, A, B, C, s0


@pytest.mark.parametrize("b,t,h,p,g,n,chunk", [
    (2, 64, 4, 32, 1, 16, 16),
    (1, 128, 8, 64, 1, 64, 32),
    (1, 32, 2, 16, 2, 8, 8),         # multi-group
    (2, 96, 4, 32, 1, 32, 32),       # t not a power of two
])
def test_ssd_scan_kernel(b, t, h, p, g, n, chunk):
    x, dt, A, B, C, s0 = _ssd_inputs(b, t, h, p, g, n)
    y_ref, s_ref = ssd_naive_ref(x, dt, A, B, C, s0)
    y_k, s_k = ssd_scan(x, dt, A, B, C, chunk, s0)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref),
                               atol=2e-3)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_ref),
                               atol=2e-3)


def test_ssd_chunked_jnp_matches_naive():
    x, dt, A, B, C, s0 = _ssd_inputs(2, 64, 4, 32, 1, 16)
    y_ref, s_ref = ssd_naive_ref(x, dt, A, B, C, s0)
    y_c, s_c = ssd_chunked(x, dt, A, B, C, 16, s0)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_ref),
                               atol=2e-3)
    np.testing.assert_allclose(np.asarray(s_c), np.asarray(s_ref),
                               atol=2e-3)


def test_ssd_no_init_state():
    x, dt, A, B, C, _ = _ssd_inputs(1, 32, 2, 16, 1, 8)
    y_ref, s_ref = ssd_naive_ref(x, dt, A, B, C, None)
    y_k, s_k = ssd_scan(x, dt, A, B, C, 8, None)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref),
                               atol=2e-3)


def test_chunked_prefill_matches_decode_composition():
    """Prefilling a chunk then decoding == attention semantics agree
    between the two kernels at the boundary."""
    B, Hq, Hkv, D, S = 1, 4, 2, 64, 64
    prefix = 31
    ks = jax.random.split(KEY, 3)
    q1 = jax.random.normal(ks[0], (B, 1, Hq, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), jnp.float32)
    # one-token chunked prefill at position `prefix` == decode over
    # lengths prefix+1
    a = chunked_prefill_attention(q1, k, v, prefix, bq=8, bk=32)
    b = decode_attention(q1[:, 0], k, v,
                         jnp.full((B,), prefix + 1, jnp.int32), bk=32)
    np.testing.assert_allclose(np.asarray(a[:, 0]), np.asarray(b),
                               atol=1e-4)
