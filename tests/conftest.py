import os
import sys

# tests must see exactly ONE device (the dry-run sets 512 in its own
# process); keep any user XLA_FLAGS from leaking in
os.environ.pop("XLA_FLAGS", None)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

jax.config.update("jax_enable_x64", False)
