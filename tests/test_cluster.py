"""Cluster/simulator integration tests: policy behavior under load, the
paper's qualitative claims at test scale, and accounting invariants."""
import pytest

from repro.core.latency import SLO, attainment, max_goodput
from repro.core.policies import Sliders
from repro.engine.request import State
from repro.sim.simulator import ServingConfig, build_cluster, run_sim
from repro.sim.workload import ARXIV, SHAREGPT

BAL = SLO(ttft=1.5, tpot=0.030)


def _run(policy, sliders, qps=100.0, n=200, blocks=8192, flags=None,
         workload=SHAREGPT, seed=0):
    sc = ServingConfig(policy=policy, sliders=sliders, hbm_blocks=blocks)
    return run_sim(sc, BAL, workload, qps, n, seed=seed,
                   taichi_flags=flags)


def test_all_requests_complete():
    for pol, sl in [("aggregation", Sliders(2, 2, 1024, 1024)),
                    ("disaggregation", Sliders(2, 2, 0, 0)),
                    ("taichi", Sliders(2, 2, 1024, 256))]:
        st = _run(pol, sl, qps=40, n=120)
        assert all(r.state == State.FINISHED for r in st.reqs), pol
        assert all(r.output_len == r.target_output_len for r in st.reqs)
        assert all(r.finish_time >= r.arrival for r in st.reqs)


def test_latency_accounting_monotone():
    st = _run("taichi", Sliders(2, 2, 1024, 256), qps=60, n=150)
    for r in st.reqs:
        assert r.ttft() is not None and r.ttft() >= 0
        if r.output_len > 1:
            assert r.tpot() is not None and r.tpot() > 0
        assert r.first_token_time <= r.last_token_time


@pytest.mark.slow
def test_obs1_structure_under_balanced_slo():
    """The paper's core observation at moderate test scale: aggregation
    degrades TPOT, disaggregation degrades TTFT, TaiChi bounds both."""
    agg = _run("aggregation", Sliders(2, 2, 1024, 1024), qps=110, n=250)
    dis = _run("disaggregation", Sliders(2, 2, 0, 0), qps=110, n=250)
    tai = _run("taichi", Sliders(2, 2, 1024, 256), qps=110, n=250)
    assert dis.p90_tpot < agg.p90_tpot, "disagg must have better TPOT"
    assert agg.p90_ttft < dis.p90_ttft, "agg must have better TTFT"
    assert tai.slo_attainment >= max(agg.slo_attainment,
                                     dis.slo_attainment), \
        (tai.slo_attainment, agg.slo_attainment, dis.slo_attainment)


def test_flowing_engages_under_memory_pressure():
    st = _run("taichi", Sliders(2, 2, 1024, 256), qps=100, n=300,
              blocks=2048)
    c = st.cluster
    assert c.degrade_count > 0, "watermark degradation should fire"
    # degraded requests actually migrated
    migrated = [r for r in st.reqs if r.n_migrations > 0]
    assert migrated


def test_flowing_disabled_means_no_migrating_moves():
    st = _run("taichi", Sliders(2, 2, 1024, 256), qps=100, n=200,
              blocks=2048, flags={"enable_flowing": False})
    c = st.cluster
    assert c.degrade_count == 0 and c.backflow_count == 0


def test_disaggregation_transfers_every_request():
    st = _run("disaggregation", Sliders(2, 2, 0, 0), qps=30, n=80)
    c = st.cluster
    assert c.transfer_count >= len(st.reqs)
    # and every decode ran on a D instance, prefill on P
    for r in st.reqs:
        assert r.prefill_instance in (0, 1)
        assert r.decode_instance in (2, 3)


def test_aggregation_never_transfers():
    st = _run("aggregation", Sliders(2, 2, 1024, 1024), qps=30, n=80)
    assert st.cluster.transfer_count == 0
    for r in st.reqs:
        assert r.prefill_instance == r.decode_instance


def test_goodput_sweep_monotone_metric():
    def run_at(q):
        return _run("taichi", Sliders(2, 2, 1024, 256), qps=q, n=120)
    g, stats = max_goodput(run_at, [20, 60], target=0.9)
    assert g in (0.0, 20, 60)
    assert len(stats) == 2


def test_interference_accounting():
    st = _run("aggregation", Sliders(2, 2, 512, 512), qps=100, n=200)
    vals = [r.interference_intensity() for r in st.reqs
            if r.interference_intensity() is not None]
    assert vals and any(v > 0 for v in vals), \
        "mixed batches must record prefill-decode interference"


@pytest.mark.slow
def test_backflow_resets_tpot_window():
    st = _run("taichi", Sliders(1, 3, 2048, 64), qps=110, n=250,
              blocks=1500)
    c = st.cluster
    if c.backflow_count:
        flowed = [r for r in st.reqs if r.tpot_reset_len > 0]
        assert flowed
