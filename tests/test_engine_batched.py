"""Batched-executor parity: the packed, bucketed, fully-jitted path must
be token-exact vs. the retained row-wise reference oracle under greedy
decoding — across uneven prompt lengths spanning multiple T buckets and
a mid-stream migration (extract_state/insert_state round-trip).

The pure-numpy packing/bucketing unit tests at the top run in the fast
tier; the model-executing parity tests are slow-tier."""
import numpy as np
import pytest

from repro.engine import batching

# ---------------------------------------------------------------------------
# fast tier: packing / bucketing logic (no model execution)
# ---------------------------------------------------------------------------


def test_bucket_rounds_up_to_configured_then_pow2():
    buckets = (16, 32, 64)
    assert batching.bucket(1, buckets) == 16
    assert batching.bucket(16, buckets) == 16
    assert batching.bucket(17, buckets) == 32
    assert batching.bucket(64, buckets) == 64
    assert batching.bucket(65, buckets) == 128      # beyond largest: pow2
    assert batching.bucket_batch(1) == 1
    assert batching.bucket_batch(3) == 4
    assert batching.bucket_batch(8) == 8


def test_default_t_buckets_cover_max_seq():
    bs = batching.default_t_buckets(256)
    assert bs[0] == 16 and bs[-1] == 256
    assert all(b2 == 2 * b1 for b1, b2 in zip(bs, bs[1:]))
    assert batching.default_t_buckets(48)[-1] == 48  # non-pow2 max_seq kept


def test_pack_prefill_pads_rows_and_batch():
    packed = batching.pack_prefill(
        chunks=[[5, 6, 7], [8, 9]], starts=[4, 0], row_slots=[2, 0],
        n_slots=4, t_buckets=(4, 8))
    assert packed.tokens.shape == (2, 4)             # B=2 (pow2), T bucket 4
    np.testing.assert_array_equal(packed.tokens[0], [5, 6, 7, 0])
    np.testing.assert_array_equal(packed.valid, [3, 2])
    np.testing.assert_array_equal(packed.start, [4, 0])
    np.testing.assert_array_equal(packed.slots, [2, 0])
    # batch padding rows carry the out-of-range slot (scatter drops them)
    packed3 = batching.pack_prefill(
        chunks=[[1], [2], [3]], starts=[0, 0, 0], row_slots=[0, 1, 2],
        n_slots=4, t_buckets=(4,))
    assert packed3.tokens.shape == (4, 4)
    assert packed3.slots[3] == 4
    assert packed3.valid[3] == 0


# ---------------------------------------------------------------------------
# slow tier: token-exact parity on a real (reduced) model
# ---------------------------------------------------------------------------

jax = pytest.importorskip("jax")

from repro.cache import PrefixCache                           # noqa: E402
from repro.configs import reduced_config                      # noqa: E402
from repro.core.estimator import CostModel                    # noqa: E402
from repro.core.hw import InstanceSpec                        # noqa: E402
from repro.core.instance import D_HEAVY, P_HEAVY, Instance    # noqa: E402
from repro.engine.engine import JaxExecutor, packable         # noqa: E402
from repro.engine.request import Request                      # noqa: E402
from repro.models import transformer as tf                    # noqa: E402


@pytest.fixture(scope="module")
def setup():
    cfg = reduced_config("smollm-135m")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    cost = CostModel(cfg, InstanceSpec(tp=1))
    return cfg, params, cost


def _generate(cfg, params, cost, prompts, n_out, *, batched, chunk=32,
              t_buckets=(8, 16, 32)):
    ex = JaxExecutor(cfg, params, n_slots=len(prompts) + 1, max_seq=256,
                     batched=batched, t_buckets=t_buckets)
    inst = Instance(0, D_HEAVY, chunk, cost, ex, hbm_blocks=512)
    reqs = [Request(prompt_len=len(p), max_new_tokens=n_out,
                    hidden_output_len=n_out, prompt_tokens=list(p))
            for p in prompts]
    for r in reqs:
        inst.enqueue_prefill(r)
    now, guard = 0.0, 0
    while not all(r.done() for r in reqs) and guard < 300:
        dur, done, _ = inst.run_iteration(now)
        now += dur
        guard += 1
        for r in done:
            inst.admit_decode(r)
    assert all(r.done() for r in reqs)
    return [r.output_tokens for r in reqs]


@pytest.mark.slow
def test_batched_matches_rowwise_uneven_buckets(setup):
    """Uneven prompt lengths whose chunk sequence spans at least two T
    buckets (9/14 -> 16-bucket, 24-token tail -> 32-bucket)."""
    cfg, params, cost = setup
    assert packable(cfg)
    rng = np.random.default_rng(5)
    prompts = [list(rng.integers(1, cfg.vocab_size, size=n))
               for n in (9, 14, 33, 47)]
    ref = _generate(cfg, params, cost, prompts, 6, batched=False)
    bat = _generate(cfg, params, cost, prompts, 6, batched=True)
    assert bat == ref


@pytest.mark.slow
def test_batched_migration_round_trip_token_exact(setup):
    """extract_state/insert_state between two batched engines mid-decode
    must not change greedy generation vs. the row-wise reference."""
    cfg, params, cost = setup
    rng = np.random.default_rng(6)
    prompt = list(rng.integers(1, cfg.vocab_size, size=26))

    def run_migrated(batched):
        exA = JaxExecutor(cfg, params, n_slots=4, max_seq=256,
                          batched=batched)
        exB = JaxExecutor(cfg, params, n_slots=4, max_seq=256,
                          batched=batched)
        iA = Instance(0, D_HEAVY, 16, cost, exA, hbm_blocks=512)
        iB = Instance(1, P_HEAVY, 16, cost, exB, hbm_blocks=512)
        req = Request(prompt_len=len(prompt), max_new_tokens=8,
                      hidden_output_len=8, prompt_tokens=list(prompt))
        iA.enqueue_prefill(req)
        now = 0.0
        while req.prefill_remaining > 0:
            dur, _, _ = iA.run_iteration(now)
            now += dur
        iA.admit_decode(req)
        for _ in range(3):
            dur, _, _ = iA.run_iteration(now)
            now += dur
        state = iA.eject(req)
        iB.inject(req, state)
        while not req.done():
            dur, _, _ = iB.run_iteration(now)
            now += dur
        return req.output_tokens

    assert run_migrated(True) == run_migrated(False)


# ---------------------------------------------------------------------------
# prefix-cache KV reuse: token-exact vs. the uncached oracle
# ---------------------------------------------------------------------------

def _run_sequenced(cfg, params, cost, waves, n_out, *, cached,
                   batched=True, chunk=32, overlap=False):
    """Run request ``waves`` on one instance.  The next wave is enqueued
    once the previous wave's requests finish (``overlap=False`` —
    retained-slot adoption) or as soon as they have their first token,
    i.e. prefilled but still decoding (``overlap=True`` — live-donor
    gather).  Returns (outputs in enqueue order, instance)."""
    ex = JaxExecutor(cfg, params, n_slots=6, max_seq=256, batched=batched,
                     prefix_cache=cached)
    pc = PrefixCache(512, 16) if cached else None
    inst = Instance(0, D_HEAVY, chunk, cost, ex, hbm_blocks=512,
                    prefix_cache=pc)
    all_reqs = []
    now = 0.0
    for wave in waves:
        reqs = [Request(prompt_len=len(p), max_new_tokens=n_out,
                        hidden_output_len=n_out, prompt_tokens=list(p))
                for p in wave]
        all_reqs.extend(reqs)
        for r in reqs:
            inst.enqueue_prefill(r)
        ready = ((lambda: all(r.first_token_time is not None for r in reqs))
                 if overlap else (lambda: all(r.done() for r in reqs)))
        guard = 0
        while not ready() and guard < 300:
            dur, done, _ = inst.run_iteration(now)
            now += dur
            guard += 1
            for r in done:
                inst.admit_decode(r)
        assert ready()
    guard = 0
    while not all(r.done() for r in all_reqs) and guard < 300:
        dur, done, _ = inst.run_iteration(now)
        now += dur
        guard += 1
        for r in done:
            inst.admit_decode(r)
    assert all(r.done() for r in all_reqs)
    return [r.output_tokens for r in all_reqs], inst


@pytest.mark.slow
def test_prefix_cache_adoption_token_exact(setup):
    """A finished request's retained slot row is adopted by a later
    request sharing its prefix — greedy outputs must match the uncached
    row-wise oracle exactly."""
    cfg, params, cost = setup
    rng = np.random.default_rng(11)
    shared = list(rng.integers(1, cfg.vocab_size, size=32))
    waves = [[shared + list(rng.integers(1, cfg.vocab_size, size=9))],
             [shared + list(rng.integers(1, cfg.vocab_size, size=17))],
             [list(shared)]]                  # identical full prompt
    ref, _ = _run_sequenced(cfg, params, cost, waves, 6, cached=False,
                            batched=False)
    got, inst = _run_sequenced(cfg, params, cost, waves, 6, cached=True)
    assert got == ref
    assert inst.cache_hits == 2
    assert inst.executor.prefix_adoptions >= 1
    # the identical-prompt hit is capped at prompt_len - 1 full blocks
    assert inst.cached_prefill_tokens == 32 + 16


@pytest.mark.slow
def test_prefix_cache_live_donor_token_exact(setup):
    """Concurrent requests sharing a prefix: the later one gathers the
    matched KV columns from the LIVE donor's row (on-device masked
    copy) — still token-exact, on both executor paths."""
    cfg, params, cost = setup
    rng = np.random.default_rng(13)
    shared = list(rng.integers(1, cfg.vocab_size, size=48))
    tails = [list(rng.integers(1, cfg.vocab_size, size=n))
             for n in (5, 11, 21)]
    # overlap: followers arrive while the donor is still decoding, so
    # its slot is live and the matched columns must be gathered
    waves = [[shared + tails[0]], [shared + tails[1]], [shared + tails[2]]]
    ref, _ = _run_sequenced(cfg, params, cost, waves, 8, cached=False,
                            batched=False, chunk=64, overlap=True)

    got_b, inst_b = _run_sequenced(cfg, params, cost, waves, 8,
                                   cached=True, chunk=64, overlap=True)
    assert got_b == ref
    assert inst_b.cache_hits >= 2
    if inst_b.executor.paged:
        # paged cache: a LIVE donor needs no gather — the follower's
        # block table aliases the donor's blocks (zero copies)
        assert inst_b.executor.prefix_adoptions >= 1
        assert inst_b.executor.prefix_copies == 0
    else:
        assert inst_b.executor.prefix_copies >= 1

    got_r, inst_r = _run_sequenced(cfg, params, cost, waves, 8,
                                   cached=True, batched=False, chunk=64,
                                   overlap=True)
    assert got_r == ref
    assert inst_r.cache_hits >= 2


@pytest.mark.slow
def test_prefix_cache_noop_for_nonpackable(setup):
    """Families whose state can't be sliced at a token boundary must
    ignore the engine prefix cache (claim_prefix returns 0)."""
    cfg = reduced_config("gemma3-1b")
    assert not packable(cfg)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    cost = CostModel(cfg, InstanceSpec(tp=1))
    rng = np.random.default_rng(17)
    shared = list(rng.integers(1, cfg.vocab_size, size=32))
    waves = [[shared + list(rng.integers(1, cfg.vocab_size, size=9))],
             [shared + list(rng.integers(1, cfg.vocab_size, size=13))]]
    ref, _ = _run_sequenced(cfg, params, cost, waves, 4, cached=False,
                            batched=False, chunk=16)
    got, inst = _run_sequenced(cfg, params, cost, waves, 4, cached=True,
                               chunk=16)
    assert got == ref
    assert not inst.executor.prefix_cache_enabled
    assert inst.cached_prefill_tokens == 0     # engine refused the claim


@pytest.mark.slow
def test_slot_fallback_matches_rowwise_nonpackable(setup):
    """Families where T-padding is unsafe (ring-buffer local attention)
    take the on-device slot-indexed row path — still token-exact."""
    cfg = reduced_config("gemma3-1b")
    assert not packable(cfg)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    cost = CostModel(cfg, InstanceSpec(tp=1))
    rng = np.random.default_rng(8)
    prompts = [list(rng.integers(1, cfg.vocab_size, size=n))
               for n in (13, 21)]
    ref = _generate(cfg, params, cost, prompts, 4, batched=False, chunk=16)
    bat = _generate(cfg, params, cost, prompts, 4, batched=True, chunk=16)
    assert bat == ref
