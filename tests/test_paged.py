"""Paged KV execution tests.

Fast tier: mixed-batch packing, block-table/allocator invariants under
random interleaved ops (hypothesis when available, seeded fallback
otherwise), kernel backend autodetect, and paged Pallas kernels vs. the
jnp gather reference on tiny shapes.

Slow tier: token-exact greedy parity of the paged executor (one fused
mixed prefill+decode jit call per iteration) against the row-wise dense
oracle — including prefix adoption via block-table aliasing and a
migration round trip that ships only owned blocks — plus donor
re-registration after migration-in and prefix-aware transfer charging.
"""
import os
import random

import numpy as np
import pytest

from repro.engine import batching

# ---------------------------------------------------------------------------
# fast tier: packing
# ---------------------------------------------------------------------------


def _table(bids, width=16):
    row = np.full(width, -1, np.int32)
    row[:len(bids)] = bids
    return row


def test_pack_mixed_buckets_all_three_axes():
    packed = batching.pack_mixed(
        chunks=[[5, 6, 7], [9]], starts=[4, 60],
        table_rows=[_table([2, 0]), _table([7, 1, 3, 11])],
        t_buckets=(4, 8), max_blocks=16, block_size=16)
    assert packed.tokens.shape == (2, 4)          # B=2, T bucket 4
    # row 1 is a decode-style row: start 60 + 1 token -> needs 4 blocks,
    # NB buckets to the next power of two
    assert packed.tables.shape[1] == 4
    np.testing.assert_array_equal(packed.valid, [3, 1])
    np.testing.assert_array_equal(packed.start, [4, 60])
    np.testing.assert_array_equal(packed.tables[0], [2, 0, -1, -1])
    np.testing.assert_array_equal(packed.tables[1], [7, 1, 3, 11])


def test_pack_mixed_pad_rows_are_inert():
    packed = batching.pack_mixed(
        chunks=[[1], [2], [3]], starts=[0, 0, 0],
        table_rows=[_table([0]), _table([1]), _table([2])],
        t_buckets=(4,), max_blocks=8, block_size=16)
    assert packed.tokens.shape[0] == 4            # B pow2 padded
    assert packed.valid[3] == 0
    assert (packed.tables[3] == -1).all()         # every write drops


def test_pack_mixed_nb_capped_at_max_blocks():
    packed = batching.pack_mixed(
        chunks=[[1] * 8], starts=[72],             # needs 5 blocks
        table_rows=[_table([0, 1, 2, 3, 4], width=6)],
        t_buckets=(8,), max_blocks=6, block_size=16)
    assert packed.tables.shape[1] == 6            # pow2(5)=8 capped at 6


# ---------------------------------------------------------------------------
# fast tier: kernel backend autodetect
# ---------------------------------------------------------------------------

jax = pytest.importorskip("jax")

from repro.kernels import resolve_interpret                   # noqa: E402


def test_resolve_interpret_autodetect_and_override(monkeypatch):
    monkeypatch.delenv("REPRO_KERNELS_INTERPRET", raising=False)
    on_tpu = jax.default_backend() == "tpu"
    assert resolve_interpret(None) == (not on_tpu)
    assert resolve_interpret(True) is True
    assert resolve_interpret(False) is False
    monkeypatch.setenv("REPRO_KERNELS_INTERPRET", "1")
    assert resolve_interpret(None) is True
    monkeypatch.setenv("REPRO_KERNELS_INTERPRET", "0")
    assert resolve_interpret(None) is False
    # explicit argument still wins over the env
    assert resolve_interpret(True) is True


# ---------------------------------------------------------------------------
# fast tier: block-table / allocator invariants (PagedKVCache)
# ---------------------------------------------------------------------------

from repro.configs import reduced_config                      # noqa: E402
from repro.engine.kvcache import OutOfBlocks                  # noqa: E402
from repro.engine.paged import PagedKVCache                   # noqa: E402


def _mini_kv(num_blocks=24, n_slots=6, block_size=4):
    cfg = reduced_config("smollm-135m")
    return PagedKVCache(cfg, n_slots, max_seq=64, num_blocks=num_blocks,
                        block_size=block_size)


def run_kv_ops(ops, num_blocks, n_slots, block_size):
    """Random interleaving of the executor's physical-bookkeeping ops;
    PagedKVCache.check_invariants asserts no double-owned block, table
    rows == owned bids, and free + cached + used == total after every
    op."""
    kv = _mini_kv(num_blocks, n_slots, block_size)
    live = {}                                     # rid -> slot
    free_slots = list(range(n_slots))
    for op, rid, tokens in ops:
        if op == "add" and rid not in live and free_slots:
            try:
                kv.ensure(rid, tokens)
            except OutOfBlocks:
                continue
            slot = free_slots.pop()
            live[rid] = slot
            kv.refresh_row(slot, rid)
        elif op == "grow" and rid in live:
            try:
                kv.ensure(rid, tokens)
            except OutOfBlocks:
                continue
            kv.refresh_row(live[rid], rid)
        elif op == "share" and rid not in live and live and free_slots:
            donor = sorted(live)[rid % len(live)]
            shared = kv.allocator.owned(donor)[
                :kv.blocks_for(tokens) - 1]
            for b in shared:
                kv.allocator.register(b)
            try:
                kv.allocator.allocate(rid, tokens, shared=shared)
            except OutOfBlocks:
                continue
            slot = free_slots.pop()
            live[rid] = slot
            kv.refresh_row(slot, rid)
            # CoW aliasing: both tables reference the shared prefix
            assert kv.row_bids(slot)[:len(shared)] == \
                kv.row_bids(live[donor])[:len(shared)]
        elif op == "free" and rid in live:
            slot = live.pop(rid)
            kv.clear_row(slot)
            free_slots.append(slot)
            kv.allocator.free(rid)
        kv.check_invariants()
        for r, s in live.items():
            owned = kv.allocator.owned(r)[:kv.max_blocks]
            assert kv.row_bids(s) == owned
    for rid in list(live):
        kv.clear_row(live[rid])
        kv.allocator.free(rid)
    a = kv.allocator
    assert a.used_blocks == 0
    assert a.free_blocks + a.cached_blocks == a.num_blocks


KV_OPS = ("add", "grow", "share", "free")


def _random_kv_ops(rng, n):
    return [(rng.choice(KV_OPS), rng.randrange(10), rng.randrange(1, 80))
            for _ in range(n)]


def test_block_table_invariants_seeded():
    for seed in range(20):
        rng = random.Random(seed)
        run_kv_ops(_random_kv_ops(rng, 80),
                   num_blocks=rng.randrange(8, 48), n_slots=6,
                   block_size=rng.randrange(1, 8))


try:
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=40, deadline=None)
    @given(st.lists(st.tuples(st.sampled_from(KV_OPS),
                              st.integers(0, 9), st.integers(1, 80)),
                    max_size=60),
           st.integers(8, 48), st.integers(1, 8))
    def test_block_table_invariants_hypothesis(ops, num_blocks, block_size):
        run_kv_ops(ops, num_blocks, n_slots=6, block_size=block_size)
except ImportError:                               # pragma: no cover
    pass


def test_rebind_allocator_requires_matching_block_size():
    from repro.cache.shared_allocator import SharedBlockAllocator
    kv = _mini_kv(block_size=4)
    with pytest.raises(ValueError):
        kv.rebind_allocator(SharedBlockAllocator(16, block_size=8))
    bigger = SharedBlockAllocator(40, block_size=4)
    kv.rebind_allocator(bigger)
    assert kv.allocator is bigger
    assert kv.num_blocks == 40
    # pool leaves rebuilt to the adopted allocator's capacity
    P = 40 * 4
    assert all(a.shape[1] == P
               for a in jax.tree.leaves(kv.pool["segments"]))


# ---------------------------------------------------------------------------
# fast tier: paged kernels vs jnp reference (tiny shapes, interpret)
# ---------------------------------------------------------------------------

import jax.numpy as jnp                                       # noqa: E402

from repro.kernels.chunked_prefill_attention.ops import (     # noqa: E402
    paged_chunked_prefill_attention)
from repro.kernels.decode_attention.ops import (              # noqa: E402
    paged_decode_attention)
from repro.models.attention import paged_gather               # noqa: E402


def _ref_paged_attention(q, k_pool, v_pool, tables, q_pos, bs):
    """jnp reference: dense gather through the block table + masked
    softmax (the engine's non-kernel read path)."""
    from repro.models.attention import (_gqa_scores, _masked_softmax,
                                        causal_mask)
    kd, kv_pos = paged_gather(k_pool, tables, bs)
    vd, _ = paged_gather(v_pool, tables, bs)
    mask = causal_mask(q_pos, kv_pos)
    probs = _masked_softmax(_gqa_scores(q, kd), mask)
    B, Hkv, G, T, S = probs.shape
    out = jnp.einsum("bkgts,bskd->btkgd", probs.astype(q.dtype), vd)
    return out.reshape(B, T, Hkv * G, -1)


@pytest.fixture(scope="module")
def pools():
    rng = np.random.default_rng(0)
    bs, nblk, hkv, d = 16, 24, 2, 64
    kp = jnp.asarray(rng.normal(size=(nblk * bs, hkv, d)).astype(np.float32))
    vp = jnp.asarray(rng.normal(size=(nblk * bs, hkv, d)).astype(np.float32))
    return bs, nblk, hkv, d, kp, vp


def _rand_tables(rng, nblk, lengths, bs, width):
    tables = np.full((len(lengths), width), -1, np.int32)
    pool = list(range(nblk))
    rng.shuffle(pool)
    for b, ln in enumerate(lengths):
        nb = -(-int(ln) // bs)
        tables[b, :nb] = [pool.pop() for _ in range(nb)]
    return tables


def test_paged_decode_kernel_matches_reference(pools):
    bs, nblk, hkv, d, kp, vp = pools
    rng = np.random.default_rng(1)
    lengths = np.array([37, 5, 160], np.int32)
    tables = _rand_tables(rng, nblk, lengths, bs, width=11)
    q = jnp.asarray(rng.normal(size=(3, 8, d)).astype(np.float32))
    out = paged_decode_attention(q, kp, vp, jnp.asarray(tables),
                                 jnp.asarray(lengths), block_size=bs,
                                 interpret=True)
    ref = _ref_paged_attention(q[:, None], kp, vp, jnp.asarray(tables),
                               jnp.asarray(lengths - 1)[:, None], bs)[:, 0]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_paged_prefill_kernel_matches_reference_mixed_rows(pools):
    bs, nblk, hkv, d, kp, vp = pools
    rng = np.random.default_rng(2)
    # mixed geometry: real chunk, short chunk, decode-style valid == 1
    starts = np.array([10, 0, 36], np.int32)
    valids = np.array([8, 5, 1], np.int32)
    tables = _rand_tables(rng, nblk, starts + valids, bs, width=9)
    Tq = 8
    q = jnp.asarray(rng.normal(size=(3, Tq, 8, d)).astype(np.float32))
    out = paged_chunked_prefill_attention(
        q, kp, vp, jnp.asarray(tables), jnp.asarray(starts),
        jnp.asarray(valids), block_size=bs, interpret=True)
    q_pos = starts[:, None] + np.arange(Tq)[None]
    ref = _ref_paged_attention(q, kp, vp, jnp.asarray(tables),
                               jnp.asarray(q_pos), bs)
    for b in range(3):
        for t in range(int(valids[b])):           # padded tokens: garbage
            np.testing.assert_allclose(np.asarray(out[b, t]),
                                       np.asarray(ref[b, t]), atol=2e-5)


# ---------------------------------------------------------------------------
# fast tier: prefix-aware migration charging (pure unit, stub instances)
# ---------------------------------------------------------------------------


def test_cluster_transfer_charges_nonshared_suffix():
    import itertools

    from repro.core.cluster import Cluster
    from repro.engine.request import Request

    class StubCost:
        def transfer_time(self, ctx):
            return float(ctx)

        def state_bytes(self, ctx):
            return ctx * 10

    class StubInst:
        def __init__(self, cached):
            self.cached = cached

        def eject(self, req):
            return {}

        def peek_migration_prefix(self, req):
            return self.cached

    c = Cluster.__new__(Cluster)
    c.cost = StubCost()
    c._heap = []
    c._seq = itertools.count()
    c.transfer_count = 0
    c.transfer_bytes = 0
    req = Request(prompt_len=100, max_new_tokens=16,
                  prompt_tokens=list(range(100)))
    req.prefill_pos, req.output_len = 100, 20     # context 120
    c._start_transfer(req, StubInst(0), StubInst(48), now=0.0, kind="place")
    assert c.transfer_bytes == (120 - 48) * 10    # suffix only
    t_aware = c._heap[0][0]
    assert t_aware == 120 - 48
    # an uncached destination still pays the full context
    req2 = Request(prompt_len=100, max_new_tokens=16,
                   prompt_tokens=list(range(100)))
    req2.prefill_pos, req2.output_len = 100, 20
    c._start_transfer(req2, StubInst(0), StubInst(0), now=0.0, kind="place")
    assert c.transfer_bytes == (120 - 48) * 10 + 120 * 10


# ---------------------------------------------------------------------------
# slow tier: executor parity on a real (reduced) model
# ---------------------------------------------------------------------------

from repro.core.estimator import CostModel                    # noqa: E402
from repro.core.hw import InstanceSpec                        # noqa: E402
from repro.core.instance import D_HEAVY, P_HEAVY, Instance    # noqa: E402
from repro.engine.engine import JaxExecutor                   # noqa: E402
from repro.engine.request import Request                      # noqa: E402
from repro.models import transformer as tf                    # noqa: E402


@pytest.fixture(scope="module")
def setup():
    cfg = reduced_config("smollm-135m")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    cost = CostModel(cfg, InstanceSpec(tp=1))
    return cfg, params, cost


def _drive(inst, reqs, guard=300):
    now, g = 0.0, 0
    while not all(r.done() for r in reqs) and g < guard:
        dur, done, _ = inst.run_iteration(now)
        now += dur
        g += 1
        for r in done:
            inst.admit_decode(r)
    assert all(r.done() for r in reqs)
    return now


def _make(cfg, params, cost, *, batched, paged=None, prefix=False,
          n_slots=5, chunk=32, hbm_blocks=512):
    ex = JaxExecutor(cfg, params, n_slots=n_slots, max_seq=256,
                     batched=batched, t_buckets=(8, 16, 32), paged=paged,
                     prefix_cache=prefix)
    inst = Instance(0, D_HEAVY, chunk, cost, ex, hbm_blocks=hbm_blocks)
    return ex, inst


@pytest.mark.slow
def test_paged_matches_rowwise_uneven_buckets(setup):
    """Greedy parity across uneven prompt lengths spanning multiple T
    buckets, with decode mixing into prefill iterations — the fused
    mixed-batch call must be token-exact vs. the row-wise oracle."""
    cfg, params, cost = setup
    rng = np.random.default_rng(5)
    prompts = [list(rng.integers(1, cfg.vocab_size, size=n))
               for n in (9, 14, 33, 47)]

    def gen(batched, paged):
        ex, inst = _make(cfg, params, cost, batched=batched, paged=paged)
        assert ex.paged == (paged if paged is not None else batched)
        reqs = [Request(prompt_len=len(p), max_new_tokens=6,
                        hidden_output_len=6, prompt_tokens=list(p))
                for p in prompts]
        for r in reqs:
            inst.enqueue_prefill(r)
        _drive(inst, reqs)
        return [r.output_tokens for r in reqs]

    ref = gen(batched=False, paged=False)
    assert gen(batched=True, paged=True) == ref
    # admission was bounded by blocks actually referenced: the unified
    # allocator is the executor's
    ex, inst = _make(cfg, params, cost, batched=True, paged=True)
    assert inst.allocator is ex.kv.allocator


@pytest.mark.slow
def test_paged_prefix_adoption_token_exact(setup):
    """Sequential waves sharing a prefix: the paged hit is pure
    block-table aliasing (references on retained blocks), and greedy
    outputs match the uncached row-wise oracle exactly."""
    cfg, params, cost = setup
    rng = np.random.default_rng(11)
    shared = list(rng.integers(1, cfg.vocab_size, size=32))
    waves = [[shared + list(rng.integers(1, cfg.vocab_size, size=9))],
             [shared + list(rng.integers(1, cfg.vocab_size, size=17))],
             [list(shared)]]

    def run(batched, paged, prefix):
        ex, inst = _make(cfg, params, cost, batched=batched, paged=paged,
                         prefix=prefix, n_slots=6)
        outs, reqs_all, now = [], [], 0.0
        for wave in waves:
            reqs = [Request(prompt_len=len(p), max_new_tokens=6,
                            hidden_output_len=6, prompt_tokens=list(p))
                    for p in wave]
            reqs_all.extend(reqs)
            for r in reqs:
                inst.enqueue_prefill(r)
            g = 0
            while not all(r.done() for r in reqs) and g < 300:
                dur, done, _ = inst.run_iteration(now)
                now += dur
                g += 1
                for r in done:
                    inst.admit_decode(r)
            assert all(r.done() for r in reqs)
        return [r.output_tokens for r in reqs_all], ex, inst

    ref, _, _ = run(batched=False, paged=False, prefix=False)
    got, ex, inst = run(batched=True, paged=True, prefix=True)
    assert got == ref
    assert inst.cache_hits == 2
    assert ex.prefix_adoptions == 2               # both hits were aliases
    assert ex.prefix_copies == 0                  # and none was a gather
    assert inst.cached_prefill_tokens == 32 + 16


@pytest.mark.slow
def test_paged_migration_round_trip_token_exact(setup):
    """eject/inject between two paged engines mid-decode ships only the
    owned blocks and must not change greedy generation."""
    cfg, params, cost = setup
    rng = np.random.default_rng(6)
    prompt = list(rng.integers(1, cfg.vocab_size, size=26))

    def run_migrated(batched, paged):
        exA, iA = _make(cfg, params, cost, batched=batched, paged=paged,
                        n_slots=4, chunk=16)
        exB, iB = _make(cfg, params, cost, batched=batched, paged=paged,
                        n_slots=4, chunk=16)
        iB.itype = P_HEAVY
        req = Request(prompt_len=len(prompt), max_new_tokens=8,
                      hidden_output_len=8, prompt_tokens=list(prompt))
        iA.enqueue_prefill(req)
        now = 0.0
        while req.prefill_remaining > 0:
            dur, _, _ = iA.run_iteration(now)
            now += dur
        iA.admit_decode(req)
        for _ in range(3):
            dur, _, _ = iA.run_iteration(now)
            now += dur
        state = iA.eject(req)
        if paged:
            assert "paged_blocks" in state
            # only blocks covering the context ship, not headroom
            assert state["n_blocks"] == -(-state["pos"] // 16)
        iB.inject(req, state)
        while not req.done():
            dur, _, _ = iB.run_iteration(now)
            now += dur
        return req.output_tokens

    assert run_migrated(True, True) == run_migrated(False, False)


@pytest.mark.slow
def test_migration_into_full_pool_defers_until_admission(setup):
    """Inject into a memory-full paged instance must not crash: the
    landing is deferred and materialized by admission once blocks free
    up — and the continuation stays token-exact."""
    cfg, params, cost = setup
    rng = np.random.default_rng(41)
    prompt = list(rng.integers(1, cfg.vocab_size, size=26))

    def run(tight):
        exA, iA = _make(cfg, params, cost, batched=True, paged=True,
                        n_slots=4, chunk=32)
        # destination pool: 10 blocks — an occupier (6 blocks) leaves
        # too little for the migrated context (6 blocks) until it frees
        exB = JaxExecutor(cfg, params, n_slots=4, max_seq=256,
                          batched=True, t_buckets=(8, 16, 32),
                          hbm_blocks=10 if tight else 64)
        iB = Instance(1, D_HEAVY, 32, cost, exB, hbm_blocks=512)
        occupier = Request(prompt_len=30, max_new_tokens=3,
                           hidden_output_len=3,
                           prompt_tokens=list(
                               rng.integers(1, cfg.vocab_size, size=30)))
        iB.enqueue_prefill(occupier)
        now = 0.0
        while occupier.prefill_remaining > 0:
            dur, done, _ = iB.run_iteration(now)
            now += dur
            for r in done:
                iB.admit_decode(r)
        req = Request(prompt_len=len(prompt), max_new_tokens=8,
                      hidden_output_len=8, prompt_tokens=list(prompt))
        iA.enqueue_prefill(req)
        while req.prefill_remaining > 0:
            dur, _, _ = iA.run_iteration(now)
            now += dur
        iA.admit_decode(req)
        for _ in range(3):
            dur, _, _ = iA.run_iteration(now)
            now += dur
        iB.inject(req, iA.eject(req))
        if tight:
            assert req.rid in exB._deferred_states    # pool was full
        _drive(iB, [occupier, req], guard=400)
        return req.output_tokens

    assert run(tight=True) == run(tight=False)


@pytest.mark.slow
def test_donor_reregistration_after_migration_in(setup):
    """A migrated-in request's prompt becomes adoptable on the
    DESTINATION: a later request with the same prompt prefix gets a
    prefix hit there (open ROADMAP item), on both engine paths."""
    cfg, params, cost = setup
    rng = np.random.default_rng(21)
    prompt = list(rng.integers(1, cfg.vocab_size, size=48))

    def run(batched, paged):
        exA, iA = _make(cfg, params, cost, batched=batched, paged=paged,
                        prefix=True, n_slots=6, chunk=64)
        exB, iB = _make(cfg, params, cost, batched=batched, paged=paged,
                        prefix=True, n_slots=6, chunk=64)
        req = Request(prompt_len=len(prompt), max_new_tokens=10,
                      hidden_output_len=10, prompt_tokens=list(prompt))
        iA.enqueue_prefill(req)
        now = 0.0
        while req.prefill_remaining > 0:
            dur, _, _ = iA.run_iteration(now)
            now += dur
        iA.admit_decode(req)
        for _ in range(2):
            dur, _, _ = iA.run_iteration(now)
            now += dur
        iB.inject(req, iA.eject(req))
        while not req.done():
            dur, _, _ = iB.run_iteration(now)
            now += dur
        # the migrated context must now be adoptable ON B
        follower = Request(prompt_len=len(prompt), max_new_tokens=4,
                           hidden_output_len=4,
                           prompt_tokens=list(prompt))
        assert iB.peek_prefix(follower) > 0
        iB.enqueue_prefill(follower)
        _drive(iB, [follower])
        assert inst_hits(iB) >= 1
        return follower.output_tokens

    def inst_hits(inst):
        return inst.cache_hits

    ref_ex, ref_inst = _make(cfg, params, cost, batched=False, paged=False)
    ref_req = Request(prompt_len=len(prompt), max_new_tokens=4,
                      hidden_output_len=4, prompt_tokens=list(prompt))
    ref_inst.enqueue_prefill(ref_req)
    _drive(ref_inst, [ref_req])
    assert run(True, True) == ref_req.output_tokens


@pytest.mark.slow
def test_prefix_aware_transfer_charges_suffix_only(setup):
    """Cluster migration time/bytes charge only the non-shared suffix
    when the destination caches the prompt prefix."""
    cfg, params, cost = setup
    rng = np.random.default_rng(31)
    prompt = list(rng.integers(1, cfg.vocab_size, size=64))

    exA, iA = _make(cfg, params, cost, batched=True, paged=True,
                    prefix=True, n_slots=6, chunk=64)
    exB, iB = _make(cfg, params, cost, batched=True, paged=True,
                    prefix=True, n_slots=6, chunk=64)
    # warm B with the same prompt so it caches the prefix
    warm = Request(prompt_len=len(prompt), max_new_tokens=2,
                   hidden_output_len=2, prompt_tokens=list(prompt))
    iB.enqueue_prefill(warm)
    _drive(iB, [warm])
    req = Request(prompt_len=len(prompt), max_new_tokens=6,
                  hidden_output_len=6, prompt_tokens=list(prompt))
    shared = iB.peek_migration_prefix(req)
    assert shared > 0
    # the charged context shrinks by exactly the destination's hit
    full = cost.transfer_time(req.prompt_len + 3)
    aware = cost.transfer_time(max(req.prompt_len + 3 - shared, 0))
    assert aware < full


@pytest.mark.slow
def test_mixed_step_is_single_jit_call(monkeypatch):
    """The paged executor must issue exactly ONE fused call per
    iteration, prefill and decode together."""
    cfg = reduced_config("smollm-135m")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    cost = CostModel(cfg, InstanceSpec(tp=1))
    ex = JaxExecutor(cfg, params, n_slots=4, max_seq=64,
                     batched=True, t_buckets=(8, 16))
    inst = Instance(0, D_HEAVY, 8, cost, ex, hbm_blocks=256)
    calls = []
    real = ex._mixed_fused
    ex._mixed_fused = lambda *a, **k: (calls.append(1), real(*a, **k))[1]
    ra = Request(prompt_len=12, max_new_tokens=4, hidden_output_len=4,
                 prompt_tokens=list(range(1, 13)))
    rb = Request(prompt_len=20, max_new_tokens=4, hidden_output_len=4,
                 prompt_tokens=list(range(1, 21)))
    inst.enqueue_prefill(ra)
    now = 0.0
    while ra.prefill_remaining > 0:               # prompt 12 spans 2 chunks
        dur, done, _ = inst.run_iteration(now)
        now += dur
        for r in done:
            inst.admit_decode(r)
    inst.enqueue_prefill(rb)
    calls.clear()
    inst.run_iteration(now)                       # mixed: rb chunk + ra step
    assert len(calls) == 1
    assert ra.output_len >= 2                     # the decode ran in it
    assert rb.prefill_pos > 0                     # and the prefill did too
