"""End-to-end behaviour test: a real-engine TaiChi cluster on CPU — the
full stack (proxy -> instances -> JAX engine -> flowing migrations) with
actually-computed tokens."""
import jax
import pytest

from repro.configs import reduced_config
from repro.core.cluster import Cluster
from repro.core.estimator import CostModel
from repro.core.hw import InstanceSpec
from repro.core.latency import SLO
from repro.core.policies import Sliders, TaiChiPolicy, build_instances
from repro.engine.engine import JaxExecutor
from repro.engine.request import Request, State
from repro.models import transformer as tf
from repro.sim.workload import LengthDist, WorkloadSpec

# slow tier: full JAX model/engine execution (run with `pytest -m slow`)
pytestmark = pytest.mark.slow


@pytest.fixture(scope="module")
def system():
    cfg = reduced_config("smollm-135m")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    cost = CostModel(cfg, InstanceSpec(tp=1))
    sliders = Sliders(n_p=1, n_d=1, s_p=32, s_d=16)
    factory = lambda: JaxExecutor(cfg, params, n_slots=8, max_seq=512)
    instances = build_instances(cost, sliders, factory, hbm_blocks=256,
                                block_size=16)
    slo = SLO(ttft=5.0, tpot=0.5)
    policy = TaiChiPolicy(instances, cost, slo.ttft, slo.tpot, sliders)
    return Cluster(policy, cost), slo, cfg


def test_end_to_end_real_engine(system):
    cluster, slo, cfg = system
    wl = WorkloadSpec("tiny",
                      LengthDist(mu=3.2, sigma=0.3, lo=8, hi=64),
                      LengthDist(mu=1.8, sigma=0.4, lo=2, hi=12))
    reqs = wl.sample_requests(12, qps=5.0, seed=7)
    cluster.run(reqs)
    assert all(r.state == State.FINISHED for r in reqs)
    # every request really generated its tokens
    for r in reqs:
        assert len(r.output_tokens) == r.target_output_len
        assert all(0 <= t < cfg.vocab_size for t in r.output_tokens)
        assert r.ttft() is not None and r.ttft() > 0
    st = cluster.stats(reqs, slo, 5.0)
    assert 0.0 <= st.slo_attainment <= 1.0
