"""Shared-prefix KV-cache subsystem: tree matching, instance-level
acquire/commit/release lifecycle, LRU leaf eviction, cache-aware proxy
routing, and end-to-end simulator behavior (hits reduce TTFT; a
prefix-share-0 workload is bit-identical to cache-off)."""
import dataclasses

import pytest

from repro.cache import PrefixCache, PrefixTree, chain_hashes
from repro.core.estimator import CostModel
from repro.core.hw import InstanceSpec
from repro.core.instance import D_HEAVY, Instance, P_HEAVY
from repro.core.latency import SLO
from repro.core.policies import Sliders, build_instances
from repro.core.proxy import Proxy
from repro.configs import get_config
from repro.engine.engine import SimExecutor
from repro.engine.request import Request
from repro.sim.simulator import ServingConfig, run_sim
from repro.sim.workload import (AGENTIC, MULTITURN, SHAREGPT,
                                measured_prefix_share)

BS = 4


def toks(*xs):
    return list(xs)


# ---------------------------------------------------------------------------
# prefix tree
# ---------------------------------------------------------------------------

def test_chain_hashes_full_blocks_only():
    assert len(list(chain_hashes(range(11), 4))) == 2
    a = list(chain_hashes([1, 2, 3, 4, 5, 6, 7, 8], 4))
    b = list(chain_hashes([1, 2, 3, 4, 9, 9, 9, 9], 4))
    assert a[0][0] == b[0][0]          # shared first block, same chain
    assert a[1][0] != b[1][0]          # divergent second block


def test_tree_match_longest_prefix():
    t = PrefixTree(BS)
    base = toks(*range(1, 13))                     # 3 full blocks
    t.insert(base, ["b0", "b1", "b2"])
    assert [n.bid for n in t.match(base)] == ["b0", "b1", "b2"]
    # diverging third block matches 2
    other = base[:8] + [99, 98, 97, 96]
    assert [n.bid for n in t.match(other)] == ["b0", "b1"]
    # partial final block never matches
    assert [n.bid for n in t.match(base[:11])] == ["b0", "b1"]
    assert t.match(toks(50, 51, 52, 53)) == []
    # max_blocks caps the walk
    assert len(t.match(base, max_blocks=1)) == 1


def test_tree_first_writer_wins_and_remove():
    t = PrefixTree(BS)
    base = toks(*range(1, 9))
    assert t.insert(base, ["x0", "x1"]) == ["x0", "x1"]
    assert t.insert(base, ["y0", "y1"]) == []      # positions taken
    t.insert(base + toks(21, 22, 23, 24), ["x0", "x1", "z2"])
    t.remove_bid("x1")                             # prune mid-chain
    assert [n.bid for n in t.match(base)] == ["x0"]
    # the detached subtree (z2) is unmatchable and pruned from the index
    assert not t.holds("z2")
    assert t.node_count == 1


# ---------------------------------------------------------------------------
# prefix cache lifecycle
# ---------------------------------------------------------------------------

def test_acquire_commit_release_hit_cycle():
    pc = PrefixCache(num_blocks=64, block_size=BS)
    prompt = toks(*range(1, 17))                   # 4 full blocks
    assert pc.match_tokens(prompt) == 0
    assert pc.acquire(1, prompt, 0, len(prompt) + 8)
    pc.commit(1, prompt)
    # while rid 1 is live its blocks are shareable (refcount 1 -> 2)
    hit = pc.match_tokens(prompt)
    assert hit == 12                               # capped at len-1 blocks
    assert pc.acquire(2, prompt, hit, len(prompt) + 8)
    assert pc.allocator.refcount(pc.allocator.owned(1)[0]) == 2
    pc.release(1)
    assert pc.allocator.refcount(pc.allocator.owned(2)[0]) == 1
    pc.release(2)
    # all registered blocks retained: a third request still hits
    assert pc.match_tokens(prompt) == 12
    assert pc.allocator.used_blocks == 0


def test_lru_eviction_prefers_leaves_and_reclaims():
    pc = PrefixCache(num_blocks=8, block_size=BS)
    p1 = toks(*range(1, 17))                       # 4 blocks
    assert pc.acquire(1, p1, 0, 16)
    pc.commit(1, p1)
    pc.release(1)
    assert pc.allocator.cached_blocks == 4
    # demand 6 fresh blocks: 4 free + evict 2 cached, suffix-first
    assert pc.acquire(2, toks(*range(101, 125)), 0, 24)
    assert pc.allocator.eviction_count == 2
    assert pc.match_tokens(p1) == 8                # prefix survives, tail gone


def test_acquire_fails_only_when_unevictable():
    pc = PrefixCache(num_blocks=4, block_size=BS)
    p = toks(*range(1, 17))
    assert pc.acquire(1, p, 0, 16)
    assert not pc.acquire(2, p, 0, 16)             # all blocks referenced
    pc.commit(1, p)
    hit = pc.match_tokens(p)
    assert hit == 12
    # sharing makes it admissible: 3 shared + 1 fresh... but the only
    # "fresh" candidate is the donor's own 4th block (refcount 1) — not
    # evictable, so admission must still fail, never steal it
    assert not pc.acquire(2, p, hit, 16)
    pc.release(1)
    assert pc.acquire(2, p, pc.match_tokens(p), 16)


def test_deep_chains_no_recursion_limit():
    """16k-token contexts at block 16 give 1000+-deep chains: eviction
    walks and subtree pruning must not hit Python's recursion limit."""
    pc = PrefixCache(num_blocks=2000, block_size=1)
    p1 = list(range(1, 1502))                      # 1501-deep chain
    assert pc.acquire(1, p1, 0, len(p1))
    pc.commit(1, p1)
    pc.release(1)
    # demand forces ~1000 leaf-first evictions, each walking the chain
    assert pc.acquire(2, list(range(5000, 6500)), 0, 1500)
    assert pc.allocator.eviction_count >= 1000
    # pruning a near-root node detaches the whole remaining chain
    pc.tree.remove_bid(pc.matched_bids(p1, 1)[0])
    assert pc.match_tokens(p1) == 0


def test_peek_does_not_perturb_lru_order():
    """Routing peeks probe every instance; they must not refresh LRU
    recency, or probe-only blocks outlive genuinely reused ones."""
    pc = PrefixCache(num_blocks=10, block_size=BS)
    old = toks(*range(1, 13))                      # 3 blocks, committed first
    new = toks(*range(101, 113))
    assert pc.acquire(1, old, 0, 12)
    pc.commit(1, old)
    pc.release(1)
    assert pc.acquire(2, new, 0, 12)
    pc.commit(2, new)
    pc.release(2)
    for _ in range(50):
        assert pc.match_tokens(old) == 8           # peek spam on `old`
    # two blocks must be reclaimed: both come off `old`'s tail (its
    # leaves are least recently USED), peeks notwithstanding
    assert pc.acquire(3, toks(*range(201, 225)), 0, 24)
    assert pc.match_tokens(old) == 4
    assert pc.match_tokens(new) == 8


# ---------------------------------------------------------------------------
# instance admission + cost accounting
# ---------------------------------------------------------------------------

def make_instance(iid=0, itype=D_HEAVY, chunk=256, blocks=512,
                  prefix=True):
    cost = CostModel(get_config("qwen2.5-14b"), InstanceSpec(tp=4))
    pc = PrefixCache(blocks, BS) if prefix else None
    return Instance(iid, itype, chunk, cost, SimExecutor(),
                    hbm_blocks=blocks, block_size=BS, prefix_cache=pc)


def run_to_first_token(inst, req):
    now, guard = 0.0, 0
    while req.first_token_time is None and guard < 200:
        dur, done, _ = inst.run_iteration(now)
        now += dur
        guard += 1
        for r in done:
            inst.admit_decode(r)
    return now


def test_instance_prefill_starts_at_matched_position():
    inst = make_instance()
    prompt = toks(*range(1, 101))
    r1 = Request(prompt_len=100, max_new_tokens=4, hidden_output_len=4,
                 prompt_tokens=list(prompt))
    inst.enqueue_prefill(r1)
    t1 = run_to_first_token(inst, r1)
    assert r1.cached_prefix_len == 0
    r2 = Request(prompt_len=100, max_new_tokens=4, hidden_output_len=4,
                 prompt_tokens=list(prompt))
    inst.enqueue_prefill(r2)
    t2 = run_to_first_token(inst, r2) - t1
    assert r2.cached_prefix_len == 96              # (100-1) // 4 * 4
    assert inst.cache_hits == 1 and inst.cache_lookups == 2
    assert inst.cached_prefill_tokens == 96
    # cost model charged only the uncached tokens: much faster TTFT
    assert t2 < t1 * 0.5
    # prefill token counter counts only recomputed tokens
    assert inst.prefill_token_count == 100 + 4


def test_blocked_admission_counts_one_lookup():
    """A head-of-line request retried while memory-blocked must count
    ONE cache lookup (at admission), not one per retry — else hit rate
    is deflated exactly at the saturation points benchmarks measure."""
    inst = make_instance(blocks=32)                # 128 tokens capacity
    prompt = toks(*range(1, 41))
    r1 = Request(prompt_len=40, max_new_tokens=20, hidden_output_len=20,
                 prompt_tokens=list(prompt))
    inst.enqueue_prefill(r1)
    now = run_to_first_token(inst, r1)
    inst.admit_decode(r1)
    r2 = Request(prompt_len=40, max_new_tokens=2, hidden_output_len=2,
                 prompt_tokens=list(prompt))
    inst.enqueue_prefill(r2)                       # blocked: r1 holds 26/32
    blocked_iters = 0
    guard = 0
    while not r2.done() and guard < 200:
        if not inst.allocator.holds(r2.rid) and not r2.done():
            blocked_iters += 1
        dur, done, _ = inst.run_iteration(now)
        now += dur
        guard += 1
        for r in done:
            inst.admit_decode(r)
    assert r2.done()
    assert blocked_iters > 3                       # it WAS retried
    assert inst.cache_lookups == 2                 # one per admission
    assert inst.cache_hits == 1                    # r2 hit r1's prompt
    assert r2.cached_prefix_len == 36


def test_peek_prefix_is_pure():
    inst = make_instance()
    prompt = toks(*range(1, 41))
    r1 = Request(prompt_len=40, max_new_tokens=2, hidden_output_len=2,
                 prompt_tokens=list(prompt))
    inst.enqueue_prefill(r1)
    run_to_first_token(inst, r1)
    free = inst.allocator.free_blocks
    probe = Request(prompt_len=40, max_new_tokens=2,
                    prompt_tokens=list(prompt))
    assert inst.peek_prefix(probe) == 36
    assert inst.peek_prefix(probe) == 36           # idempotent
    assert inst.allocator.free_blocks == free      # no side effects


# ---------------------------------------------------------------------------
# cache-aware routing
# ---------------------------------------------------------------------------

def test_routing_tie_breaks_toward_prefix_holder():
    cost = CostModel(get_config("qwen2.5-14b"), InstanceSpec(tp=4))
    insts = build_instances(cost, Sliders(0, 2, 256, 256),
                            lambda: SimExecutor(), hbm_blocks=512,
                            block_size=BS, prefix_cache=True)
    proxy = Proxy(insts, cost, ttft_slo=100.0)
    prompt = toks(*range(1, 101))
    warm = Request(prompt_len=100, max_new_tokens=2, hidden_output_len=2,
                   prompt_tokens=list(prompt))
    insts[1].enqueue_prefill(warm)
    run_to_first_token(insts[1], warm)
    # equal queues (both empty): the prefix holder must win the tie
    req = Request(prompt_len=100, max_new_tokens=2,
                  prompt_tokens=list(prompt))
    assert proxy.schedule_prefill(req, 0.0) is insts[1]
    # cache-awareness off: same tie now falls to the first instance
    proxy.cache_aware = False
    req2 = Request(prompt_len=100, max_new_tokens=2,
                   prompt_tokens=list(prompt))
    assert proxy.schedule_prefill(req2, 0.0) is insts[0]


def test_cache_hit_extends_feasibility():
    """A long prompt infeasible from scratch becomes feasible on the
    instance holding its prefix (the latency-shifting interaction)."""
    cost = CostModel(get_config("qwen2.5-14b"), InstanceSpec(tp=4))
    insts = build_instances(cost, Sliders(1, 1, 1024, 256),
                            lambda: SimExecutor(), hbm_blocks=2048,
                            block_size=BS, prefix_cache=True)
    prompt = toks(*range(1, 4001))
    warm = Request(prompt_len=4000, max_new_tokens=2, hidden_output_len=2,
                   prompt_tokens=list(prompt))
    insts[1].enqueue_prefill(warm)              # warm the D-heavy instance
    run_to_first_token(insts[1], warm)
    full = cost.prefill_time(4000, insts[1].chunk_size)
    resid = cost.prefill_time(4000 - insts[1].peek_prefix(
        Request(prompt_len=4000, max_new_tokens=2,
                prompt_tokens=list(prompt))), insts[1].chunk_size)
    # SLO between residual-prefill time and full-prefill time
    proxy = Proxy(insts, cost, ttft_slo=(resid + full) / 2)
    req = Request(prompt_len=4000, max_new_tokens=2,
                  prompt_tokens=list(prompt))
    chosen = proxy.schedule_prefill(req, 0.0)
    assert chosen is insts[1]
    assert proxy.infeasible_count == 0
    # without awareness the same request is infeasible everywhere
    proxy2 = Proxy(insts, cost, ttft_slo=(resid + full) / 2,
                   cache_aware=False)
    req2 = Request(prompt_len=4000, max_new_tokens=2,
                   prompt_tokens=list(prompt))
    proxy2.schedule_prefill(req2, 0.0)
    assert proxy2.infeasible_count == 1


# ---------------------------------------------------------------------------
# end-to-end simulation
# ---------------------------------------------------------------------------

SLO_E2E = SLO(ttft=2.0, tpot=0.05)


def test_multiturn_workload_emits_shared_token_streams():
    reqs = MULTITURN.sample_requests(80, 8.0, seed=3)
    assert len(reqs) == 80
    assert all(r.prompt_tokens is not None
               and len(r.prompt_tokens) == r.prompt_len for r in reqs)
    assert all(r.arrival <= b.arrival for r, b in zip(reqs, reqs[1:]))
    assert measured_prefix_share(reqs) >= 0.5
    assert measured_prefix_share(AGENTIC.sample_requests(80, 8.0)) >= 0.7


def test_sim_cache_reduces_ttft_and_reports_hits():
    sc = ServingConfig(policy="taichi", sliders=Sliders(2, 2, 1024, 256))
    off = run_sim(sc, SLO_E2E, MULTITURN, qps=8.0, n_requests=100)
    on = run_sim(dataclasses.replace(sc, prefix_cache=True), SLO_E2E,
                 MULTITURN, qps=8.0, n_requests=100)
    assert on.cache_lookups > 0
    assert on.cache_hit_rate > 0.5
    assert on.saved_prefill_tokens > 0
    assert on.mean_ttft < off.mean_ttft * 0.7
    assert off.cache_lookups == 0 and off.cache_hit_rate == 0.0


def test_sim_zero_share_bit_identical_to_cache_off():
    """Acceptance: with the cache ENABLED, a prefix-share-0 (tokenized,
    all-random) workload reproduces today's results bit-exactly."""
    tokenized = dataclasses.replace(SHAREGPT, tokenized=True)
    sc = ServingConfig(policy="taichi", sliders=Sliders(2, 2, 1024, 256))
    off = run_sim(sc, SLO_E2E, tokenized, qps=40.0, n_requests=120)
    on = run_sim(dataclasses.replace(sc, prefix_cache=True), SLO_E2E,
                 tokenized, qps=40.0, n_requests=120)
    key = lambda st: [(r.ttft(), r.tpot(), r.finish_time, r.output_len,
                       r.n_migrations) for r in st.reqs]
    assert key(on) == key(off)
    assert on.cache_hits == 0
    assert on.slo_attainment == off.slo_attainment
