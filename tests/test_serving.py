"""Online serving runtime: incremental stepping equivalence, streaming
callbacks and request futures, sliding-window telemetry, drain-and-flip
role reconfiguration, and the adaptive slider controller's decision
logic (unit-tested against a stubbed loop, plus a small end-to-end
drift run)."""
import json

import pytest

from repro.core.estimator import CostModel
from repro.core.hw import InstanceSpec
from repro.configs import get_config
from repro.core.instance import D_HEAVY, Instance, P_HEAVY
from repro.core.latency import SLO
from repro.core.policies import Sliders
from repro.engine.engine import SimExecutor
from repro.engine.request import Request, State
from repro.serving import (ControllerConfig, ServingLoop, SliderController,
                           TelemetryWindow, VirtualClock, WallClock)
from repro.sim.simulator import ServingConfig, build_cluster
from repro.sim.workload import (DECODE_HEAVY, DRIFT, PROMPT_HEAVY, Phase,
                                PhaseDriftSpec, SHAREGPT)

BAL = SLO(ttft=1.5, tpot=0.030)


def _mk_loop(policy="taichi", sliders=Sliders(2, 2, 1024, 256),
             blocks=8192, arrivals=None, **kw):
    sc = ServingConfig(policy=policy, sliders=sliders, hbm_blocks=blocks)
    cluster = build_cluster(sc, BAL)
    return ServingLoop(cluster, BAL, arrivals=arrivals, **kw)


# ---------------------------------------------------------------------------
# incremental loop == batch run
# ---------------------------------------------------------------------------

def test_incremental_loop_matches_batch_run():
    reqs_a = SHAREGPT.sample_requests(120, 40.0, seed=3)
    reqs_b = SHAREGPT.sample_requests(120, 40.0, seed=3)
    for a, b in zip(reqs_a, reqs_b):       # same lengths/arrivals
        assert (a.prompt_len, a.arrival) == (b.prompt_len, b.arrival)

    sc = ServingConfig(sliders=Sliders(2, 2, 1024, 256))
    batch = build_cluster(sc, BAL)
    batch.run(reqs_a)

    loop = _mk_loop(arrivals=iter(reqs_b), steal=False)
    loop.run()
    assert [r.finish_time for r in reqs_b] == \
        [r.finish_time for r in reqs_a]
    assert [r.output_len for r in reqs_b] == [r.output_len for r in reqs_a]


def test_run_until_is_reentrant():
    reqs = SHAREGPT.sample_requests(60, 40.0, seed=1)
    loop = _mk_loop(arrivals=iter(reqs), steal=False)
    loop.run(until=1.0)
    mid_done = sum(r.state == State.FINISHED for r in loop.requests)
    assert any(r.state != State.FINISHED for r in loop.requests)
    loop.run()
    assert all(r.state == State.FINISHED for r in reqs)
    assert sum(r.state == State.FINISHED for r in reqs) >= mid_done


# ---------------------------------------------------------------------------
# streaming + futures
# ---------------------------------------------------------------------------

def test_streaming_callbacks_and_futures():
    reqs = SHAREGPT.sample_requests(40, 30.0, seed=2)
    seen = []
    loop = _mk_loop(on_token=lambda r, t, tok: seen.append((r.rid, t)))
    handles = [loop.submit(r) for r in reqs]
    with pytest.raises(RuntimeError):
        handles[0].result()
    loop.run()
    assert all(h.done and not h.rejected for h in handles)
    for h in handles:
        assert h.result() is h.req
        # one stream event per emitted token, times nondecreasing
        assert len(h.tokens) == h.req.output_len
        times = [t for t, _ in h.tokens]
        assert times == sorted(times)
        assert h.tokens[0][0] == h.req.first_token_time
    assert len(seen) == sum(r.output_len for r in reqs)


def test_early_rejection_resolves_future_and_counts():
    # SLO nobody can meet -> every request early-rejected at the proxy
    sc = ServingConfig(sliders=Sliders(2, 2, 1024, 256))
    cluster = build_cluster(sc, SLO(ttft=1e-9, tpot=0.030),
                            taichi_flags={"early_rejection": True})
    loop = ServingLoop(cluster, SLO(ttft=1e-9, tpot=0.030))
    h = loop.submit(Request(prompt_len=500, max_new_tokens=8,
                            hidden_output_len=8))
    loop.run()
    assert h.done and h.rejected
    st = loop.stats(qps=1.0)
    assert st.early_rejections == 1
    assert st.summary()["early_rejections"] == 1
    assert loop.telemetry.total_rejected == 1


# ---------------------------------------------------------------------------
# telemetry
# ---------------------------------------------------------------------------

def test_telemetry_window_slides_and_scores():
    tw = TelemetryWindow(BAL, window=10.0)
    good = Request(prompt_len=10, max_new_tokens=4, arrival=0.0)
    good.record_token(1.0)                  # ttft 1.0 <= 1.5
    tw.on_token(good, 1.0)
    bad = Request(prompt_len=10, max_new_tokens=4, arrival=0.0)
    bad.record_token(5.0)                   # ttft 5.0 > 1.5
    tw.on_token(bad, 5.0)
    assert tw.ttft_attainment(6.0) == 0.5
    # the early event falls out of the window
    assert tw.ttft_attainment(12.0) == 0.0
    assert tw.ttft_attainment(30.0) is None

    fin = Request(prompt_len=10, max_new_tokens=3, arrival=0.0)
    fin.record_token(1.0)
    fin.record_token(1.01)
    fin.record_token(1.02)                  # tpot 10ms <= 30ms
    tw.on_finish(fin, 1.02)
    assert tw.tpot_attainment(2.0) == 1.0
    assert tw.goodput(10.0) > 0


def test_snapshot_gauges_and_json_export(tmp_path):
    reqs = SHAREGPT.sample_requests(50, 60.0, seed=5)
    loop = _mk_loop(arrivals=iter(reqs), snapshot_every=0.5)
    loop.run()
    assert loop.log.snapshots, "periodic snapshots must be recorded"
    snap = loop.log.snapshots[-1]
    for key in ("ttft_attainment", "goodput_rps", "throughput_tok_s",
                "instances", "tpot_inflight_attainment"):
        assert key in snap
    gauges = snap["instances"]
    assert {g["iid"] for g in gauges} == \
        {i.iid for i in loop.cluster.instances}
    assert all(0.0 <= g["hbm_util"] <= 1.0 for g in gauges)
    out = tmp_path / "metrics.json"
    loop.log.dump(str(out))
    data = json.loads(out.read_text())
    assert data["snapshots"][-1]["t"] == snap["t"]


def test_clocks():
    vc = VirtualClock()
    vc.sleep_until(5.0)
    assert vc.now == 5.0
    vc.sleep_until(1.0)                     # never goes backwards
    assert vc.now == 5.0
    wc = WallClock(start=3.0)
    assert 2.9 < wc.now < 3.5


# ---------------------------------------------------------------------------
# drain-and-flip
# ---------------------------------------------------------------------------

def test_drain_and_flip_preserves_in_flight_requests():
    reqs = SHAREGPT.sample_requests(80, 50.0, seed=7)
    loop = _mk_loop(arrivals=iter(reqs))
    cluster = loop.cluster
    loop.run(until=1.0)
    victim = max(cluster.instances,
                 key=lambda i: len(i.decoding) + len(i.pending_decode))
    n_inflight = len(victim.decoding) + len(victim.pending_decode)
    assert n_inflight > 0, "need in-flight decodes to drain"
    assert victim.itype == D_HEAVY
    assert loop.flip_role(victim, P_HEAVY, 1024)
    loop.run()
    assert victim.itype == P_HEAVY and victim.chunk_size == 1024
    assert victim.pending_flip is None and not victim.draining
    assert cluster.role_flip_count == 1
    assert cluster.drain_count > 0, "drained decodes travel as transfers"
    assert all(r.state == State.FINISHED for r in reqs)
    assert all(r.output_len == r.target_output_len for r in reqs)
    st = loop.stats(qps=50.0)
    assert st.role_flips == 1
    assert st.summary()["role_flips"] == 1


def test_flip_without_decodes_applies_immediately():
    loop = _mk_loop()
    inst = loop.cluster.instances[-1]
    assert inst.itype == D_HEAVY
    assert loop.cluster.request_role_flip(inst, P_HEAVY, 2048)
    assert inst.itype == P_HEAVY and inst.chunk_size == 2048
    assert loop.cluster.role_flip_count == 1
    # double-staging is refused while one is pending
    inst2 = loop.cluster.instances[0]
    inst2.begin_flip(D_HEAVY, 64)
    assert not loop.cluster.request_role_flip(inst2, D_HEAVY, 64)


def test_set_chunks_zero_requeues_stranded_prefills():
    loop = _mk_loop()
    d_inst = [i for i in loop.cluster.instances
              if i.itype == D_HEAVY][0]
    req = Request(prompt_len=300, max_new_tokens=8, hidden_output_len=8)
    d_inst.enqueue_prefill(req)
    loop.set_chunks(D_HEAVY, 0)
    assert not d_inst.prefill_queue, "queued prefill must be re-routed"
    assert any(req in i.prefill_queue for i in loop.cluster.instances
               if i.chunk_size > 0)


def test_steal_prefill_drains_imbalanced_queue():
    loop = _mk_loop()
    insts = loop.cluster.instances
    # pile a queue on one instance, leave the rest idle
    reqs = [Request(prompt_len=200, max_new_tokens=1,
                    hidden_output_len=1) for _ in range(12)]
    for r in reqs:
        insts[0].enqueue_prefill(r)
    loop.cluster._schedule_iter(insts[0], 0.0)
    loop.run()
    assert all(r.state == State.FINISHED for r in reqs)
    stolen = [i for i in insts[1:] if i.prefill_token_count > 0]
    assert stolen, "idle instances must steal queued prefill work"


# ---------------------------------------------------------------------------
# controller decision logic (stubbed loop)
# ---------------------------------------------------------------------------

class _FakeLoop:
    """Minimal ServingLoop facade for exercising controller decisions."""

    def __init__(self, instances, slo=BAL):
        class _C:
            pass
        self.cluster = _C()
        self.cluster.instances = instances
        self.slo = slo
        self.telemetry = TelemetryWindow(slo, window=10.0)
        self.chunk_calls = []
        self.flip_calls = []

    def set_chunks(self, itype, chunk):
        self.chunk_calls.append((itype, chunk))
        n = 0
        for i in self.cluster.instances:
            if i.itype == itype:
                i.chunk_size = chunk
                n += 1
        return n

    def flip_role(self, inst, itype, chunk):
        self.flip_calls.append((inst.iid, itype))
        inst.itype = itype
        inst.chunk_size = chunk
        return True


def _pool(cost, types=(P_HEAVY, P_HEAVY, D_HEAVY, D_HEAVY),
          chunks=(1024, 1024, 256, 256)):
    return [Instance(i, t, c, cost, SimExecutor(), hbm_blocks=512)
            for i, (t, c) in enumerate(zip(types, chunks))]


@pytest.fixture(scope="module")
def cost():
    return CostModel(get_config("qwen2.5-14b"), InstanceSpec(tp=4))


def _feed_ttft(tw, now, n_bad, n_good):
    for k in range(n_bad + n_good):
        r = Request(prompt_len=10, max_new_tokens=4, arrival=now - 0.5)
        r.record_token(now + (10.0 if k < n_bad else 0.1))
        tw.on_token(r, now)


def _feed_tpot(tw, now, n_bad, n_good, slo=BAL):
    for k in range(n_bad + n_good):
        r = Request(prompt_len=10, max_new_tokens=3, arrival=0.0)
        gap = slo.tpot * (3.0 if k < n_bad else 0.5)
        r.record_token(now - 2 * gap)
        r.record_token(now - gap)
        r.record_token(now)
        tw.on_finish(r, now)


def test_controller_raises_sd_when_ttft_starved(cost):
    loop = _FakeLoop(_pool(cost))
    ctl = SliderController(ControllerConfig(epoch=1.0, cooldown=0))
    ctl.bind(loop)
    _feed_ttft(loop.telemetry, 1.0, n_bad=3, n_good=7)   # att 0.7 < 0.87
    ctl.on_epoch(1.0)
    assert loop.chunk_calls == [(D_HEAVY, 512)]
    assert ctl.moves[-1]["kind"] == "chunk"


def test_controller_jumps_ladder_on_cratered_ttft(cost):
    loop = _FakeLoop(_pool(cost))
    ctl = SliderController(ControllerConfig(epoch=1.0, cooldown=0))
    ctl.bind(loop)
    _feed_ttft(loop.telemetry, 1.0, n_bad=9, n_good=1)   # att 0.1
    ctl.on_epoch(1.0)
    # jumps to the top of the ladder capped at S_P
    assert loop.chunk_calls == [(D_HEAVY, 1024)]


def test_controller_flips_dp_when_no_tpot_headroom(cost):
    insts = _pool(cost, chunks=(1024, 1024, 1024, 1024))  # S_D maxed
    loop = _FakeLoop(insts)
    ctl = SliderController(ControllerConfig(epoch=1.0, cooldown=0))
    ctl.bind(loop)
    _feed_ttft(loop.telemetry, 1.0, n_bad=5, n_good=5)
    ctl.on_epoch(1.0)
    assert loop.flip_calls and loop.flip_calls[0][1] == P_HEAVY
    assert sum(i.itype == D_HEAVY for i in insts) == 1   # min_d floor


def test_controller_lowers_sd_then_flips_pd_when_tpot_starved(cost):
    loop = _FakeLoop(_pool(cost, chunks=(1024, 1024, 64, 64)))
    ctl = SliderController(ControllerConfig(epoch=1.0, cooldown=0))
    ctl.bind(loop)
    _feed_tpot(loop.telemetry, 1.0, n_bad=5, n_good=5)
    ctl.on_epoch(1.0)                      # S_D already at floor -> flip
    assert loop.flip_calls and loop.flip_calls[0][1] == D_HEAVY


def test_controller_reverts_and_taboos_bad_raise(cost):
    loop = _FakeLoop(_pool(cost))
    ctl = SliderController(ControllerConfig(epoch=1.0, cooldown=0))
    ctl.bind(loop)
    _feed_ttft(loop.telemetry, 1.0, n_bad=3, n_good=7)
    ctl.on_epoch(1.0)                      # raise S_D 256 -> 512
    assert loop.chunk_calls == [(D_HEAVY, 512)]
    # next epoch: the raise broke TPOT -> revert + tabu, then escalate
    _feed_tpot(loop.telemetry, 2.0, n_bad=8, n_good=2)
    ctl.on_epoch(2.0)
    assert (D_HEAVY, 256) in loop.chunk_calls          # reverted
    assert any(m["kind"] == "revert" for m in ctl.moves)
    # a later ttft-starved epoch may not raise again while tabooed
    _feed_ttft(loop.telemetry, 3.0, n_bad=3, n_good=17)
    before = list(loop.chunk_calls)
    ctl.on_epoch(3.0)
    raised = [c for c in loop.chunk_calls[len(before):]
              if c[1] > 256]
    assert not raised, "sd-up must be tabooed after a revert"


def test_controller_pd_flip_floors_chunk_above_zero(cost):
    # all-P pool: _current_sd() is 0, but the flipped instance must get
    # a real chunk (chunk 0 would strand its queued prefills)
    insts = _pool(cost, types=(P_HEAVY, P_HEAVY, P_HEAVY, P_HEAVY),
                  chunks=(1024, 1024, 1024, 1024))
    loop = _FakeLoop(insts)
    ctl = SliderController(ControllerConfig(epoch=1.0, cooldown=0))
    ctl.bind(loop)
    _feed_tpot(loop.telemetry, 1.0, n_bad=6, n_good=4)
    ctl.on_epoch(1.0)
    assert loop.flip_calls and loop.flip_calls[0][1] == D_HEAVY
    flipped = [i for i in insts if i.itype == D_HEAVY]
    assert flipped and all(i.chunk_size > 0 for i in flipped)


def test_set_chunks_zero_reroute_resolves_rejections():
    slo = SLO(ttft=1e-9, tpot=0.030)       # nothing is feasible
    sc = ServingConfig(sliders=Sliders(2, 2, 1024, 256))
    cluster = build_cluster(sc, slo,
                            taichi_flags={"early_rejection": True})
    loop = ServingLoop(cluster, slo)
    d_inst = [i for i in cluster.instances if i.itype == D_HEAVY][0]
    req = Request(prompt_len=300, max_new_tokens=8, hidden_output_len=8)
    handle = loop._handles[req.rid] = __import__(
        "repro.serving.server", fromlist=["RequestHandle"]
    ).RequestHandle(req)
    loop.requests.append(req)
    d_inst.enqueue_prefill(req)
    loop.set_chunks(D_HEAVY, 0)
    # the re-route was early-rejected: the future resolves, telemetry
    # and stats see the drop
    assert handle.done and handle.rejected
    assert req.state == State.REJECTED
    assert loop.telemetry.total_rejected == 1


def test_external_submit_arrival_clamped_to_now():
    reqs = SHAREGPT.sample_requests(30, 40.0, seed=9)
    loop = _mk_loop(arrivals=iter(reqs))
    loop.run(until=0.5)
    now = loop.cluster.now
    assert now > 0
    late = Request(prompt_len=64, max_new_tokens=4, hidden_output_len=4)
    h = loop.submit(late)                   # default arrival 0.0 -> now
    assert late.arrival >= now
    loop.run()
    assert h.done
    assert late.ttft() is not None and late.ttft() < now, \
        "TTFT must be measured from submission, not t=0"


def test_controller_holds_when_saturated_both_ways(cost):
    loop = _FakeLoop(_pool(cost))
    ctl = SliderController(ControllerConfig(epoch=1.0, cooldown=0))
    ctl.bind(loop)
    _feed_ttft(loop.telemetry, 1.0, n_bad=8, n_good=2)
    _feed_tpot(loop.telemetry, 1.0, n_bad=8, n_good=2)
    ctl.on_epoch(1.0)
    assert not loop.chunk_calls and not loop.flip_calls


# ---------------------------------------------------------------------------
# end-to-end: controller adapts on a small drift (structure, not goodput
# — the goodput comparison is benchmarks/controller_bench.py and its
# slow-tier test)
# ---------------------------------------------------------------------------

def test_controller_adapts_live_on_mini_drift():
    slo = SLO(ttft=1.2, tpot=0.024)
    drift = PhaseDriftSpec("mini", (
        Phase(PROMPT_HEAVY, 10.0, qps_scale=1.4),
        Phase(DECODE_HEAVY, 10.0, qps_scale=1.2)))
    sc = ServingConfig(sliders=Sliders(1, 3, 1024, 64), hbm_blocks=16384)
    cluster = build_cluster(sc, slo)
    ctl = SliderController(ControllerConfig(epoch=2.0, cooldown=1))
    loop = ServingLoop(cluster, slo,
                       arrivals=drift.iter_requests(18.0, seed=0,
                                                    max_new_tokens=512),
                       controller=ctl, window=4.0)
    loop.run()
    assert loop.requests, "drift must produce traffic"
    assert all(r.state == State.FINISHED for r in loop.requests), \
        "no request may be lost across controller moves"
    assert ctl.n_moves > 0, "the starved phases must trigger retunes"
    st = loop.stats(qps=18.0)
    assert st.slider_moves == ctl.n_moves
    assert st.summary()["slider_moves"] == ctl.n_moves


def test_phase_drift_iterator_contract():
    reqs = list(DRIFT.iter_requests(2.0, seed=0))
    arr = [r.arrival for r in reqs]
    assert arr == sorted(arr)
    assert arr[-1] < DRIFT.total_duration
    # phases actually differ: single-token burst then long generations
    first = [r for r in reqs if r.arrival < DRIFT.phases[0].duration]
    assert all(r.hidden_output_len == 1 for r in first)
    capped = DRIFT.sample_requests(5, 2.0, seed=0)
    assert len(capped) == 5
    assert [r.prompt_len for r in capped] == \
        [r.prompt_len for r in reqs[:5]]


# ---------------------------------------------------------------------------
# acceptance (slow): the online controller strictly beats every static
# slider setting — and the hindsight-best "offline searched" one — on
# the phase-drift workload
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_controller_bench_goodput_strictly_beats_statics():
    import os
    import sys
    root = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    if root not in sys.path:
        sys.path.insert(0, root)
    from benchmarks import controller_bench
    results = controller_bench.run()      # asserts the win internally
    online = results["online"]["goodput_rps"]
    for name, r in results["static"].items():
        assert online > r["goodput_rps"], (name, r["goodput_rps"], online)
    assert online > results["offline_searched"]["goodput_rps"]
    assert results["online"]["role_flips"] >= 2, \
        "the drift must exercise drain-and-flip, not just chunk moves"
