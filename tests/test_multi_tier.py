"""Multi-tier KV cache under memory pressure: host spill tier semantics,
eviction->spill->prefetch promotion, tier conservation invariants,
cross-instance hot-prefix replication, telemetry span anchoring, and the
chunk->0 mid-chunk stranding fix."""
import random

import pytest

from repro.cache import PrefixCache, chain_hashes
from repro.cache.spill import HostSpillPool
from repro.configs import get_config
from repro.core.estimator import CostModel
from repro.core.hw import InstanceSpec
from repro.core.instance import D_HEAVY, P_HEAVY, Instance
from repro.core.latency import SLO
from repro.core.policies import Sliders
from repro.engine.engine import SimExecutor
from repro.engine.request import Request, State
from repro.serving import (ControllerConfig, ServingLoop, SliderController,
                           TelemetryWindow)
from repro.sim.simulator import ServingConfig, build_cluster

BS = 4
BAL = SLO(ttft=1.5, tpot=0.030)


# ---------------------------------------------------------------------------
# host spill pool
# ---------------------------------------------------------------------------

def test_spill_pool_contiguity_holes_and_lru_drop():
    sp = HostSpillPool(2, BS)
    tokens = list(range(1, 13))                    # 3 full blocks
    chains = list(chain_hashes(tokens, BS))
    # leaf-first HBM eviction spills children BEFORE parents — out of
    # chain order — and the flat tier must not care
    sp.put(chains[2][0], chains[2][1], None)
    sp.put(chains[1][0], chains[1][1], None)
    run = sp.match_from(tokens, 1, touch=False)
    assert [h for h, _ in run] == [chains[1][0], chains[2][0]]
    # block 0 never spilled: a hole truncates the run to nothing
    assert sp.match_from(tokens, 0, touch=False) == []
    # stored tokens are verified: same chain walk, different content
    other = tokens[:4] + [99, 98, 97, 96] + tokens[8:]
    assert sp.match_from(other, 1, touch=False) == []
    # overflow drops the OLDEST entry, truncating (not corrupting) runs
    sp.put(chains[0][0], chains[0][1], None)
    assert chains[2][0] not in sp
    assert len(sp.match_from(tokens, 0, touch=False)) == 2
    assert sp.stats()["dropped"] == 1
    # take() removes and counts a promotion
    sp.take(chains[0][0])
    assert chains[0][0] not in sp and sp.promoted == 1


def test_spill_pool_zero_capacity_accepts_nothing():
    sp = HostSpillPool(0, BS)
    chains = list(chain_hashes(range(1, 5), BS))
    assert not sp.put(chains[0][0], chains[0][1], None)
    assert len(sp) == 0 and sp.spilled == 0


# ---------------------------------------------------------------------------
# eviction -> spill -> prefetch promotion (bookkeeping tier)
# ---------------------------------------------------------------------------

def test_eviction_spills_and_prefetch_promotes():
    pc = PrefixCache(num_blocks=4, block_size=BS, spill_blocks=8)
    prompt = list(range(1, 17))                    # 4 full blocks
    assert pc.acquire(1, prompt, 0, 16)
    pc.commit(1, prompt)
    pc.release(1)                                  # all 4 retained (LRU)
    assert pc.match_tokens(prompt) == 12           # (16-1)//4*4
    # a disjoint allocation evicts everything; the host tier catches it
    assert pc.acquire(2, list(range(100, 116)), 0, 16)
    assert pc.spilled_blocks == 4
    assert pc.match_tokens(prompt) == 0            # gone from HBM...
    assert pc.match_tokens_tiered(prompt) == 12    # ...but not from reach
    pc.release(2)                                  # uncommitted: blocks free
    promoted = pc.prefetch(prompt)
    assert promoted == 12                          # hit cap: 3 of 4 blocks
    assert pc.match_tokens(prompt) == 12           # resident again
    assert pc.spill.promoted == 3
    # conservation held throughout
    a = pc.allocator
    assert a.free_blocks + a.cached_blocks + a.used_blocks == 4


def test_prefetch_extends_partial_hbm_prefix_only_contiguously():
    pc = PrefixCache(num_blocks=8, block_size=BS, spill_blocks=8)
    prompt = list(range(1, 33))                    # 8 blocks
    assert pc.acquire(1, prompt, 0, 32)
    pc.commit(1, prompt)
    pc.release(1)
    # evict the whole chain into the host tier
    assert pc.acquire(2, list(range(100, 132)), 0, 32)
    assert pc.spilled_blocks == 8
    pc.release(2)
    # drop one mid-chain entry from the host tier -> the promotion run
    # must stop at the hole, not skip over it
    hole = list(chain_hashes(prompt, BS))[2][0]
    pc.spill.take(hole)
    assert pc.prefetch(prompt) == 8                # blocks 0..1 only
    assert pc.match_tokens(prompt) == 8


def test_tiered_match_is_pure():
    pc = PrefixCache(num_blocks=4, block_size=BS, spill_blocks=8)
    prompt = list(range(1, 17))
    assert pc.acquire(1, prompt, 0, 16)
    pc.commit(1, prompt)
    pc.release(1)
    assert pc.acquire(2, list(range(100, 116)), 0, 16)
    free = pc.allocator.free_blocks
    spilled = pc.spilled_blocks
    for _ in range(3):
        assert pc.match_tokens_tiered(prompt) == 12
    assert pc.allocator.free_blocks == free
    assert pc.spilled_blocks == spilled


# ---------------------------------------------------------------------------
# tier conservation under interleaved lifecycle ops
# ---------------------------------------------------------------------------

_BASE = list(range(1, 33))
PROMPTS = [
    _BASE,                                         # 8 blocks
    _BASE[:16] + list(range(50, 66)),              # shares 4 blocks
    _BASE[:8] + list(range(70, 94)),               # shares 2 blocks
    list(range(200, 224)),                         # disjoint, 6 blocks
]
TIER_OPS = ("acquire", "commit", "release", "prefetch")


def run_tiered_ops(ops, num_blocks, spill_blocks):
    pc = PrefixCache(num_blocks, BS, spill_blocks=spill_blocks)
    a = pc.allocator
    live = {}                                      # rid -> prompt
    for op, rid, pi in ops:
        prompt = PROMPTS[pi % len(PROMPTS)]
        if op == "acquire":
            if rid in live:
                continue
            hit = pc.match_tokens(prompt)
            total = len(prompt) + 2 * BS
            if pc.can_acquire(prompt, hit, total):
                assert pc.acquire(rid, prompt, hit, total)
                live[rid] = prompt
        elif op == "commit":
            if rid in live:
                pc.commit(rid, live[rid])
        elif op == "release":
            if rid in live:
                live.pop(rid)
                pc.release(rid)
        else:                                      # prefetch
            pc.prefetch(prompt)
        # HBM conservation after EVERY op, spill or no spill
        assert a.free_blocks + a.cached_blocks + a.used_blocks \
            == num_blocks
        for r in live:
            assert a.holds(r)
        # host-tier conservation: everything ever accepted is still
        # resident, was promoted back, or was LRU-dropped
        if pc.spill is not None:
            s = pc.spill.stats()
            assert s["spilled"] == (s["resident"] + s["promoted"]
                                    + s["dropped"])
            assert s["resident"] <= spill_blocks
        # the tiered view never reports less than HBM alone
        assert pc.match_tokens_tiered(prompt) >= pc.match_tokens(prompt)
    for rid in list(live):
        pc.release(rid)
    assert a.used_blocks == 0
    assert a.free_blocks + a.cached_blocks == num_blocks


def test_tier_conservation_interleaved_seeded():
    for seed in range(30):
        rng = random.Random(seed)
        ops = [(rng.choice(TIER_OPS), rng.randrange(6), rng.randrange(8))
               for _ in range(120)]
        run_tiered_ops(ops, num_blocks=rng.randrange(4, 24),
                       spill_blocks=rng.choice([0, 2, 8, 32]))


try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:                # seeded smoke test above still runs
    st = None

if st is not None:
    @given(ops=st.lists(
        st.tuples(st.sampled_from(TIER_OPS), st.integers(0, 7),
                  st.integers(0, 7)),
        min_size=1, max_size=150),
        num_blocks=st.integers(4, 32), spill_blocks=st.integers(0, 32))
    @settings(max_examples=150, deadline=None)
    def test_tier_conservation_invariants(ops, num_blocks, spill_blocks):
        run_tiered_ops(ops, num_blocks, spill_blocks)


# ---------------------------------------------------------------------------
# telemetry span anchoring
# ---------------------------------------------------------------------------

def _finished_request(t0, ttft=0.2, tpot=0.02):
    r = Request(prompt_len=10, max_new_tokens=2, arrival=t0)
    r.record_token(t0 + ttft)
    r.record_token(t0 + ttft + tpot)
    return r


def test_telemetry_rates_divide_by_observed_span():
    """A window anchored at a nonzero start (wall clock, mid-run attach)
    must report rates per second OBSERVED — not per second since the
    time origin, which deflated early goodput by up to window/elapsed."""
    tw = TelemetryWindow(BAL, window=10.0)
    tw.anchor(100.0)
    r = _finished_request(100.0)
    tw.on_token(r, 100.2)
    tw.on_token(r, 100.22)
    tw.on_finish(r, 100.22)
    assert BAL.satisfied(r)
    assert tw.goodput(100.5) == pytest.approx(1 / 0.5)
    snap = tw.snapshot(100.5)
    assert snap["throughput_tok_s"] == pytest.approx(2 / 0.5)
    # pre-fix behavior: span = min(window, now) = 10.0 -> 0.1 and 0.2


def test_telemetry_anchor_is_lazy_and_idempotent():
    tw = TelemetryWindow(BAL, window=10.0)
    assert tw.goodput(123.0) == 0.0                # no events, no anchor
    r = _finished_request(50.0)
    tw.on_token(r, 50.2)                           # first event anchors
    tw.on_finish(r, 50.22)
    tw.anchor(0.0)                                 # later call: no-op
    assert tw.goodput(52.2) == pytest.approx(1 / 2.0)


def test_telemetry_virtual_clock_spans_unchanged():
    """Simulation runs anchor at 0.0 — spans (and every existing
    benchmark number) must match the old min(window, now) exactly."""
    tw = TelemetryWindow(BAL, window=10.0)
    tw.anchor(0.0)
    r = _finished_request(0.0)
    tw.on_token(r, 0.2)
    tw.on_token(r, 0.22)
    tw.on_finish(r, 0.22)
    assert tw.goodput(5.0) == pytest.approx(1 / 5.0)
    assert tw.goodput(40.0) == 0.0                 # slid out of the window


# ---------------------------------------------------------------------------
# chunk -> 0 mid-chunk stranding
# ---------------------------------------------------------------------------

def _sim_instance(chunk=16, blocks=512):
    cost = CostModel(get_config("qwen2.5-14b"), InstanceSpec(tp=4))
    return Instance(0, D_HEAVY, chunk, cost, SimExecutor(),
                    hbm_blocks=blocks, block_size=BS)


def test_chunk_zero_does_not_strand_admitted_prefill():
    """set_chunks(..., 0) reroutes QUEUED work, but a mid-chunk prefill
    already holds blocks and must keep flowing to completion."""
    inst = _sim_instance(chunk=16)
    req = Request(prompt_len=64, max_new_tokens=2, hidden_output_len=2,
                  prompt_tokens=list(range(1, 65)))
    inst.enqueue_prefill(req)
    now, _, _ = inst.run_iteration(0.0)            # 16 of 64 tokens in
    assert inst.allocator.holds(req.rid)
    assert 0 < req.prefill_remaining < 64
    inst.chunk_size = 0                            # slider zeroed mid-chunk
    guard = 0
    while req.prefill_remaining > 0 and guard < 20:
        dur, done, _ = inst.run_iteration(now)
        now += dur
        guard += 1
    assert req.prefill_remaining == 0, "admitted prefill stranded"
    assert req.first_token_time is not None


def test_chunk_zero_with_decode_population_still_finishes_head():
    """The regression also bites when chunk_size minus the decode batch
    width zeroes the budget: the admitted head must still progress."""
    inst = _sim_instance(chunk=8)
    dec = [Request(prompt_len=8, max_new_tokens=30, hidden_output_len=30,
                   prompt_tokens=list(range(100 + 10 * i, 108 + 10 * i)))
           for i in range(8)]
    for r in dec:
        inst.enqueue_prefill(r)
    pre = Request(prompt_len=40, max_new_tokens=2, hidden_output_len=2,
                  prompt_tokens=list(range(1, 41)))
    now = 0.0
    for _ in range(8):                             # prefill the decoders
        dur, done, _ = inst.run_iteration(now)
        now += dur
        for r in done:
            inst.admit_decode(r)
    inst.enqueue_prefill(pre)
    guard = 0
    while pre.prefill_remaining > 0 and guard < 300:
        dur, done, _ = inst.run_iteration(now)
        now += dur or 0.01
        guard += 1
        for r in done:
            inst.admit_decode(r)
    assert pre.prefill_remaining == 0


# ---------------------------------------------------------------------------
# cross-instance hot-prefix replication
# ---------------------------------------------------------------------------

def _hot_prefix_requests(base, n, spacing=0.5, tail=64):
    return [Request(prompt_len=len(base) + tail, max_new_tokens=4,
                    hidden_output_len=4,
                    prompt_tokens=base + list(range(10_000 + 97 * i,
                                                    10_000 + 97 * i + tail)),
                    arrival=spacing * i)
            for i in range(n)]


def test_replication_spreads_hot_prefix_across_instances():
    sc = ServingConfig(policy="taichi", sliders=Sliders(1, 1, 512, 256),
                       hbm_blocks=1024, block_size=16, prefix_cache=True)
    cluster = build_cluster(sc, BAL)
    ctl = SliderController(ControllerConfig(
        replicate=True, replicate_min_hits=2, replicate_max_paths=2,
        replicate_max_blocks=64))
    loop = ServingLoop(cluster, BAL, controller=ctl)
    base = list(range(1, 257))                     # 16 hot blocks
    for r in _hot_prefix_requests(base, 14):
        loop.submit(r)
    loop.run()
    assert all(r.state == State.FINISHED for r in loop.requests)
    assert ctl.replications > 0
    assert cluster.replication_count == ctl.replications
    assert cluster.replication_bytes > 0
    probe = base + [9999]
    holders = [i for i in cluster.instances
               if i.prefix_cache.match_tokens(probe) > 0]
    assert len(holders) == len(cluster.instances), \
        "hot prefix should be resident on every instance"
    assert sum(i.replicas_in for i in cluster.instances) > 0


def test_replication_off_by_default_and_single_instance_noop():
    sc = ServingConfig(policy="taichi", sliders=Sliders(1, 1, 512, 256),
                       hbm_blocks=1024, block_size=16, prefix_cache=True)
    cluster = build_cluster(sc, BAL)
    ctl = SliderController(ControllerConfig())     # replicate defaults off
    loop = ServingLoop(cluster, BAL, controller=ctl)
    for r in _hot_prefix_requests(list(range(1, 257)), 10):
        loop.submit(r)
    loop.run()
    assert ctl.replications == 0
    assert cluster.replication_count == 0


def test_replica_admission_never_evicts_local_content():
    pc = PrefixCache(num_blocks=4, block_size=BS)
    local = list(range(1, 17))
    assert pc.acquire(1, local, 0, 16)
    pc.commit(1, local)
    pc.release(1)                                  # 4 cached local blocks
    foreign = list(range(100, 132))
    res = pc.admit_replica(foreign, 8)
    assert res is None                             # zero free: nothing lands
    assert pc.match_tokens(local) == 12            # local cache untouched


def test_flip_during_horizon_with_replication_in_flight():
    """A drain-and-flip staged while a replication transfer is queued
    and decode horizons are in flight must land cleanly: transfers
    deliver, no request strands, no mid-horizon state extraction."""
    sc = ServingConfig(policy="taichi", sliders=Sliders(1, 1, 512, 256),
                       hbm_blocks=1024, block_size=16, prefix_cache=True)
    cluster = build_cluster(sc, BAL, async_exec=True)
    cluster.set_horizon(8)
    loop = ServingLoop(cluster, BAL)
    base = list(range(1, 257))
    reqs = _hot_prefix_requests(base, 10, spacing=0.2, tail=64)
    for r in reqs:
        loop.submit(r)
    loop.run(until=2.0)
    insts = cluster.instances
    src = max(insts, key=lambda i: i.prefix_cache.match_tokens(base + [0]))
    assert src.prefix_cache.match_tokens(base + [0]) > 0
    dst = next(i for i in insts if i is not src)
    assert cluster.replicate_prefix(src, dst, base)
    # flip the replication DESTINATION while the payload is in flight
    assert loop.flip_role(dst, P_HEAVY if dst.itype == D_HEAVY else D_HEAVY,
                          512)
    loop.run()
    assert all(r.state == State.FINISHED for r in reqs)
    assert cluster.replication_count == 1
    assert dst.pending_flip is None                # flip landed
    assert dst.prefix_cache.match_tokens(base + [0]) > 0
