"""Unit + property tests for the paper's two algorithms and the policy
corner cases (TaiChi sliders recover aggregation / disaggregation).

The hypothesis-free invariants are duplicated in tests/test_flowing.py so
the fast tier keeps Algorithm 1 coverage on a bare interpreter."""
import pytest

pytest.importorskip("hypothesis")

import hypothesis.strategies as st
from hypothesis import given, settings

from repro.configs import get_config
from repro.core import flowing
from repro.core.estimator import CostModel
from repro.core.hw import InstanceSpec
from repro.core.instance import D_HEAVY, P_HEAVY, Instance
from repro.core.latency import SLO
from repro.core.policies import Sliders
from repro.core.proxy import Proxy
from repro.engine.engine import SimExecutor
from repro.engine.request import Request
from repro.sim.simulator import ServingConfig, build_cluster, run_sim
from repro.sim.workload import SHAREGPT

COST = CostModel(get_config("qwen2.5-14b"), InstanceSpec(tp=4))


def _inst(iid=0, itype=D_HEAVY, chunk=256, blocks=64, block_size=16):
    return Instance(iid, itype, chunk, COST, SimExecutor(),
                    hbm_blocks=blocks, block_size=block_size)


def _decoding_request(inst, prompt=100, out_len=5, now=0.0):
    r = Request(prompt_len=prompt, max_new_tokens=512,
                hidden_output_len=400)
    r.prefill_pos = prompt
    r.output_len = out_len
    r.first_token_time = now
    r.tpot_reset_time = now
    r.last_token_time = now + 0.02 * max(out_len - 1, 0)
    inst.allocator.allocate(r.rid, r.context_len)
    inst.decoding[r.rid] = r
    return r


# ---------------------------------------------------------------------------
# Algorithm 1
# ---------------------------------------------------------------------------

def test_degrade_selects_longest_first_until_watermark():
    inst = _inst(blocks=100)
    reqs = [_decoding_request(inst, prompt=200, out_len=o)
            for o in (3, 50, 20, 40)]
    # usage: 4 * ceil((200+o)/16) blocks ~ 52 blocks; set watermark low to
    # force exactly the two longest out
    used = inst.allocator.used_blocks
    two_longest = sorted(reqs, key=lambda r: -r.output_len)[:2]
    release = sum(inst.allocator.blocks_for(r.context_len)
                  for r in two_longest)
    watermark = (used - release + 1) / 100
    selected = flowing.select_degrade(inst, watermark)
    assert [r.rid for r in selected] == [r.rid for r in two_longest]


def test_degrade_noop_below_watermark():
    inst = _inst(blocks=1000)
    _decoding_request(inst)
    assert flowing.select_degrade(inst, 0.95) == []


def test_degrade_ranks_on_effective_length_after_backflow():
    inst = _inst(blocks=100)
    a = _decoding_request(inst, out_len=50)
    b = _decoding_request(inst, out_len=30)
    a.tpot_reset_len = 45          # a flowed back recently -> effective 5
    sel = flowing.select_degrade(inst, watermark=0.01)
    assert sel[0].rid == b.rid, "backflowed request must rank as 'new'"


def test_backflow_selects_requests_near_tpot_slo():
    inst = _inst(itype=P_HEAVY)
    slo_tpot = 0.1
    fast = _decoding_request(inst, out_len=10)       # tpot 0.02
    slow = _decoding_request(inst, out_len=10)
    slow.last_token_time = slow.tpot_reset_time + 0.097 * 9  # tpot 0.097
    out = flowing.select_backflow(inst, slo_tpot, alpha=0.96, now=1.0)
    assert [r.rid for r in out] == [slow.rid]


def test_backflow_ignores_reset_window():
    """After a reset the request is 'new': early post-reset TPOT spikes
    with n<=1 must not trigger re-backflow."""
    inst = _inst(itype=P_HEAVY)
    r = _decoding_request(inst, out_len=20)
    r.reset_tpot_window()
    assert r.current_tpot(now=2.0) is None
    assert flowing.select_backflow(inst, 0.1, 0.96, 2.0) == []


@given(outs=st.lists(st.integers(0, 500), min_size=1, max_size=12),
       watermark=st.floats(0.01, 0.99))
@settings(max_examples=100, deadline=None)
def test_degrade_property(outs, watermark):
    """Property: after removing the selected set, usage <= watermark or
    nothing left to remove; selection is longest-first by effective len."""
    inst = _inst(blocks=max(len(outs) * 40, 60))
    reqs = [_decoding_request(inst, prompt=100, out_len=o) for o in outs]
    sel = flowing.select_degrade(inst, watermark)
    sel_ids = [r.rid for r in sel]
    assert len(sel_ids) == len(set(sel_ids))
    removed = sum(inst.allocator.blocks_for(r.context_len) for r in sel)
    remaining = inst.allocator.used_blocks - removed
    if len(sel) < len(reqs):
        assert remaining <= watermark * inst.allocator.num_blocks
    # longest-first: selected set = top-k by effective output length
    ranked = sorted(reqs, key=lambda r: -r.effective_output_len)
    top = {r.rid for r in ranked[:len(sel)]}
    # ties can reorder; compare multisets of lengths instead
    assert sorted((r.effective_output_len for r in sel), reverse=True) == \
        sorted((r.effective_output_len for r in ranked[:len(sel)]),
               reverse=True)


# ---------------------------------------------------------------------------
# Algorithm 2
# ---------------------------------------------------------------------------

def test_prefill_prefers_feasible_min_queue():
    p_inst = _inst(0, P_HEAVY, chunk=1024, blocks=4096)
    d_inst = _inst(1, D_HEAVY, chunk=256, blocks=4096)
    proxy = Proxy([p_inst, d_inst], COST, ttft_slo=30.0)
    # short request: both feasible; D-heavy has fewer queued tokens -> D
    short = Request(prompt_len=128, max_new_tokens=64)
    chosen = proxy.schedule_prefill(short, now=0.0)
    assert chosen is d_inst, "short prefill should degrade onto D-heavy"


def test_prefill_long_request_goes_to_p_heavy_under_tight_slo():
    p_inst = _inst(0, P_HEAVY, chunk=2048, blocks=4096)
    d_inst = _inst(1, D_HEAVY, chunk=128, blocks=4096)
    # preload D-heavy queue so its Q makes long requests infeasible
    for _ in range(4):
        d_inst.enqueue_prefill(Request(prompt_len=4000, max_new_tokens=8))
    tight = COST.prefill_time(8000, 2048) * 2.5
    proxy = Proxy([p_inst, d_inst], COST, ttft_slo=tight)
    long_req = Request(prompt_len=8000, max_new_tokens=64)
    chosen = proxy.schedule_prefill(long_req, now=0.0)
    assert chosen is p_inst


def test_prefill_random_fallback_when_infeasible():
    p_inst = _inst(0, P_HEAVY, chunk=1024)
    proxy = Proxy([p_inst], COST, ttft_slo=1e-9)
    r = Request(prompt_len=4096, max_new_tokens=8)
    chosen = proxy.schedule_prefill(r, now=0.0)
    assert chosen is p_inst
    assert proxy.infeasible_count == 1


def test_pure_decode_instance_never_prefils():
    d0 = _inst(0, D_HEAVY, chunk=0)
    p0 = _inst(1, P_HEAVY, chunk=1024)
    proxy = Proxy([d0, p0], COST, ttft_slo=60.0)
    for _ in range(5):
        chosen = proxy.schedule_prefill(
            Request(prompt_len=512, max_new_tokens=8), now=0.0)
        assert chosen is p0


def test_decode_placement_in_place_on_dheavy():
    p0 = _inst(0, P_HEAVY, 1024)
    d0 = _inst(1, D_HEAVY, 256)
    d1 = _inst(2, D_HEAVY, 256)
    proxy = Proxy([p0, d0, d1], COST, 10.0)
    r = Request(prompt_len=100, max_new_tokens=8)
    assert proxy.place_decode(r, d0, [d0, d1]) is d0      # in-place
    d0.allocator.allocate(999, 800)                        # load d0
    assert proxy.place_decode(r, p0, [d0, d1]) is d1      # least loaded


# ---------------------------------------------------------------------------
# Policy corner cases (sliders recover the two baselines)
# ---------------------------------------------------------------------------

def test_sliders_recover_baselines():
    slo = SLO(ttft=2.0, tpot=0.05)
    # TaiChi with s_d == s_p behaves like aggregation: every instance has
    # identical capability, so both baselines' instances match chunk sizes
    sc = ServingConfig(policy="aggregation",
                       sliders=Sliders(2, 2, 1024, 1024))
    cl = build_cluster(sc, slo)
    assert all(i.chunk_size == 1024 for i in cl.instances)
    assert len(cl.instances) == 4
    sc = ServingConfig(policy="disaggregation",
                       sliders=Sliders(2, 2, 0, 0))
    cl = build_cluster(sc, slo)
    p = [i for i in cl.instances if i.itype == P_HEAVY]
    d = [i for i in cl.instances if i.itype == D_HEAVY]
    assert all(i.chunk_size >= sc.max_ctx for i in p), \
        "disagg P instances prefill whole prompts (no chunking)"
    assert all(i.chunk_size == 0 for i in d), \
        "disagg D instances never prefill"


def test_preemption_recovers_from_memory_deadlock():
    inst = _inst(blocks=40)
    # context exactly at a block boundary so the next token needs a fresh
    # block, which is unavailable -> all decodes stall -> deadlock
    reqs = [_decoding_request(inst, prompt=155, out_len=5)
            for _ in range(3)]
    # exhaust memory so extends fail
    while inst.allocator.free_blocks > 0:
        inst.allocator.allocate(10_000 + inst.allocator.free_blocks, 16)
    dur, done, fin = inst.run_iteration(0.0)
    assert inst.preemptions >= 1
    assert inst.prefill_queue or inst.decoding
