"""Fault-tolerance layer, fast tier (sim executors): deterministic
fault injection, instance crash/quarantine/recovery with evacuation by
recompute, TRANSFER retry/corruption handling, the serving-loop
watchdog's heartbeat + probation machinery, client aborts, and the
chaos property test — randomized fault schedules under which every
submitted request terminally resolves, allocators conserve blocks, and
recovered requests stay token-exact against a fault-free oracle."""
import random

import pytest

from repro.core.cluster import FaultToleranceConfig
from repro.core.instance import (HEALTH_DEAD, HEALTH_OK,
                                 HEALTH_QUARANTINED)
from repro.core.latency import SLO
from repro.core.policies import Sliders
from repro.engine.request import Request, State, TERMINAL_STATES
from repro.serving import ServingLoop, WatchdogConfig
from repro.serving.faults import (CRASH, EXEC_ERROR, RECOVER, STALL,
                                  Fault, FaultInjector, payload_checksum)
from repro.serving.recovery import RecoveryConfig
from repro.sim.simulator import ServingConfig, build_cluster
from repro.sim.workload import SHAREGPT

BAL = SLO(ttft=1.5, tpot=0.030)
LOOSE = SLO(ttft=10.0, tpot=1.0)


def _mk_loop(policy="taichi", sliders=Sliders(2, 2, 1024, 256),
             blocks=4096, slo=LOOSE, ft=None, async_exec=False,
             recovery=None, **kw):
    sc = ServingConfig(policy=policy, sliders=sliders, hbm_blocks=blocks)
    cluster = build_cluster(sc, slo, ft=ft, async_exec=async_exec,
                            recovery=recovery)
    return ServingLoop(cluster, slo, **kw)


def _assert_conserved(cluster):
    """free + cached + used == total on every instance's allocator, and
    nothing still held once every request is terminal."""
    for inst in cluster.instances:
        a = inst.allocator
        cached = getattr(a, "cached_blocks", 0)
        assert a.free_blocks + cached + a.used_blocks == a.num_blocks, \
            f"instance {inst.iid} leaked blocks"


def _assert_all_terminal(loop):
    for r in loop.requests:
        assert r.state in TERMINAL_STATES, \
            f"request {r.rid} stuck in {r.state.value}"
        assert r.finish_time is not None
    _assert_conserved(loop.cluster)
    for inst in loop.cluster.instances:
        assert inst.allocator.used_blocks == 0, \
            f"instance {inst.iid} still holds blocks after drain"


# ---------------------------------------------------------------------------
# injector determinism
# ---------------------------------------------------------------------------

def test_random_schedule_is_deterministic():
    a = FaultInjector.random_schedule(7, [0, 1, 2, 3], t_end=4.0,
                                      recover_after=1.0,
                                      transfer_drop_p=0.3,
                                      transfer_corrupt_p=0.1)
    b = FaultInjector.random_schedule(7, [0, 1, 2, 3], t_end=4.0,
                                      recover_after=1.0,
                                      transfer_drop_p=0.3,
                                      transfer_corrupt_p=0.1)
    assert [(f.t, f.kind, f.iid) for f in a.schedule] == \
        [(f.t, f.kind, f.iid) for f in b.schedule]
    assert [a.transfer_outcome() for _ in range(64)] == \
        [b.transfer_outcome() for _ in range(64)]
    # schedule sorted by time; recover follows its crash
    ts = [f.t for f in a.schedule]
    assert ts == sorted(ts)


def test_fault_kind_validated():
    with pytest.raises(ValueError):
        Fault(1.0, "meteor", 0)


def test_payload_checksum_content_sensitivity():
    import numpy as np
    s1 = {"k": np.arange(8, dtype=np.int32), "meta": [1, 2, "x"]}
    s2 = {"k": np.arange(8, dtype=np.int32), "meta": [1, 2, "x"]}
    assert payload_checksum(s1) == payload_checksum(s2)
    s2["k"] = s2["k"].copy()
    s2["k"][3] += 1                       # one flipped element
    assert payload_checksum(s1) != payload_checksum(s2)
    assert payload_checksum(None) != payload_checksum({})


# ---------------------------------------------------------------------------
# faults disabled == identical behavior
# ---------------------------------------------------------------------------

def test_faults_off_is_bit_identical():
    reqs_a = SHAREGPT.sample_requests(60, 40.0, seed=5)
    reqs_b = SHAREGPT.sample_requests(60, 40.0, seed=5)

    plain = _mk_loop(arrivals=iter(reqs_a), steal=False)
    plain.run()
    # empty schedule + zero probabilities: the layer must be inert
    armed = _mk_loop(arrivals=iter(reqs_b), steal=False,
                     faults=FaultInjector(),
                     watchdog=WatchdogConfig())
    armed.run()
    assert [r.finish_time for r in reqs_b] == \
        [r.finish_time for r in reqs_a]
    assert [r.output_len for r in reqs_b] == \
        [r.output_len for r in reqs_a]
    assert "faults" not in armed.snapshot()
    snap = armed.snapshot()
    assert all("health" not in g for g in snap["instances"])


# ---------------------------------------------------------------------------
# crash: evacuation by recompute vs fail-stop
# ---------------------------------------------------------------------------

def test_crash_evacuates_and_requests_still_finish():
    reqs = SHAREGPT.sample_requests(60, 60.0, seed=2)
    loop = _mk_loop(arrivals=iter(reqs), steal=False)
    cluster = loop.cluster
    loop.run(until=0.4)
    victim = max(cluster.instances,
                 key=lambda i: len(i.decoding) + len(i.prefill_queue))
    evicted = cluster.fail_instance(victim)
    assert victim.health == HEALTH_DEAD
    assert evicted and cluster.instance_failures == 1
    assert cluster.evacuated_requests == len(evicted)
    # the dead instance holds nothing and caches nothing
    assert victim.allocator.used_blocks == 0
    assert not victim.has_work()
    loop.run()
    _assert_all_terminal(loop)
    recovered = [r for r in loop.requests if r.n_recoveries > 0]
    assert recovered, "evacuation must have re-routed someone"
    for r in recovered:
        assert r.state == State.FINISHED
        assert r.output_len == r.target_output_len   # token-exact
    assert all(r.state == State.FINISHED for r in loop.requests)
    counters = cluster.fault_counters()
    assert counters["instance_failures"] == 1
    assert counters["evacuated_requests"] == len(evicted)


def test_fail_stop_fails_victims_terminally():
    reqs = SHAREGPT.sample_requests(60, 60.0, seed=2)
    loop = _mk_loop(arrivals=iter(reqs), steal=False,
                    ft=FaultToleranceConfig.fail_stop())
    cluster = loop.cluster
    loop.run(until=0.4)
    victim = max(cluster.instances,
                 key=lambda i: len(i.decoding) + len(i.prefill_queue))
    evicted = cluster.fail_instance(victim)
    loop.run()
    _assert_all_terminal(loop)
    failed = [r for r in loop.requests if r.state == State.FAILED]
    assert len(failed) >= len(evicted)
    for r in evicted:
        assert r.state == State.FAILED
        assert r.finish_reason.startswith("instance_")
    assert loop.failed_count == len(failed)
    assert loop.telemetry.total_failed == len(failed)


def test_dead_instance_excluded_from_placement():
    loop = _mk_loop(steal=False)
    cluster = loop.cluster
    dead = cluster.instances[0]
    cluster.fail_instance(dead)
    reqs = SHAREGPT.sample_requests(40, 80.0, seed=3)
    for r in reqs:
        loop.submit(r)
    loop.run()
    _assert_all_terminal(loop)
    assert all(r.prefill_instance != dead.iid for r in loop.requests
               if r.prefill_instance is not None)
    assert all(r.decode_instance != dead.iid for r in loop.requests
               if r.decode_instance is not None)


def test_all_instances_down_fails_not_hangs():
    loop = _mk_loop(steal=False)
    for inst in loop.cluster.instances:
        loop.cluster.fail_instance(inst)
    h = loop.submit(Request(prompt_len=64, max_new_tokens=8))
    loop.run()
    assert h.failed and h.req.finish_reason == "no_capacity"


def test_recover_instance_rejoins_rotation():
    loop = _mk_loop(steal=False)
    cluster = loop.cluster
    inst = cluster.instances[0]
    cluster.fail_instance(inst)
    assert cluster.recover_instance(inst)
    assert inst.health == HEALTH_OK
    assert not cluster.recover_instance(inst)      # idempotent
    reqs = SHAREGPT.sample_requests(40, 80.0, seed=4)
    for r in reqs:
        loop.submit(r)
    loop.run()
    _assert_all_terminal(loop)
    assert any(r.prefill_instance == inst.iid for r in loop.requests)


# ---------------------------------------------------------------------------
# TRANSFER faults: retry, recompute fallback, corruption detection
# ---------------------------------------------------------------------------

def test_transfer_drops_are_retried_with_backoff():
    reqs = SHAREGPT.sample_requests(50, 50.0, seed=6)
    inj = FaultInjector(seed=6, transfer_drop_p=0.3)
    loop = _mk_loop(policy="disaggregation", arrivals=iter(reqs),
                    steal=False, faults=inj)
    loop.run()
    _assert_all_terminal(loop)
    assert loop.cluster.transfer_retries > 0
    assert inj.transfer_drops > 0
    assert all(r.state == State.FINISHED for r in loop.requests)
    for r in loop.requests:
        assert r.output_len == r.target_output_len


def test_transfer_exhaustion_falls_back_to_recompute():
    reqs = SHAREGPT.sample_requests(30, 50.0, seed=7)
    # every landing drops: each transfer exhausts its retries, then the
    # request must recompute its way to completion (placement retargets
    # to the prefill instance itself once every D-move keeps failing,
    # or the recovery bound trips -> FAILED; never a hang)
    inj = FaultInjector(seed=7, transfer_drop_p=1.0)
    loop = _mk_loop(policy="disaggregation", arrivals=iter(reqs),
                    steal=False, faults=inj)
    loop.run()
    _assert_all_terminal(loop)
    assert loop.cluster.transfer_recomputes > 0
    for r in loop.requests:
        assert r.state in (State.FINISHED, State.FAILED)
        if r.state == State.FAILED:
            assert r.finish_reason in ("too_many_recoveries",
                                       "transfer_failed")


def test_transfer_corruption_detected_and_retried():
    reqs = SHAREGPT.sample_requests(40, 50.0, seed=8)
    inj = FaultInjector(seed=8, transfer_corrupt_p=0.25)
    loop = _mk_loop(policy="disaggregation", arrivals=iter(reqs),
                    steal=False, faults=inj)
    loop.run()
    _assert_all_terminal(loop)
    assert loop.cluster.transfer_corruptions > 0
    assert loop.cluster.transfer_retries > 0
    assert all(r.state == State.FINISHED for r in loop.requests)


def test_unverified_corruption_delivers_but_counts():
    reqs = SHAREGPT.sample_requests(30, 50.0, seed=9)
    inj = FaultInjector(seed=9, transfer_corrupt_p=0.5)
    ft = FaultToleranceConfig(verify_transfers=False)
    loop = _mk_loop(policy="disaggregation", arrivals=iter(reqs),
                    steal=False, faults=inj, ft=ft)
    loop.run()
    _assert_all_terminal(loop)
    assert loop.cluster.transfer_corruptions > 0
    assert loop.cluster.transfer_retries == 0      # delivered, not retried


# ---------------------------------------------------------------------------
# watchdog: heartbeat quarantine + probation re-admission
# ---------------------------------------------------------------------------

def test_stall_trips_watchdog_and_probation_readmits():
    # the heartbeat keys on the dispatch/commit split's step deadline,
    # so this runs the async pipeline (the live path's event shape)
    reqs = SHAREGPT.sample_requests(120, 60.0, seed=10)
    inj = FaultInjector([Fault(0.3, STALL, 0, duration=5.0)])
    wd = WatchdogConfig(heartbeat_timeout=0.3, probation=0.5,
                        check_every=0.05)
    loop = _mk_loop(arrivals=iter(reqs), steal=False, async_exec=True,
                    faults=inj, watchdog=wd)
    loop.run()
    _assert_all_terminal(loop)
    cluster = loop.cluster
    assert inj.fired[STALL] == 1
    assert cluster.quarantines >= 1, "watchdog never caught the stall"
    assert cluster.instance_recoveries >= 1, "probation never re-admitted"
    assert all(i.health == HEALTH_OK for i in cluster.instances)
    kinds = [e["kind"] for e in loop.log.events]
    assert "quarantine" in kinds and "readmit" in kinds
    assert all(r.state == State.FINISHED for r in loop.requests)


def test_probation_backs_off_per_repeat_offense():
    loop = _mk_loop(steal=False,
                    watchdog=WatchdogConfig(probation=1.0,
                                            probation_backoff=2.0,
                                            max_probation=3.0))
    inst = loop.cluster.instances[0]
    assert loop._start_probation(inst, 0.0) == 1.0
    assert loop._start_probation(inst, 0.0) == 2.0
    assert loop._start_probation(inst, 0.0) == 3.0
    assert loop._start_probation(inst, 0.0) == 3.0  # capped


def test_exec_error_quarantines_and_work_recovers():
    reqs = SHAREGPT.sample_requests(80, 60.0, seed=11)
    inj = FaultInjector([Fault(0.3, EXEC_ERROR, 1)])
    wd = WatchdogConfig(probation=0.5, check_every=0.05)
    loop = _mk_loop(arrivals=iter(reqs), steal=False,
                    faults=inj, watchdog=wd)
    loop.run()
    _assert_all_terminal(loop)
    cluster = loop.cluster
    assert cluster.exec_errors == 1
    assert "InjectedFault" in cluster.last_exec_error
    assert cluster.quarantines >= 1
    assert all(r.state == State.FINISHED for r in loop.requests)
    # the armed executor restored itself: one shot, not a dead instance
    assert cluster.instances[1].health == HEALTH_OK


def test_crash_then_scheduled_recover():
    reqs = SHAREGPT.sample_requests(80, 60.0, seed=12)
    inj = FaultInjector([Fault(0.3, CRASH, 0), Fault(1.0, RECOVER, 0)])
    loop = _mk_loop(arrivals=iter(reqs), steal=False, faults=inj)
    loop.run()
    _assert_all_terminal(loop)
    cluster = loop.cluster
    assert cluster.instance_failures == 1
    assert cluster.instance_recoveries == 1
    assert cluster.instances[0].health == HEALTH_OK
    assert all(r.state == State.FINISHED for r in loop.requests)


# ---------------------------------------------------------------------------
# client aborts
# ---------------------------------------------------------------------------

def test_abort_mid_flight_frees_blocks_and_resolves():
    reqs = SHAREGPT.sample_requests(40, 60.0, seed=13)
    loop = _mk_loop(arrivals=iter(reqs), steal=False)
    loop.run(until=0.3)
    live = [r for r in loop.requests if r.state not in TERMINAL_STATES]
    assert live, "nothing in flight to abort"
    for r in live:
        loop.abort(r.rid)
    loop.run()
    _assert_all_terminal(loop)
    aborted = [r for r in loop.requests if r.state == State.CANCELLED]
    assert aborted
    for r in aborted:
        assert r.finish_reason == "abort"
        for inst in loop.cluster.instances:
            assert not inst.allocator.holds(r.rid)
    assert loop.aborted_count == len(aborted)
    assert loop.telemetry.total_aborted == len(aborted)
    assert loop.snapshot()["faults"]["aborted"] == len(aborted)


def test_abort_unknown_and_finished_rids():
    loop = _mk_loop(steal=False)
    assert not loop.abort(10 ** 9)              # never submitted
    h = loop.submit(Request(prompt_len=32, max_new_tokens=4))
    loop.run()
    assert h.done
    assert loop.abort(h.req.rid)                # terminal: no-op True
    assert h.req.state == State.FINISHED


def test_abort_from_admission_queue_cancels_immediately():
    from repro.frontend.admission import AdmissionConfig
    loop = _mk_loop(steal=False,
                    admission=AdmissionConfig(max_depth=16,
                                              max_inflight=0))
    h = loop.submit(Request(prompt_len=32, max_new_tokens=4))
    assert len(loop.admission) == 1
    assert loop.abort(h.req.rid)
    assert h.cancelled and h.req.finish_reason == "abort"
    assert len(loop.admission) == 0
    assert loop.aborted_count == 1


# ---------------------------------------------------------------------------
# chaos property test: randomized schedules, nothing lost, token-exact
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(6))
def test_chaos_no_request_lost_and_token_exact(seed):
    n, qps = 70, 50.0
    oracle = SHAREGPT.sample_requests(n, qps, seed=100 + seed)
    base = _mk_loop(arrivals=iter(oracle), steal=False)
    base.run()
    want = {r.rid - oracle[0].rid: r.output_len for r in oracle}

    reqs = SHAREGPT.sample_requests(n, qps, seed=100 + seed)
    t_end = max(r.arrival for r in reqs)
    inj = FaultInjector.random_schedule(
        seed, [0, 1, 2, 3],             # iids of the 2P+2D pool below
        t_end=t_end, n_crashes=1, n_stalls=2, n_exec_errors=1,
        stall_duration=0.5, recover_after=0.8,
        transfer_drop_p=0.05, transfer_corrupt_p=0.02)
    rng = random.Random(seed)
    loop = _mk_loop(arrivals=iter(reqs), steal=False, faults=inj,
                    watchdog=WatchdogConfig(heartbeat_timeout=0.4,
                                            probation=0.5,
                                            check_every=0.05))
    # interleave a few client aborts with the fault schedule
    loop.run(until=t_end * 0.5)
    live = [r for r in loop.requests if r.state not in TERMINAL_STATES]
    for r in rng.sample(live, min(3, len(live))):
        loop.abort(r.rid)
    loop.run()

    # 1) every submitted request terminally resolved
    _assert_all_terminal(loop)
    # 2) finished requests are greedy token-exact vs the fault-free
    #    oracle (same workload seed => same per-request target)
    first = reqs[0].rid
    for r in loop.requests:
        if r.state == State.FINISHED:
            assert r.output_len == want[r.rid - first], \
                f"request {r.rid} lost or duplicated tokens"
    # 3) faults actually fired and were survived
    assert sum(inj.fired.values()) >= 1
    recovered = [r for r in loop.requests
                 if r.n_recoveries > 0 and r.state == State.FINISHED]
    if loop.cluster.evacuated_requests:
        assert recovered or loop.cluster.failed_count \
            or loop.cluster.aborted_count
    # 4) loop-side and cluster-side outcome counters agree
    fc = loop.cluster.fault_counters()
    assert fc["failed"] == loop.failed_count
    assert fc["aborted"] == loop.aborted_count


# ---------------------------------------------------------------------------
# warm recovery: checkpoints, restore, bit-identical when off
# ---------------------------------------------------------------------------

def test_recovery_disabled_config_is_inert():
    reqs_a = SHAREGPT.sample_requests(60, 40.0, seed=5)
    reqs_b = SHAREGPT.sample_requests(60, 40.0, seed=5)
    plain = _mk_loop(arrivals=iter(reqs_a), steal=False)
    plain.run()
    # enable=False must leave Cluster.recovery None: bit-identical run
    armed = _mk_loop(arrivals=iter(reqs_b), steal=False,
                     recovery=RecoveryConfig(enable=False))
    assert armed.cluster.recovery is None
    armed.run()
    assert [r.finish_time for r in reqs_b] == \
        [r.finish_time for r in reqs_a]
    assert [r.output_len for r in reqs_b] == \
        [r.output_len for r in reqs_a]
    assert "recovery" not in armed.snapshot()


def test_recovery_on_without_faults_changes_nothing():
    """Checkpointing is pure observation: with no crash there is never
    a restore, and the served schedule matches a recovery-less run."""
    reqs_a = SHAREGPT.sample_requests(60, 40.0, seed=5)
    reqs_b = SHAREGPT.sample_requests(60, 40.0, seed=5)
    plain = _mk_loop(arrivals=iter(reqs_a), steal=False)
    plain.run()
    warm = _mk_loop(arrivals=iter(reqs_b), steal=False,
                    recovery=RecoveryConfig(enable=True))
    warm.run()
    assert [r.finish_time for r in reqs_b] == \
        [r.finish_time for r in reqs_a]
    assert [r.output_len for r in reqs_b] == \
        [r.output_len for r in reqs_a]
    rc = warm.snapshot()["recovery"]
    assert rc["checkpoints"] > 0
    assert rc["warm_restores"] == 0


def test_warm_restore_resumes_from_checkpoint():
    reqs = SHAREGPT.sample_requests(80, 60.0, seed=12)
    oracle = SHAREGPT.sample_requests(80, 60.0, seed=12)
    base = _mk_loop(arrivals=iter(oracle), steal=False)
    base.run()
    want = {r.rid - oracle[0].rid: r.output_len for r in oracle}

    # instance 2 is a decode-role instance under Sliders(2, 2, ...) —
    # crashing it catches mid-decode victims with checkpointed progress
    inj = FaultInjector([Fault(0.5, CRASH, 2), Fault(1.2, RECOVER, 2)])
    loop = _mk_loop(arrivals=iter(reqs), steal=False, faults=inj,
                    recovery=RecoveryConfig(enable=True,
                                            checkpoint_tokens=8))
    # count streamed tokens per request at the sink: a warm restore must
    # never re-emit a token index that already streamed (no double
    # emission across the restore)
    emitted = {}
    orig_sinks = {i.iid: i.token_sink for i in loop.cluster.instances}

    def counting(iid):
        def sink(req, t):
            emitted[req.rid] = emitted.get(req.rid, 0) + 1
            orig_sinks[iid](req, t)
        return sink
    for i in loop.cluster.instances:
        i.token_sink = counting(i.iid)
    loop.run()

    _assert_all_terminal(loop)
    first = reqs[0].rid
    for r in loop.requests:
        assert r.state == State.FINISHED
        assert r.output_len == want[r.rid - first]
        # every emission was a fresh token index
        assert emitted.get(r.rid, 0) == r.output_len
    rc = loop.cluster.recovery_counters()
    assert rc["warm_restores"] > 0, "crash victims never resumed warm"
    assert rc["warm_restored_tokens"] > 0
    assert rc["checkpoints"] > 0
    snap = loop.snapshot()
    assert snap["recovery"]["warm_restores"] == rc["warm_restores"]


@pytest.mark.parametrize("seed", range(4))
def test_warm_chaos_no_request_lost_and_token_exact(seed):
    """The chaos property machine with warm recovery enabled: same
    invariants as the cold-path chaos test — conservation, terminal
    resolution, greedy token-exactness — plus no double emission."""
    n, qps = 70, 50.0
    oracle = SHAREGPT.sample_requests(n, qps, seed=200 + seed)
    base = _mk_loop(arrivals=iter(oracle), steal=False)
    base.run()
    want = {r.rid - oracle[0].rid: r.output_len for r in oracle}

    reqs = SHAREGPT.sample_requests(n, qps, seed=200 + seed)
    t_end = max(r.arrival for r in reqs)
    inj = FaultInjector.random_schedule(
        seed, [0, 1, 2, 3], t_end=t_end, n_crashes=2, n_stalls=2,
        n_exec_errors=1, stall_duration=0.5, recover_after=0.8,
        transfer_drop_p=0.05, transfer_corrupt_p=0.02)
    rng = random.Random(seed)
    loop = _mk_loop(arrivals=iter(reqs), steal=False, faults=inj,
                    recovery=RecoveryConfig(enable=True,
                                            checkpoint_tokens=8),
                    watchdog=WatchdogConfig(heartbeat_timeout=0.4,
                                            probation=0.5,
                                            check_every=0.05))
    emitted = {}
    orig_sinks = {i.iid: i.token_sink for i in loop.cluster.instances}

    def counting(iid):
        def sink(req, t):
            emitted[req.rid] = emitted.get(req.rid, 0) + 1
            orig_sinks[iid](req, t)
        return sink
    for i in loop.cluster.instances:
        i.token_sink = counting(i.iid)
    loop.run(until=t_end * 0.5)
    live = [r for r in loop.requests if r.state not in TERMINAL_STATES]
    for r in rng.sample(live, min(3, len(live))):
        loop.abort(r.rid)
    loop.run()

    _assert_all_terminal(loop)
    first = reqs[0].rid
    for r in loop.requests:
        if r.state == State.FINISHED:
            assert r.output_len == want[r.rid - first], \
                f"request {r.rid} lost or duplicated tokens"
            assert emitted.get(r.rid, 0) == r.output_len, \
                f"request {r.rid} double-emitted across a restore"
    assert sum(inj.fired.values()) >= 1
    rc = loop.cluster.recovery_counters()
    # checkpoints always flow; a restore only if a crash caught victims
    assert rc["checkpoints"] > 0
    fc = loop.cluster.fault_counters()
    assert fc["failed"] == loop.failed_count
    assert fc["aborted"] == loop.aborted_count


# ---------------------------------------------------------------------------
# post-crash KV re-replication
# ---------------------------------------------------------------------------

def test_crash_rereplicates_hot_prefix_immediately():
    """When a hot-prefix replica holder dies, the manager re-establishes
    the path on the coldest healthy peer at fail time instead of waiting
    for the controller's next replication epoch."""
    from repro.serving import ControllerConfig, SliderController
    sc = ServingConfig(policy="taichi", sliders=Sliders(2, 1, 512, 256),
                       hbm_blocks=1024, block_size=16, prefix_cache=True)
    cluster = build_cluster(sc, LOOSE,
                            recovery=RecoveryConfig(enable=True))
    ctl = SliderController(ControllerConfig(
        replicate=True, replicate_min_hits=2, replicate_max_paths=2,
        replicate_max_blocks=64))
    loop = ServingLoop(cluster, LOOSE, controller=ctl)
    base = list(range(1, 257))                     # 16 hot blocks
    for i in range(14):
        tail = list(range(10_000 + 97 * i, 10_000 + 97 * i + 64))
        loop.submit(Request(prompt_len=len(base) + 64, max_new_tokens=4,
                            hidden_output_len=4,
                            prompt_tokens=base + tail,
                            arrival=0.5 * i))
    loop.run()
    assert ctl.replications > 0, "no replica to lose — test is vacuous"
    rec = cluster.recovery
    key, holders = next(iter(rec._replicas.items()))
    victim = cluster._inst_by_id[next(iter(holders))]
    before = rec.rereplications
    cluster.fail_instance(victim)
    assert rec.rereplications > before, \
        "crash of a replica holder never re-replicated its path"
    loop.run()                                     # land the transfer
    survivors = [i for i in cluster.instances
                 if i is not victim
                 and i.prefix_cache.match_tokens(list(key) + [0]) > 0]
    assert survivors, "re-replicated path landed nowhere healthy"
    _assert_conserved(cluster)


# ---------------------------------------------------------------------------
# retry-backoff jitter
# ---------------------------------------------------------------------------

def test_retry_jitter_seeded_and_bounded():
    a = FaultInjector(seed=3)
    b = FaultInjector(seed=3)
    seq_a = [a.retry_jitter(0.05, prev, 0.8)
             for prev in (0.05, 0.1, 0.4, 2.0)]
    seq_b = [b.retry_jitter(0.05, prev, 0.8)
             for prev in (0.05, 0.1, 0.4, 2.0)]
    assert seq_a == seq_b                      # same seed, same delays
    for d, prev in zip(seq_a, (0.05, 0.1, 0.4, 2.0)):
        assert 0.05 <= d <= 0.8                # [base, cap] always
        assert d <= max(0.05, prev) * 3.0
    # the jitter stream is independent of transfer outcomes
    c = FaultInjector(seed=3, transfer_drop_p=0.3)
    outcomes = [c.transfer_outcome() for _ in range(16)]
    c2 = FaultInjector(seed=3, transfer_drop_p=0.3)
    c2.retry_jitter(0.05, 0.1, 0.8)            # consume jitter first
    assert [c2.transfer_outcome() for _ in range(16)] == outcomes
