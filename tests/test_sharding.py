"""Sharding-rule tests without real meshes: every param/cache spec must
divide its dimension (jit input shardings reject padding), for all archs
and both production mesh geometries."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.analysis.roofline import (collective_bytes_from_hlo,
                                     model_flops_per_device)
from repro.configs import ASSIGNED, get_config
from repro.distributed import sharding as shd
from repro.models import transformer as tf


class FakeMesh:
    """Just enough mesh surface for the spec builders."""

    def __init__(self, shape, names):
        import numpy as np
        self.axis_names = names
        self.devices = np.empty(shape, dtype=object)


SINGLE = FakeMesh((16, 16), ("data", "model"))
MULTI = FakeMesh((2, 16, 16), ("pod", "data", "model"))


def _axis_sizes(mesh):
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _check_divisible(spec_tree, shape_tree, mesh, what):
    sizes = _axis_sizes(mesh)

    def check(path, spec, leaf):
        entries = list(spec)
        for i, e in enumerate(entries):
            if e is None:
                continue
            axes = e if isinstance(e, tuple) else (e,)
            total = 1
            for a in axes:
                total *= sizes[a]
            assert leaf.shape[i] % total == 0, (
                what, jax.tree_util.keystr(path), leaf.shape, i, e)

    jax.tree_util.tree_map_with_path(
        lambda p, s, l: check(p, s, l), spec_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, P))


@pytest.mark.parametrize("arch", ASSIGNED)
@pytest.mark.parametrize("mesh", [SINGLE, MULTI], ids=["16x16", "2x16x16"])
def test_param_specs_divisible(arch, mesh):
    cfg = get_config(arch)
    shapes = tf.abstract_params(cfg)
    specs = shd.param_specs(cfg, _axis_sizes(mesh)["model"])
    _check_divisible(specs, shapes, mesh, f"{arch} params")


@pytest.mark.parametrize("arch", ASSIGNED)
@pytest.mark.parametrize("mesh", [SINGLE, MULTI], ids=["16x16", "2x16x16"])
@pytest.mark.parametrize("batch,seq", [(128, 32768), (1, 524288)])
def test_cache_specs_divisible(arch, mesh, batch, seq):
    cfg = get_config(arch)
    shapes = tf.abstract_cache(cfg, batch, seq, cross_len=1500)
    specs = shd.cache_specs(cfg, mesh, batch, seq, cross_len=1500)
    _check_divisible(specs, shapes, mesh, f"{arch} cache")


@pytest.mark.parametrize("arch", ASSIGNED)
def test_every_big_weight_is_sharded(arch):
    """No >=64MiB tensor may end up fully replicated (HBM discipline)."""
    cfg = get_config(arch)
    shapes = tf.abstract_params(cfg)
    specs = shd.param_specs(cfg, 16)

    def check(path, spec, leaf):
        if leaf.size * 2 >= 64 * 1024 ** 2:
            assert any(e is not None for e in spec), (
                arch, jax.tree_util.keystr(path), leaf.shape)

    jax.tree_util.tree_map_with_path(
        check, specs, shapes, is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# roofline HLO parser
# ---------------------------------------------------------------------------

def test_collective_parser_counts_ops():
    hlo = """
  %ag = bf16[256,4096]{1,0} all-gather(%x), dimensions={0}
  %ar.1 = f32[128]{0} all-reduce(%y), to_apply=%sum
  %rs = bf16[16,1024]{1,0} reduce-scatter(%z), dimensions={0}
  %a2a.2 = f32[8,64]{1,0} all-to-all(%w), dimensions={0}
  %cp = u32[4]{0} collective-permute(%v), source_target_pairs={{0,1}}
  %ags = bf16[2,2]{1,0} all-gather-start(%q), dimensions={0}
  %agd = bf16[2,2]{1,0} all-gather-done(%ags)
"""
    got = collective_bytes_from_hlo(hlo)
    expect = (256 * 4096 * 2 + 128 * 4 + 16 * 1024 * 2 + 8 * 64 * 4
              + 4 * 4 + 2 * 2 * 2)   # -done NOT counted
    assert got == expect, (got, expect)


def test_collective_parser_tuple_shapes():
    hlo = ("  %ar = (f32[8]{0}, f32[16]{0}) all-reduce(%a, %b), "
           "to_apply=%sum\n")
    assert collective_bytes_from_hlo(hlo) == 8 * 4 + 16 * 4


def test_model_flops_moe_uses_active_params():
    dense = model_flops_per_device("qwen3-14b", "train_4k", 256)
    moe = model_flops_per_device("arctic-480b", "train_4k", 256)
    # arctic active ~15.6B ~ qwen3's 14.8B: same order, NOT 480/15 apart
    assert 0.5 < moe / dense < 2.5
