"""Paper Figures 5-6: latency distributions across configurations —
aggregation chunk-size sweep (CP128..CP2048) and disaggregation PD-ratio
sweep (P1D3..P3D1) at fixed load.  Shows the TTFT/TPOT trade-off each
knob navigates (latency shifting across phases, Opportunity 2)."""
from benchmarks.common import MODEL, TP, emit, slo_regimes, timed
from repro.core.policies import Sliders
from repro.sim.simulator import ServingConfig, run_sim
from repro.sim.workload import ARXIV

# long-prompt workload near prefill saturation: chunk size then governs
# prefill capacity and the TTFT/TPOT shift is visible (paper ran QPS=12
# near its cluster's knee for the same reason)
QPS = 6.5
N = 150


def run():
    slo = slo_regimes(workload="arxiv")["balanced"]
    rows = {}
    # CP128 is omitted: its prefill capacity (~31k tok/s for 4
    # instances) is below this workload's demand — the simulated queue
    # diverges, which is the paper's own Fig-5 observation that chunk
    # sizes below 1024 are "unsustainable for the workload"
    for chunk in [256, 512, 1024, 2048]:
        sc = ServingConfig(model=MODEL, tp=TP, policy="aggregation",
                           sliders=Sliders(2, 2, chunk, chunk))
        with timed() as t:
            st = run_sim(sc, slo, ARXIV, QPS, N, seed=2)
        rows[f"CP{chunk}"] = (st.p90_ttft, st.p90_tpot)
        emit(f"fig5.CP{chunk}", t.us,
             f"p90_ttft={st.p90_ttft:.2f}s;p90_tpot={st.p90_tpot*1e3:.1f}ms")
    for np_ in [1, 2, 3]:
        sc = ServingConfig(model=MODEL, tp=TP, policy="disaggregation",
                           sliders=Sliders(np_, 4 - np_, 0, 0))
        with timed() as t:
            st = run_sim(sc, slo, ARXIV, QPS, N, seed=2)
        rows[f"P{np_}D{4-np_}"] = (st.p90_ttft, st.p90_tpot)
        emit(f"fig6.P{np_}D{4-np_}", t.us,
             f"p90_ttft={st.p90_ttft:.2f}s;p90_tpot={st.p90_tpot*1e3:.1f}ms")
    # cross-phase latency shifting: larger chunk lowers TTFT, raises
    # TPOT (CP256 -> CP1024; beyond that TTFT turns non-monotone, as in
    # the paper's Fig 6 discussion of extreme configurations)
    shift = (rows["CP1024"][0] <= rows["CP256"][0]
             and rows["CP1024"][1] >= rows["CP256"][1])
    emit("fig5.claim_latency_shift", 0,
         f"larger_chunk_shifts_ttft_to_tpot={shift}")
    return rows


if __name__ == "__main__":
    run()
