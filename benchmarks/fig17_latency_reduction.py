"""Paper Figure 17: P90 tail-latency reduction at TaiChi's maximum
supported load — TTFT vs disaggregation (paper: 2.4-13.2x) and TPOT vs
aggregation (paper: 1.11-1.69x)."""
from benchmarks.common import default_configs, emit, slo_regimes, timed
from repro.sim.simulator import run_sim
from repro.sim.workload import SHAREGPT

QPS = 120.0
N = 300


def run():
    slo = slo_regimes()["balanced"]
    configs = default_configs()
    stats = {}
    for pname, sc in configs.items():
        with timed() as t:
            stats[pname] = run_sim(sc, slo, SHAREGPT, QPS, N, seed=4)
        st = stats[pname]
        emit(f"fig17.{pname}", t.us,
             f"p90_ttft={st.p90_ttft:.3f}s;p90_tpot={st.p90_tpot*1e3:.1f}ms")
    ttft_red = stats["disaggregation"].p90_ttft / stats["taichi"].p90_ttft
    tpot_red = stats["aggregation"].p90_tpot / stats["taichi"].p90_tpot
    emit("fig17.claim_C5", 0,
         f"ttft_reduction_vs_disagg={ttft_red:.2f}x;"
         f"tpot_reduction_vs_agg={tpot_red:.2f}x;"
         f"both_gt_1={ttft_red > 1 and tpot_red > 1}")
    return {"ttft_x": ttft_red, "tpot_x": tpot_red}


if __name__ == "__main__":
    run()
