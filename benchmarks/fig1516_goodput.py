"""Paper Figures 15-16 (headline): maximum goodput (max QPS with >= 90%
SLO attainment) for chatbot (ShareGPT-like) and summarization
(ArXiv-like) under two balanced SLO variants.

Claim C4: TaiChi beats PD aggregation (paper: +9..47%) and PD
disaggregation (paper: +29..77%) under balanced SLOs."""
from benchmarks.common import (MODEL, TP, cost_model, emit, slo_regimes,
                               timed)
from repro.core.latency import SLO
from repro.core.policies import Sliders
from repro.sim.simulator import ServingConfig, goodput_sweep
from repro.sim.workload import ARXIV, SHAREGPT

N = 250


def _slos(workload):
    cm = cost_model()
    base_tpot = cm.decode_iteration_time(32, 1024)
    prompt = 430 if workload == "sharegpt" else 6000
    base_ttft = cm.prefill_time(prompt, 2048)
    # SLO1: lower TTFT, higher TPOT; SLO2: higher TTFT, lower TPOT (§4.1).
    # TPOT multipliers sit BETWEEN the interference-free decode time and
    # full-chunk interference level (~1.9x base on v5e) so the regime is
    # genuinely balanced — the paper's A100 SLOs encode the same choice
    # relative to its much steeper 0.2 ms/token interference slope.
    return {"slo1": SLO(ttft=base_ttft * 8, tpot=base_tpot * 1.85),
            "slo2": SLO(ttft=base_ttft * 14, tpot=base_tpot * 1.45)}


def _configs(slo_name):
    sd = 256 if slo_name == "slo1" else 128   # paper: tighter TPOT -> smaller S_D
    return {
        "aggregation": ServingConfig(MODEL, TP, "aggregation",
                                     Sliders(2, 2, 1024, 1024)),
        "disaggregation": ServingConfig(MODEL, TP, "disaggregation",
                                        Sliders(2, 2, 0, 0)),
        "taichi": ServingConfig(MODEL, TP, "taichi",
                                Sliders(2, 2, 1024, sd)),
    }


def run():
    results = {}
    for wname, wl, grid in [
        ("chatbot", SHAREGPT, [60, 80, 100, 110, 120, 130, 140]),
        ("summarization", ARXIV, [2, 3, 4, 5, 6, 7, 8]),
    ]:
        slos = _slos(wl.name)
        for sname, slo in slos.items():
            for pname, sc in _configs(sname).items():
                with timed() as t:
                    g, stats = goodput_sweep(sc, slo, wl, grid, N)
                results[(wname, sname, pname)] = g
                att = ";".join(f"q{s.qps:g}:{s.slo_attainment:.2f}"
                               for s in stats)
                emit(f"fig1516.{wname}.{sname}.{pname}", t.us,
                     f"goodput={g};{att}")
    # C4 checks
    for wname in ("chatbot", "summarization"):
        for sname in ("slo1", "slo2"):
            tai = results[(wname, sname, "taichi")]
            agg = results[(wname, sname, "aggregation")]
            dis = results[(wname, sname, "disaggregation")]
            gain_a = (tai - agg) / agg * 100 if agg else float("inf")
            gain_d = (tai - dis) / dis * 100 if dis else float("inf")
            emit(f"fig1516.claim_C4.{wname}.{sname}", 0,
                 f"taichi={tai};agg={agg};disagg={dis};"
                 f"gain_vs_agg={gain_a:.0f}%;gain_vs_disagg={gain_d:.0f}%")
    return results


if __name__ == "__main__":
    run()
