"""Paper Figure 19: overhead analysis — KV-transfer and scheduling costs
as a fraction of total request time (paper: 0.20% transfer, 0.01%
prefill-sched, 0.89% decode-sched)."""
import time

from benchmarks.common import default_configs, emit, slo_regimes, timed
from repro.sim.simulator import build_cluster
from repro.sim.workload import ARXIV


def run():
    slo = slo_regimes(workload="arxiv")["balanced"]
    sc = default_configs()["taichi"]
    cluster = build_cluster(sc, slo)
    reqs = ARXIV.sample_requests(200, 5.0, seed=6)

    # wall-clock the scheduling code itself (Algorithm 1+2 execution)
    sched_time = {"prefill": 0.0, "decode": 0.0}
    orig_arrival = cluster.policy.on_arrival
    orig_mig = cluster.policy.select_migrations

    def timed_arrival(req, now):
        t0 = time.perf_counter()
        r = orig_arrival(req, now)
        sched_time["prefill"] += time.perf_counter() - t0
        return r

    def timed_mig(now, inst):
        t0 = time.perf_counter()
        r = orig_mig(now, inst)
        sched_time["decode"] += time.perf_counter() - t0
        return r

    cluster.policy.on_arrival = timed_arrival
    cluster.policy.select_migrations = timed_mig
    with timed() as t:
        cluster.run(reqs)
    total_req_time = sum((r.finish_time or 0) - r.arrival for r in reqs
                         if r.finish_time)
    transfer_time = sum(cluster.cost.transfer_time(1000)
                        for _ in range(cluster.transfer_count))
    fr_t = transfer_time / max(total_req_time, 1e-9) * 100
    fr_p = sched_time["prefill"] / max(total_req_time, 1e-9) * 100
    fr_d = sched_time["decode"] / max(total_req_time, 1e-9) * 100
    emit("fig19.overhead", t.us,
         f"transfer_pct={fr_t:.3f};prefill_sched_pct={fr_p:.3f};"
         f"decode_sched_pct={fr_d:.3f};"
         f"transfers={cluster.transfer_count}")
    emit("fig19.claim_C7", 0,
         f"all_overheads_below_2pct={max(fr_t, fr_p, fr_d) < 2.0}")
    return {"transfer": fr_t, "prefill": fr_p, "decode": fr_d}


if __name__ == "__main__":
    run()
