"""Engine executor benchmark: paged fused mixed-batch path (K=1 and
K=8 decode horizon) vs. batched dense path vs. row-wise reference.

Measures, on a reduced CPU config (so it runs anywhere; the same jit
variants lower for the TPU meshes):

  * prefill tokens/s — N requests with uneven prompt lengths, chunked
    prefill, no decode mixed in;
  * decode steps/s — decode-batch iterations after all prefills, until
    every variant has generated the same number of tokens (the K=8
    horizon variant fuses 8 steps per jit call and reads back once per
    horizon, so its host overhead per token is ~1/8 of the K=1 path's);
  * peak KV-cache bytes — dense paths reserve ``n_slots x max_seq``
    rows; the paged pool is sized to the workload's actual contexts
    (same slot count), which is where the paged memory win shows up.

All executors are warmed up on an identical workload first so compile
time is excluded; the comparison is steady-state dispatch + execution.

Usage:  PYTHONPATH=src python benchmarks/engine_bench.py [--model smollm-135m]
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks.common import emit, write_json
from repro.configs import reduced_config
from repro.core.estimator import CostModel
from repro.core.hw import InstanceSpec
from repro.core.instance import D_HEAVY, Instance
from repro.engine.engine import JaxExecutor
from repro.engine.request import Request
from repro.models import transformer as tf

N_REQS = 8
CHUNK = 256
MAX_SEQ = 512
BLOCK = 16
DECODE_STEPS = 32            # tokens generated per request per pass
HORIZON = 8                  # fused steps for the horizon variant
# paged pool: half the dense token capacity at the SAME slot count —
# contexts here peak around 185 tokens (prompt + decode + headroom), so
# 2048 pooled tokens hold all 8 requests with room to spare while the
# dense paths reserve 8 x 512 = 4096
PAGED_BLOCKS = N_REQS * MAX_SEQ // (2 * BLOCK)
# prompt lengths are drawn per pass: production traffic has unbounded
# length diversity, so the timed "fresh" pass uses lengths the executor
# has never seen — the row-wise path recompiles per distinct chunk
# length, the batched/paged paths hit their warm bucketed shapes.
# Kept short enough that every decode frontier stays within one 8-block
# table bucket: the decode phase then isolates per-iteration host +
# dispatch overhead (what the K-step horizon removes) instead of the
# CPU-only jnp gather cost that ROADMAP already flags as the interpret
# path's known bottleneck.
LEN_RANGE = (24, 81)


def _make_requests(cfg, rng, n_out=DECODE_STEPS + 8):
    reqs = []
    for n in rng.integers(*LEN_RANGE, size=N_REQS):
        p = list(rng.integers(1, cfg.vocab_size, size=n))
        reqs.append(Request(prompt_len=int(n), max_new_tokens=n_out,
                            prompt_tokens=p))
    return reqs


def _run_phases(inst, ex, cfg, seed: int):
    """One workload pass on an existing instance (so jit caches persist
    across the warmup and timed passes).  Returns (prefill_s,
    prefill_tokens, decode_s, decode_steps, decode_readbacks).  The
    decode phase runs to a fixed TOKEN count so K=1 and K=8 variants do
    identical work; readbacks are counted over that phase alone."""
    rng = np.random.default_rng(seed)
    reqs = _make_requests(cfg, rng)
    for r in reqs:
        inst.enqueue_prefill(r)
    ex.sync()

    t0 = time.perf_counter()
    now, guard = 0.0, 0
    while any(r.prefill_remaining > 0 for r in reqs) and guard < 1000:
        dur, _, _ = inst.run_iteration(now)
        now += dur
        guard += 1
    ex.sync()
    prefill_s = time.perf_counter() - t0
    prefill_tokens = sum(r.prompt_len for r in reqs)

    for r in reqs:
        inst.admit_decode(r)
    target = DECODE_STEPS * len(reqs)
    base, guard = inst.decode_token_count, 0
    rb0 = ex.host_readbacks
    t0 = time.perf_counter()
    while inst.decode_token_count - base < target and guard < 1000:
        dur, _, _ = inst.run_iteration(now)
        now += dur
        guard += 1
    ex.sync()
    decode_s = time.perf_counter() - t0
    decode_steps = inst.decode_token_count - base
    decode_readbacks = ex.host_readbacks - rb0
    for r in reqs:                      # free slots/blocks for the next pass
        inst.remove_request(r)
    return prefill_s, prefill_tokens, decode_s, decode_steps, \
        decode_readbacks


VARIANTS = (
    # name, batched, paged, hbm_blocks (paged pool size), max_horizon
    ("rowwise", False, False, None, 1),
    ("batched", True, False, None, 1),
    ("paged", True, True, PAGED_BLOCKS, 1),
    (f"paged-h{HORIZON}", True, True, PAGED_BLOCKS, HORIZON),
)


def run(model: str = "smollm-135m"):
    cfg = reduced_config(model)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    cost = CostModel(cfg, InstanceSpec(tp=1))
    results = {}
    cache_bytes = {}
    readbacks = {}
    for name, batched, paged, blocks, horizon in VARIANTS:
        ex = JaxExecutor(cfg, params, n_slots=N_REQS, max_seq=MAX_SEQ,
                         batched=batched, paged=paged, hbm_blocks=blocks,
                         cache_block_size=BLOCK)
        inst = Instance(0, D_HEAVY, CHUNK, cost, ex, hbm_blocks=4096,
                        block_size=BLOCK, max_horizon=horizon)
        cache_bytes[name] = ex.cache_bytes()
        _run_phases(inst, ex, cfg, seed=11)           # warmup pass
        # fresh pass: unseen prompt lengths (what serving traffic does)
        fps, fptk, _, _, _ = _run_phases(inst, ex, cfg, seed=12)
        # steady pass: same lengths again (all shapes warm on both paths)
        ps, ptk, ds, dst, rb = _run_phases(inst, ex, cfg, seed=12)
        results[name] = (fptk / fps, ptk / ps, dst / ds)
        readbacks[name] = rb
        emit(f"engine.{name}.prefill_fresh", fps / fptk * 1e6,
             f"tokens_per_s={fptk / fps:.1f};model={model};chunk={CHUNK}")
        emit(f"engine.{name}.prefill_steady", ps / ptk * 1e6,
             f"tokens_per_s={ptk / ps:.1f};model={model};chunk={CHUNK}")
        emit(f"engine.{name}.decode", ds / dst * 1e6,
             f"steps_per_s={dst / ds:.1f};model={model};batch={N_REQS};"
             f"horizon={horizon}")
        emit(f"engine.{name}.cache_bytes", 0.0,
             f"bytes={cache_bytes[name]};slots={N_REQS};max_seq={MAX_SEQ}")
    h = f"paged-h{HORIZON}"
    fresh_x = results["batched"][0] / results["rowwise"][0]
    steady_x = results["batched"][1] / results["rowwise"][1]
    decode_x = results["batched"][2] / results["rowwise"][2]
    paged_decode_x = results["paged"][2] / results["batched"][2]
    paged_prefill_x = results["paged"][1] / results["batched"][1]
    horizon_decode_x = results[h][2] / results["paged"][2]
    cache_reduction_x = cache_bytes["batched"] / cache_bytes["paged"]
    emit("engine.speedup", 0.0,
         f"prefill_fresh_x={fresh_x:.2f};prefill_steady_x={steady_x:.2f};"
         f"decode_x={decode_x:.2f};paged_decode_x={paged_decode_x:.2f};"
         f"horizon_decode_x={horizon_decode_x:.2f};"
         f"paged_cache_reduction_x={cache_reduction_x:.2f}")
    write_json("engine_bench", {
        "model": model, "chunk": CHUNK, "n_reqs": N_REQS,
        "max_seq": MAX_SEQ, "block_size": BLOCK,
        "paged_pool_blocks": PAGED_BLOCKS, "horizon": HORIZON,
        "tokens_per_s": {
            name: {"prefill_fresh": round(r[0], 1),
                   "prefill_steady": round(r[1], 1),
                   "decode_steps_per_s": round(r[2], 1)}
            for name, r in results.items()},
        "decode_readbacks": readbacks,
        "peak_cache_bytes": cache_bytes,
        "speedup": {"prefill_fresh_x": round(fresh_x, 2),
                    "prefill_steady_x": round(steady_x, 2),
                    "decode_x": round(decode_x, 2),
                    "paged_vs_batched_decode_x": round(paged_decode_x, 2),
                    "paged_vs_batched_prefill_x": round(paged_prefill_x, 2),
                    "horizon_decode_x": round(horizon_decode_x, 2),
                    "paged_cache_reduction_x": round(cache_reduction_x, 2)},
    })
    return fresh_x, steady_x, decode_x


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="smollm-135m")
    run(ap.parse_args().model)
