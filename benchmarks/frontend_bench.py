"""Network front-end smoke benchmark: socket-level streaming throughput
and per-token wire overhead.

Boots the full deployable stack — ``FrontendServer`` (asyncio HTTP/SSE)
over the live JAX engine (reduced smollm config, CPU-runnable) with the
multi-process detokenizer pipeline and the router-side admission queue —
then drives ``N_CLIENTS`` concurrent streaming ``/v1/completions``
clients over real loopback sockets and measures:

* **streamed tokens/s** — SSE deltas received across all clients per
  wall second (the end-to-end serving rate a user sees);
* **wire overhead** — engine token event -> SSE frame on the socket
  (``TelemetryWindow.record_wire`` spans), p50/p95/mean ms: the cost of
  the pipeline + asyncio hop, NOT of model compute;
* **client TTFB** — request sent -> first SSE byte, p50/p95.

Emits CSV rows via benchmarks.common.emit and JSON to
benchmarks/out/frontend_bench.json; the slow-CI gate
(benchmarks/check_regression.py --frontend) re-checks the recorded
floors: a minimum streamed rate and a ceiling on per-token wire
overhead.  Both are deliberately loose — they catch structural
regressions (string work leaking back into the token hot path, a
blocking writer), not runner jitter.
"""
from __future__ import annotations

import json
import socket
import threading
import time

import numpy as np

from benchmarks.common import emit, write_json

N_CLIENTS = 16
MAX_TOKENS = 16
TOK_WORKERS = 2

#: acceptance floors re-checked by check_regression.py --frontend
TOKENS_PER_S_FLOOR = 5.0        # CPU runner, 2-layer model: very loose
WIRE_P95_MS_CEIL = 250.0        # pipeline+socket hop must stay light


def _build_server():
    import jax

    from repro.configs import reduced_config
    from repro.core.latency import SLO
    from repro.core.policies import Sliders
    from repro.engine.engine import JaxExecutor
    from repro.frontend import (AdmissionConfig, FrontendConfig,
                                FrontendServer)
    from repro.models import transformer as tf
    from repro.serving import ServingLoop
    from repro.sim.simulator import ServingConfig, build_cluster

    cfg = reduced_config("smollm-135m")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    sc = ServingConfig(model="smollm-135m", tp=1, policy="taichi",
                       sliders=Sliders(n_p=1, n_d=1, s_p=64, s_d=32),
                       hbm_blocks=512)
    factory = lambda: JaxExecutor(cfg, params, n_slots=8, max_seq=512)
    cluster = build_cluster(sc, SLO(ttft=10.0, tpot=1.0),
                            executor_factory=factory)
    loop = ServingLoop(cluster, SLO(ttft=10.0, tpot=1.0),
                       admission=AdmissionConfig(max_depth=128,
                                                 max_inflight=8))
    return FrontendServer(loop, FrontendConfig(port=0,
                                               tok_workers=TOK_WORKERS))


def _client(port, prompt, res, idx):
    s = socket.create_connection(("127.0.0.1", port), timeout=300)
    body = json.dumps({"prompt": prompt, "max_tokens": MAX_TOKENS,
                       "stream": True}).encode()
    t0 = time.monotonic()
    s.sendall((f"POST /v1/completions HTTP/1.1\r\nHost: b\r\n"
               f"Content-Length: {len(body)}\r\n"
               "Connection: close\r\n\r\n").encode() + body)
    ttfb = None
    data = b""
    while chunk := s.recv(65536):
        if ttfb is None:
            ttfb = time.monotonic() - t0
        data += chunk
    s.close()
    # delta frames have finish_reason null; the finish chunk does not
    res[idx] = (ttfb, data.count(b'"finish_reason":null'))


def run():
    srv = _build_server()
    th = threading.Thread(target=srv.run, daemon=True)
    th.start()
    if not srv.started.wait(timeout=120):
        raise RuntimeError("frontend server failed to start")

    res = {}
    clients = [threading.Thread(
        target=_client, args=(srv.port, f"bench client {i} prompt", res, i),
        daemon=True) for i in range(N_CLIENTS)]
    t0 = time.monotonic()
    for c in clients:
        c.start()
    for c in clients:
        c.join(timeout=600)
    wall = time.monotonic() - t0
    if len(res) != N_CLIENTS:
        raise RuntimeError(f"only {len(res)}/{N_CLIENTS} clients answered")

    streamed = sum(n for _, n in res.values())
    tok_s = streamed / wall
    ttfbs = [t for t, _ in res.values() if t is not None]
    wire = srv.loop.telemetry.wire_stats() or {}
    snap = srv.loop.snapshot()
    srv.shutdown()
    th.join(timeout=120)

    emit("frontend.streamed_tok_s", wall * 1e6 / max(streamed, 1),
         f"{tok_s:.1f}tok/s/{N_CLIENTS}clients")
    emit("frontend.wire_p95", wire.get("p95_ms", 0.0) * 1e3,
         f"p50={wire.get('p50_ms', 0)}ms")
    emit("frontend.ttfb_p95",
         float(np.percentile(ttfbs, 95)) * 1e6 if ttfbs else 0.0,
         f"p50={np.percentile(ttfbs, 50):.3f}s" if ttfbs else "none")

    write_json("frontend_bench", {
        "clients": N_CLIENTS,
        "max_tokens": MAX_TOKENS,
        "tok_workers": TOK_WORKERS,
        "wall_s": round(wall, 3),
        "streamed_frames": streamed,
        "streamed_tokens_per_s": round(tok_s, 2),
        "ttfb_p50_s": round(float(np.percentile(ttfbs, 50)), 4),
        "ttfb_p95_s": round(float(np.percentile(ttfbs, 95)), 4),
        "wire": wire,
        "queue_wait": snap.get("queue_wait"),
        "admission": snap.get("admission"),
        "acceptance": {
            "tokens_per_s_floor": TOKENS_PER_S_FLOOR,
            "wire_p95_ms_ceil": WIRE_P95_MS_CEIL,
        },
    })


if __name__ == "__main__":
    run()
