"""Kernel microbenchmarks (interpret mode on CPU — wall time is NOT
TPU-representative; the derived column reports the work description and
FLOPs so the roofline table can relate them to v5e peaks)."""
import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.kernels.chunked_prefill_attention.ops import (
    chunked_prefill_attention)
from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.ssd_scan.ops import ssd_scan


def _time(fn, *args, reps=3, **kw):
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def run():
    key = jax.random.PRNGKey(0)
    # chunked prefill attention: chunk 128 against 1k prefix
    B, Tq, Hq, Hkv, D, S = 1, 128, 8, 8, 128, 1152
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, Tq, Hq, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), jnp.float32)
    us = _time(chunked_prefill_attention, q, k, v, 1024, bq=128, bk=128)
    flops = 4 * B * Tq * Hq * D * S
    emit("kernel.chunked_prefill_attention", us,
         f"interpret=True;flops={flops};shape=B{B}xT{Tq}xH{Hq}xS{S}")

    # decode attention: 32 sequences, 2k cache
    B, Hq, Hkv, D, S = 32, 8, 2, 128, 2048
    q = jax.random.normal(ks[0], (B, Hq, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), jnp.float32)
    lengths = jnp.full((B,), S, jnp.int32)
    us = _time(decode_attention, q, k, v, lengths, bk=512)
    emit("kernel.decode_attention", us,
         f"interpret=True;flops={4*B*Hq*D*S};shape=B{B}xH{Hq}xS{S}")

    # ssd scan: mamba2-1.3b-like single layer slice
    b, t, h, p, g, n = 2, 512, 8, 64, 1, 128
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, t, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, t, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)))
    Bm = jax.random.normal(ks[3], (b, t, g, n), jnp.float32)
    Cm = jax.random.normal(ks[4], (b, t, g, n), jnp.float32)
    us = _time(ssd_scan, x, dt, A, Bm, Cm, 128, None)
    emit("kernel.ssd_scan", us,
         f"interpret=True;chunk=128;shape=b{b}xt{t}xh{h}xp{p}xn{n}")


if __name__ == "__main__":
    run()
