"""Kernel microbenchmarks (interpret mode on CPU — wall time is NOT
TPU-representative; the derived column reports the work description and
FLOPs so the roofline table can relate them to v5e peaks).  Dense and
paged variants run the same logical attention so the JSON artifact
tracks the paged kernels' overhead trajectory."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, write_json
from repro.kernels.chunked_prefill_attention.ops import (
    chunked_prefill_attention, paged_chunked_prefill_attention)
from repro.kernels.decode_attention.ops import (decode_attention,
                                                paged_decode_attention)
from repro.kernels.ssd_scan.ops import ssd_scan


def _time(fn, *args, reps=3, **kw):
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def run():
    key = jax.random.PRNGKey(0)
    results = {}
    # chunked prefill attention: chunk 128 against 1k prefix
    B, Tq, Hq, Hkv, D, S = 1, 128, 8, 8, 128, 1152
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, Tq, Hq, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), jnp.float32)
    us = _time(chunked_prefill_attention, q, k, v, 1024, bq=128, bk=128)
    flops = 4 * B * Tq * Hq * D * S
    emit("kernel.chunked_prefill_attention", us,
         f"interpret=True;flops={flops};shape=B{B}xT{Tq}xH{Hq}xS{S}")
    results["chunked_prefill_us"] = round(us, 1)

    # paged chunked prefill: same logical work through block tables
    bs = 64
    n_blk = S // bs
    kp = k[0].reshape(S, Hkv, D)
    vp = v[0].reshape(S, Hkv, D)
    tables = jnp.arange(n_blk, dtype=jnp.int32)[None]
    start = jnp.full((B,), 1024, jnp.int32)
    valid = jnp.full((B,), Tq, jnp.int32)
    us = _time(paged_chunked_prefill_attention, q, kp, vp, tables, start,
               valid, block_size=bs)
    emit("kernel.paged_prefill_attention", us,
         f"interpret=True;flops={flops};shape=B{B}xT{Tq}xH{Hq}xS{S};bs={bs}")
    results["paged_prefill_us"] = round(us, 1)

    # decode attention: 32 sequences, 2k cache
    B, Hq, Hkv, D, S = 32, 8, 2, 128, 2048
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (B, Hq, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), jnp.float32)
    lengths = jnp.full((B,), S, jnp.int32)
    us = _time(decode_attention, q, k, v, lengths, bk=512)
    emit("kernel.decode_attention", us,
         f"interpret=True;flops={4*B*Hq*D*S};shape=B{B}xH{Hq}xS{S}")
    results["decode_us"] = round(us, 1)

    # paged decode: one shared pool, per-sequence block tables
    bs = 256
    n_blk = B * S // bs
    kp = k.reshape(B * S, Hkv, D)
    vp = v.reshape(B * S, Hkv, D)
    per_seq = S // bs
    tables = jnp.asarray(
        np.arange(n_blk, dtype=np.int32).reshape(B, per_seq))
    us = _time(paged_decode_attention, q, kp, vp, tables, lengths,
               block_size=bs)
    emit("kernel.paged_decode_attention", us,
         f"interpret=True;flops={4*B*Hq*D*S};shape=B{B}xH{Hq}xS{S};bs={bs}")
    results["paged_decode_us"] = round(us, 1)

    # ssd scan: mamba2-1.3b-like single layer slice
    b, t, h, p, g, n = 2, 512, 8, 64, 1, 128
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, t, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, t, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)))
    Bm = jax.random.normal(ks[3], (b, t, g, n), jnp.float32)
    Cm = jax.random.normal(ks[4], (b, t, g, n), jnp.float32)
    us = _time(ssd_scan, x, dt, A, Bm, Cm, 128, None)
    emit("kernel.ssd_scan", us,
         f"interpret=True;chunk=128;shape=b{b}xt{t}xh{h}xp{p}xn{n}")
    results["ssd_scan_us"] = round(us, 1)

    write_json("kernel_bench", {"interpret": True, "timings_us": results})


if __name__ == "__main__":
    run()
