"""Paper Table 2 / Figure 1-2: SLO attainment of PD aggregation vs
disaggregation vs TaiChi under the three SLO regimes at fixed load.

Claim C1: agg wins tight-TTFT/relaxed-TPOT; disagg wins tight-TPOT/
relaxed-TTFT; both collapse under balanced SLOs while TaiChi holds."""
import dataclasses

from benchmarks.common import (default_configs, emit, slo_regimes,
                               taichi_sliders_for, timed)
from repro.sim.simulator import run_sim
from repro.sim.workload import SHAREGPT

QPS = 110.0
N = 300


def run():
    regimes = slo_regimes()
    configs = default_configs()
    rows = {}
    for rname, slo in regimes.items():
        for pname, sc in configs.items():
            if pname == "taichi":
                sc = dataclasses.replace(
                    sc, sliders=taichi_sliders_for(rname))
            with timed() as t:
                st = run_sim(sc, slo, SHAREGPT, QPS, N, seed=0)
            rows[(rname, pname)] = st.slo_attainment
            emit(f"table2.{rname}.{pname}", t.us,
                 f"attainment={st.slo_attainment:.3f};"
                 f"p90_ttft={st.p90_ttft:.2f}s;"
                 f"p90_tpot={st.p90_tpot*1e3:.1f}ms")
    # claim checks
    c1a = rows[("tight_ttft", "aggregation")] > rows[("tight_ttft",
                                                      "disaggregation")]
    c1b = rows[("tight_tpot", "disaggregation")] > rows[("tight_tpot",
                                                         "aggregation")]
    c1c = (rows[("balanced", "taichi")]
           >= max(rows[("balanced", "aggregation")],
                  rows[("balanced", "disaggregation")]))
    emit("table2.claim_C1", 0,
         f"agg_wins_tight_ttft={c1a};disagg_wins_tight_tpot={c1b};"
         f"taichi_wins_balanced={c1c}")
    return rows


if __name__ == "__main__":
    run()
