"""Slow-CI regression gate over the engine benchmark trajectory.

Compares a fresh ``benchmarks/out/engine_bench.json`` against the
committed baseline in ``benchmarks/baselines/engine_bench.json`` and
fails (exit 1) when

  * any variant's decode steps/s drops more than ``REPRO_BENCH_TOL``
    (default 20%) below the baseline, or
  * the K-step decode-horizon speedup ``horizon_decode_x`` falls below
    the 1.5x acceptance floor.

Absolute tokens/s numbers vary with the runner, so the tolerance is
deliberately loose — this gate catches trajectory regressions (a path
getting structurally slower), not machine jitter.  Regenerate the
baseline with::

    PYTHONPATH=src:. python benchmarks/engine_bench.py
    cp benchmarks/out/engine_bench.json benchmarks/baselines/

With ``--kv`` (or ``--kv-only``) it additionally re-checks the
multi-tier KV pressure bench's recorded acceptance floors from
``benchmarks/out/kv_pressure.json`` — int8 effective capacity, the
spill tier's TTFT win over drop-and-recompute, and the tier stack's
goodput gain.

With ``--frontend`` (or ``--frontend-only``) it re-checks the HTTP/SSE
front-end smoke bench (``benchmarks/out/frontend_bench.json``): the
socket-level streamed tokens/s floor and the per-token wire-overhead
ceiling.

With ``--trace`` (or ``--trace-only``) it re-checks the lifecycle
tracing overhead bench (``benchmarks/out/trace_overhead_bench.json``):
traced runs must be bit-identical to untraced ones (goodput ratio at
1.0) and tracing's wall-time cost must stay under its ceiling.

Usage:  python benchmarks/check_regression.py [--fresh path] [--baseline path]
"""
from __future__ import annotations

import argparse
import json
import os
import sys

HERE = os.path.dirname(os.path.abspath(__file__))
HORIZON_FLOOR = 1.5


def check(fresh_path: str, baseline_path: str, tol: float) -> int:
    with open(baseline_path) as f:
        base = json.load(f)
    with open(fresh_path) as f:
        fresh = json.load(f)
    failures = []
    for name, b in base["tokens_per_s"].items():
        fv = fresh["tokens_per_s"].get(name)
        if fv is None:
            failures.append(f"variant {name!r} missing from fresh run")
            continue
        floor = (1.0 - tol) * b["decode_steps_per_s"]
        got = fv["decode_steps_per_s"]
        status = "ok" if got >= floor else "REGRESSION"
        print(f"{name:>12}: decode {got:9.1f} steps/s "
              f"(baseline {b['decode_steps_per_s']:.1f}, "
              f"floor {floor:.1f}) {status}")
        if got < floor:
            failures.append(
                f"{name}: decode {got:.1f} < floor {floor:.1f} "
                f"(baseline {b['decode_steps_per_s']:.1f}, tol {tol:.0%})")
    hx = fresh["speedup"].get("horizon_decode_x", 0.0)
    print(f"{'horizon_x':>12}: {hx:.2f} (floor {HORIZON_FLOOR})")
    if hx < HORIZON_FLOOR:
        failures.append(
            f"horizon_decode_x {hx:.2f} < acceptance floor {HORIZON_FLOOR}")
    if failures:
        print("\nFAIL:\n  " + "\n  ".join(failures))
        return 1
    print("\nOK: no decode regression vs baseline")
    return 0


#: multi-tier KV acceptance floors re-checked from the recorded JSON
#: (the sim is seed-deterministic, so these reproduce across machines)
KV_CAPACITY_FLOOR = 1.8


def check_kv_pressure(path: str) -> int:
    """Gate over benchmarks/out/kv_pressure.json: the int8 tier must
    keep its effective-capacity floor, spill must beat
    drop-and-recompute on mean/p99 TTFT, and the tier stack must win
    goodput under the eviction-forcing pool."""
    with open(path) as f:
        res = json.load(f)
    s = res["summary"]
    checks = [
        ("int8_capacity_ratio", res["int8_capacity_ratio"],
         KV_CAPACITY_FLOOR),
        ("spill_mean_ttft_reduction", s["spill_mean_ttft_reduction"], 0.0),
        ("spill_p99_ttft_reduction", s["spill_p99_ttft_reduction"], 0.0),
        ("tiered_goodput_gain", s["tiered_goodput_gain"], 1.0),
    ]
    failures = []
    for name, got, floor in checks:
        status = "ok" if got > floor else "REGRESSION"
        print(f"{name:>26}: {got:.3f} (floor {floor}) {status}")
        if got <= floor:
            failures.append(f"{name} {got:.3f} <= floor {floor}")
    if failures:
        print("\nFAIL:\n  " + "\n  ".join(failures))
        return 1
    print("\nOK: multi-tier KV floors hold")
    return 0


def check_chaos(path: str) -> int:
    """Gate over benchmarks/out/chaos_bench.json: under the fixed fault
    schedule, recovery-on must strictly beat fail-stop goodput, and the
    schedule must actually have bitten (fail-stop failed requests) —
    otherwise the bench is measuring nothing."""
    with open(path) as f:
        res = json.load(f)
    s = res["summary"]
    failures = []
    gain = s["recovery_goodput_gain"]
    status = "ok" if gain > 1.0 else "REGRESSION"
    print(f"{'recovery_goodput_gain':>26}: {gain:.3f} (floor 1.0) {status}")
    if gain <= 1.0:
        failures.append(f"recovery_goodput_gain {gain:.3f} <= 1.0")
    n_failed = s["failstop_failed"]
    status = "ok" if n_failed > 0 else "REGRESSION"
    print(f"{'failstop_failed':>26}: {n_failed} (floor 1) {status}")
    if n_failed <= 0:
        failures.append("the fault schedule never failed a fail-stop "
                        "request — the bench lost its signal")
    wg = s["warm_goodput_gain"]
    status = "ok" if wg >= 1.0 else "REGRESSION"
    print(f"{'warm_goodput_gain':>26}: {wg:.3f} (floor 1.0) {status}")
    if wg < 1.0:
        failures.append(f"warm_goodput_gain {wg:.3f} < 1.0 — warm "
                        "recovery lost goodput vs cold recompute")
    if failures:
        print("\nFAIL:\n  " + "\n  ".join(failures))
        return 1
    print("\nOK: chaos recovery floors hold")
    return 0


def check_frontend(path: str) -> int:
    """Gate over benchmarks/out/frontend_bench.json: the socket-level
    smoke run must clear its recorded streamed-rate floor and keep the
    per-token wire overhead (engine event -> SSE frame on the socket)
    under its ceiling.  Catches string work leaking back into the token
    hot path or a blocking writer, not runner jitter."""
    with open(path) as f:
        res = json.load(f)
    acc = res["acceptance"]
    tok_s = res["streamed_tokens_per_s"]
    wire_p95 = (res.get("wire") or {}).get("p95_ms")
    failures = []
    status = "ok" if tok_s >= acc["tokens_per_s_floor"] else "REGRESSION"
    print(f"{'streamed_tok_s':>26}: {tok_s:.2f} "
          f"(floor {acc['tokens_per_s_floor']}) {status}")
    if tok_s < acc["tokens_per_s_floor"]:
        failures.append(f"streamed tokens/s {tok_s:.2f} < floor "
                        f"{acc['tokens_per_s_floor']}")
    if wire_p95 is None:
        failures.append("no wire spans recorded — the streaming path "
                        "never reported to telemetry")
    else:
        status = ("ok" if wire_p95 <= acc["wire_p95_ms_ceil"]
                  else "REGRESSION")
        print(f"{'wire_p95_ms':>26}: {wire_p95:.2f} "
              f"(ceiling {acc['wire_p95_ms_ceil']}) {status}")
        if wire_p95 > acc["wire_p95_ms_ceil"]:
            failures.append(f"wire p95 {wire_p95:.2f}ms > ceiling "
                            f"{acc['wire_p95_ms_ceil']}ms")
    if failures:
        print("\nFAIL:\n  " + "\n  ".join(failures))
        return 1
    print("\nOK: front-end streaming floors hold")
    return 0


def check_trace(path: str) -> int:
    """Gate over benchmarks/out/trace_overhead_bench.json: tracing must
    be strictly observational (bit-identical request outcomes, goodput
    ratio at 1.0 within the recorded floor) and its wall-time cost must
    stay under the recorded ceiling — a structural leak of the tracer
    onto the hot path, not runner jitter, is what trips this."""
    with open(path) as f:
        res = json.load(f)
    b = res["bounds"]
    failures = []
    ident = res["bit_identical"]
    status = "ok" if ident else "REGRESSION"
    print(f"{'bit_identical':>26}: {ident} (must be True) {status}")
    if not ident:
        failures.append("traced run produced different request outcomes "
                        "— the tracer is no longer observational")
    ratio = res["goodput_ratio"]
    status = "ok" if ratio >= b["goodput_ratio_floor"] else "REGRESSION"
    print(f"{'goodput_ratio':>26}: {ratio:.4f} "
          f"(floor {b['goodput_ratio_floor']}) {status}")
    if ratio < b["goodput_ratio_floor"]:
        failures.append(f"traced goodput ratio {ratio:.4f} < floor "
                        f"{b['goodput_ratio_floor']}")
    over = res["wall_overhead_frac"]
    status = "ok" if over <= b["wall_overhead_ceil"] else "REGRESSION"
    print(f"{'wall_overhead_frac':>26}: {over:.3f} "
          f"(ceiling {b['wall_overhead_ceil']}) {status}")
    if over > b["wall_overhead_ceil"]:
        failures.append(f"tracing wall overhead {over:.3f} > ceiling "
                        f"{b['wall_overhead_ceil']}")
    if failures:
        print("\nFAIL:\n  " + "\n  ".join(failures))
        return 1
    print("\nOK: tracing stays observational and cheap")
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh",
                    default=os.path.join(HERE, "out", "engine_bench.json"))
    ap.add_argument("--baseline",
                    default=os.path.join(HERE, "baselines",
                                         "engine_bench.json"))
    ap.add_argument("--tol", type=float,
                    default=float(os.environ.get("REPRO_BENCH_TOL", "0.20")))
    ap.add_argument("--kv", nargs="?", const=os.path.join(
        HERE, "out", "kv_pressure.json"),
        help="also gate the multi-tier KV pressure bench JSON "
             "(skips the engine check when given alone with --kv-only)")
    ap.add_argument("--kv-only", action="store_true",
                    help="gate only the KV pressure JSON")
    ap.add_argument("--frontend", nargs="?", const=os.path.join(
        HERE, "out", "frontend_bench.json"),
        help="also gate the HTTP/SSE front-end smoke bench JSON")
    ap.add_argument("--frontend-only", action="store_true",
                    help="gate only the front-end smoke JSON")
    ap.add_argument("--chaos", nargs="?", const=os.path.join(
        HERE, "out", "chaos_bench.json"),
        help="also gate the fault-injection chaos bench JSON")
    ap.add_argument("--chaos-only", action="store_true",
                    help="gate only the chaos bench JSON")
    ap.add_argument("--trace", nargs="?", const=os.path.join(
        HERE, "out", "trace_overhead_bench.json"),
        help="also gate the lifecycle-tracing overhead bench JSON")
    ap.add_argument("--trace-only", action="store_true",
                    help="gate only the tracing overhead JSON")
    args = ap.parse_args()
    rc = 0
    if not (args.kv_only or args.frontend_only or args.chaos_only
            or args.trace_only):
        rc |= check(args.fresh, args.baseline, args.tol)
    if args.kv or args.kv_only:
        rc |= check_kv_pressure(args.kv or os.path.join(
            HERE, "out", "kv_pressure.json"))
    if args.frontend or args.frontend_only:
        rc |= check_frontend(args.frontend or os.path.join(
            HERE, "out", "frontend_bench.json"))
    if args.chaos or args.chaos_only:
        rc |= check_chaos(args.chaos or os.path.join(
            HERE, "out", "chaos_bench.json"))
    if args.trace or args.trace_only:
        rc |= check_trace(args.trace or os.path.join(
            HERE, "out", "trace_overhead_bench.json"))
    sys.exit(rc)


if __name__ == "__main__":
    main()
