"""Goodput under injected faults: a FIXED fault schedule — one instance
crash (with a later re-admission) plus two transient stalls — over the
DRIFT workload, comparing the fault-tolerance layer's two policies at
the identical arrival trace and schedule:

* ``recovery`` — the default ``FaultToleranceConfig``: the dead
  instance's resident requests are evacuated through
  preemption-by-recompute and re-routed to survivors; lossy transfers
  retry with backoff and fall back to recompute.
* ``fail_stop`` — ``FaultToleranceConfig.fail_stop()``: victims resolve
  FAILED, transfers never retry.
* ``warm`` — same fault tolerance as ``recovery`` plus
  ``RecoveryConfig(enable=True)``: crash victims resume from their
  latest progress checkpoint instead of recomputing from token 0.

All runs lose the same instance for the same window and eat the same
stalls, so the goodput deltas isolate exactly what request-level
recovery — and then warm recovery on top — buys.  The sim is
seed-deterministic, so the acceptance floors (recovery strictly beats
fail-stop goodput, warm recovery at least matches cold, and fail-stop
actually failed requests — the schedule really bit) reproduce across
machines.

Emits CSV rows via benchmarks.common.emit and JSON to
benchmarks/out/chaos_bench.json; the slow-CI regression gate
(benchmarks/check_regression.py --chaos) re-checks the recorded floors.
"""
from __future__ import annotations

import time

from benchmarks.common import emit, write_json
from repro.core.cluster import FaultToleranceConfig
from repro.core.latency import SLO
from repro.core.policies import Sliders
from repro.engine.request import State
from repro.serving import ServingLoop
from repro.serving.faults import (CRASH, RECOVER, STALL, Fault,
                                  FaultInjector)
from repro.serving.recovery import RecoveryConfig
from repro.sim.simulator import ServingConfig, build_cluster
from repro.sim.workload import DRIFT

MODEL = "qwen2.5-14b"
TP = 4
QPS = 14.0
SEED = 0
MAX_NEW = 768
HBM_BLOCKS = 16384
SLIDERS = Sliders(2, 2, 1024, 256)
#: loose enough that a recomputed victim can still meet it — the bench
#: measures recovery, not SLO brinkmanship
SLO_CHAOS = SLO(ttft=2.5, tpot=0.05)


def _schedule():
    """1 crash + 2 stalls over DRIFT's 80 s: the crash takes out a
    D-heavy instance (iid 2) mid decode tsunami — the worst case, its
    HBM holds the most in-flight KV — and it rejoins 20 s later; the
    stalls hit a P-heavy instance during the prompt burst and the
    multiturn tail."""
    return FaultInjector([
        Fault(12.0, STALL, 0, duration=2.0),
        Fault(36.0, CRASH, 2),
        Fault(56.0, RECOVER, 2),
        Fault(66.0, STALL, 1, duration=2.0),
    ])


def _run_one(ft: FaultToleranceConfig, recovery=None) -> dict:
    sc = ServingConfig(model=MODEL, tp=TP, policy="taichi",
                       sliders=SLIDERS, hbm_blocks=HBM_BLOCKS)
    cluster = build_cluster(sc, SLO_CHAOS, seed=SEED, ft=ft,
                            recovery=recovery)
    cluster.attach_faults(_schedule())
    loop = ServingLoop(cluster, SLO_CHAOS,
                       arrivals=DRIFT.iter_requests(QPS, seed=SEED,
                                                    max_new_tokens=MAX_NEW),
                       window=4.0)
    loop.run()
    reqs = loop.requests
    ok = sum(r.state == State.FINISHED and SLO_CHAOS.satisfied(r)
             for r in reqs)
    fc = cluster.fault_counters()
    snap = loop.snapshot()
    out = {
        "n": len(reqs), "ok": ok,
        "goodput_rps": round(ok / DRIFT.total_duration, 4),
        "attainment": round(ok / max(len(reqs), 1), 4),
        "failed": loop.failed_count,
        "evacuated": fc["evacuated_requests"],
        "transfer_retries": fc["transfer_retries"],
        "recovered": snap.get("recovered_total", 0),
        "recovered_slo_ok": snap.get("recovered_slo_ok_total", 0),
        "instance_failures": fc["instance_failures"],
        "instance_recoveries": fc["instance_recoveries"],
    }
    if "recovery" in snap:
        rc = snap["recovery"]
        out["warm_restores"] = rc["warm_restores"]
        out["warm_restored_tokens"] = rc["warm_restored_tokens"]
        out["warm_fallbacks"] = rc["warm_fallbacks"]
        out["checkpoints"] = rc["checkpoints"]
    return out


def run():
    results = {"qps": QPS, "seed": SEED, "slo": {"ttft_s": SLO_CHAOS.ttft,
                                                 "tpot_s": SLO_CHAOS.tpot},
               "schedule": [{"t": f.t, "kind": f.kind, "iid": f.iid,
                             "duration": f.duration}
                            for f in _schedule().schedule],
               "variants": {}}
    agg = {}
    for name, ft, rec in (
            ("recovery", FaultToleranceConfig(), None),
            ("fail_stop", FaultToleranceConfig.fail_stop(), None),
            ("warm", FaultToleranceConfig(), RecoveryConfig(enable=True))):
        t0 = time.time()
        r = _run_one(ft, recovery=rec)
        agg[name] = r
        results["variants"][name] = dict(r, wall_s=round(time.time() - t0, 1))
        emit(f"chaos.{name}", results["variants"][name]["wall_s"] * 1e6,
             f"goodput_rps={r['goodput_rps']:.3f};att={r['attainment']:.3f};"
             f"failed={r['failed']};evacuated={r['evacuated']};"
             f"recovered={r['recovered']}")

    on, off, warm = agg["recovery"], agg["fail_stop"], agg["warm"]
    gain = on["goodput_rps"] / max(off["goodput_rps"], 1e-9)
    warm_gain = warm["goodput_rps"] / max(on["goodput_rps"], 1e-9)
    results["summary"] = {
        "recovery_goodput_gain": round(gain, 4),
        "failstop_failed": off["failed"],
        "recovery_failed": on["failed"],
        "warm_goodput_gain": round(warm_gain, 4),
        "warm_restores": warm.get("warm_restores", 0),
        "warm_restored_tokens": warm.get("warm_restored_tokens", 0),
    }
    emit("chaos.recovery_goodput_gain", 0.0,
         f"x={gain:.3f};floor=1.0;failstop_failed={off['failed']}")
    emit("chaos.warm_goodput_gain", 0.0,
         f"x={warm_gain:.3f};floor=1.0;"
         f"warm_restores={warm.get('warm_restores', 0)}")
    path = write_json("chaos_bench", results)
    assert gain > 1.0, (
        f"recovery-on must strictly beat fail-stop goodput (got {gain:.3f}; "
        f"see {path})")
    assert off["failed"] > 0, "the fixed schedule never failed a request"
    assert warm_gain >= 1.0, (
        f"warm recovery must not lose goodput vs cold recompute "
        f"(got {warm_gain:.3f}; see {path})")
    assert warm.get("warm_restores", 0) > 0, \
        "the fixed crash never produced a warm restore"


if __name__ == "__main__":
    import sys
    sys.path.insert(0, "src")
    run()
