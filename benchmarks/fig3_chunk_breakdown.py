"""Paper Figure 3: mixed-batch execution time vs chunk size (batch 16).
Larger chunks -> more prefill tokens per iteration -> longer iterations
(linear-operation time dominates)."""
from benchmarks.common import cost_model, emit, timed


def run():
    cm = cost_model()
    out = {}
    for chunk in [0, 128, 256, 512, 1024, 2048]:
        with timed() as t:
            it = cm.decode_iteration_time(16, 1024, chunk_tokens=chunk)
        out[chunk] = it
        emit(f"fig3.cp{chunk}", t.us, f"iter_ms={it*1e3:.2f}")
    mono = all(out[a] <= out[b] + 1e-9
               for a, b in zip([0, 128, 256, 512, 1024],
                               [128, 256, 512, 1024, 2048]))
    emit("fig3.claim_monotone", 0, f"exec_time_increases_with_chunk={mono}")
    return out


if __name__ == "__main__":
    run()
