"""Shared benchmark harness: SLO regimes derived from profiled base
latencies (the paper's absolute SLOs are A100-specific; we scale to the
target TPU per DESIGN.md §3), CSV emission helpers, and machine-readable
JSON result files (benchmarks/out/<name>.json — CI uploads these as
artifacts)."""
from __future__ import annotations

import json
import os
import sys
import time

from repro.configs import get_config
from repro.core.estimator import CostModel
from repro.core.hw import InstanceSpec
from repro.core.latency import SLO
from repro.core.policies import Sliders
from repro.sim.simulator import ServingConfig

MODEL = "qwen2.5-14b"       # the paper's primary evaluation model
TP = 4


def cost_model(model: str = MODEL, tp: int = TP) -> CostModel:
    return CostModel(get_config(model), InstanceSpec(tp=tp))


def slo_regimes(model: str = MODEL, workload: str = "sharegpt"):
    """Three SLO regimes analogous to the paper's Table 2, scaled to our
    hardware: base_tpot = interference-free decode iteration; base_ttft =
    mean-prompt full prefill.  Returned dict: name -> SLO."""
    cm = cost_model(model)
    base_tpot = cm.decode_iteration_time(32, 1024)
    prompt = 430 if workload == "sharegpt" else 6000
    base_ttft = cm.prefill_time(prompt, 2048)
    return {
        # relaxed TTFT, tight TPOT -> disaggregation's home turf
        # (paper: 16 s / 60 ms on A100)
        "tight_tpot": SLO(ttft=base_ttft * 120, tpot=base_tpot * 1.25),
        # tight TTFT, relaxed TPOT -> aggregation's home turf
        # (paper: 5 s / 250 ms)
        "tight_ttft": SLO(ttft=base_ttft * 6, tpot=base_tpot * 5.0),
        # balanced -> the paper's contested regime (paper: 6 s / 100 ms)
        "balanced": SLO(ttft=base_ttft * 10, tpot=base_tpot * 1.9),
    }


def taichi_sliders_for(regime: str) -> Sliders:
    """TaiChi adapts its three sliders to the SLO regime (paper §3.1):
    tight TTFT -> aggregation-like (S_D == S_P); tight TPOT ->
    disaggregation-like (S_D ~ 0); balanced -> hybrid."""
    return {
        "tight_ttft": Sliders(2, 2, 1024, 1024),
        "tight_tpot": Sliders(2, 2, 4096, 64),
        "balanced": Sliders(2, 2, 1024, 256),
    }[regime]


def default_configs(model: str = MODEL):
    return {
        "aggregation": ServingConfig(
            model=model, tp=TP, policy="aggregation",
            sliders=Sliders(2, 2, 1024, 1024)),
        "disaggregation": ServingConfig(
            model=model, tp=TP, policy="disaggregation",
            sliders=Sliders(2, 2, 0, 0)),
        "taichi": ServingConfig(
            model=model, tp=TP, policy="taichi",
            sliders=Sliders(2, 2, 1024, 256)),
    }


def emit(name: str, us_per_call: float, derived: str):
    """The benchmarks/run.py contract: ``name,us_per_call,derived``."""
    print(f"{name},{us_per_call:.1f},{derived}")
    sys.stdout.flush()


def write_json(name: str, payload: dict, out_dir: str = None) -> str:
    """Write a machine-readable result file next to the CSV stream."""
    out_dir = out_dir or os.path.join(os.path.dirname(__file__), "out")
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


class timed:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.us = (time.time() - self.t0) * 1e6
