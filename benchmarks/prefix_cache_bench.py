"""Shared-prefix KV cache: TTFT / goodput deltas vs. the no-cache
baseline at matched QPS (sim cost model).

Runs the multi-turn and agentic workloads (>= 50% prefix share by
construction) through the TaiChi policy with the per-instance prefix
cache off and on, at the same QPS grid, and reports mean/p50/p99 TTFT,
SLO attainment, hit rate, and saved prefill tokens.  A cache-on but
routing-unaware ablation isolates how much comes from cache-aware
TTFT_hat vs. KV reuse itself.

Emits the usual ``name,us_per_call,derived`` CSV rows plus a machine-
readable JSON file (benchmarks/out/prefix_cache.json).

Usage:  PYTHONPATH=src:. python benchmarks/prefix_cache_bench.py
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import emit, slo_regimes, write_json
from repro.core.policies import Sliders
from repro.sim.simulator import ServingConfig, run_sim
from repro.sim.workload import AGENTIC, MULTITURN, measured_prefix_share

N_REQUESTS = 160
SEED = 0
# matched QPS points per workload: moderate load and near cache-off
# saturation (where queueing amplifies the prefill savings)
QPS = {"multiturn": (8.0, 16.0), "agentic": (8.0, 16.0)}


def _ttft_stats(st):
    return {
        "mean_ttft_s": round(st.mean_ttft, 4),
        "p50_ttft_s": round(st.ttft_percentile(50), 4),
        "p99_ttft_s": round(st.ttft_percentile(99), 4),
        "attainment": round(st.slo_attainment, 4),
        "cache_hit_rate": round(st.cache_hit_rate, 4),
        "saved_prefill_tokens": st.saved_prefill_tokens,
    }


def run():
    slo = slo_regimes(workload="sharegpt")["balanced"]
    base = ServingConfig(policy="taichi",
                         sliders=Sliders(2, 2, 1024, 256))
    results = {"n_requests": N_REQUESTS, "seed": SEED,
               "slo": {"ttft_s": slo.ttft, "tpot_s": slo.tpot},
               "workloads": {}}
    worst_reduction = None
    for wl in (MULTITURN, AGENTIC):
        share = measured_prefix_share(
            wl.sample_requests(N_REQUESTS, QPS[wl.name][0], seed=SEED))
        per_qps = []
        for qps in QPS[wl.name]:
            off = run_sim(base, slo, wl, qps, N_REQUESTS, seed=SEED)
            on = run_sim(dataclasses.replace(base, prefix_cache=True),
                         slo, wl, qps, N_REQUESTS, seed=SEED)
            blind = run_sim(dataclasses.replace(base, prefix_cache=True),
                            slo, wl, qps, N_REQUESTS, seed=SEED,
                            taichi_flags={"cache_aware": False})
            red = 1.0 - on.mean_ttft / off.mean_ttft
            worst_reduction = (red if worst_reduction is None
                               else min(worst_reduction, red))
            per_qps.append({
                "qps": qps,
                "cache_off": _ttft_stats(off),
                "cache_on": _ttft_stats(on),
                "cache_on_routing_blind": _ttft_stats(blind),
                "mean_ttft_reduction": round(red, 4),
            })
            emit(f"prefix_cache.{wl.name}.qps{qps:g}",
                 on.mean_ttft * 1e6,
                 f"mean_ttft_off_s={off.mean_ttft:.4f};"
                 f"mean_ttft_on_s={on.mean_ttft:.4f};"
                 f"reduction={red:.2f};hit_rate={on.cache_hit_rate:.2f};"
                 f"saved_tokens={on.saved_prefill_tokens};"
                 f"attain_off={off.slo_attainment:.2f};"
                 f"attain_on={on.slo_attainment:.2f}")
        results["workloads"][wl.name] = {
            "prefix_share": round(share, 4), "runs": per_qps}
    emit("prefix_cache.worst_mean_ttft_reduction", 0.0,
         f"reduction={worst_reduction:.2f};target=0.30")
    path = write_json("prefix_cache", results)
    emit("prefix_cache.json", 0.0, f"path={path}")
    assert worst_reduction >= 0.30, (
        f"mean TTFT reduction {worst_reduction:.2f} < 0.30 target")
    return results


if __name__ == "__main__":
    run()
