"""Paper Figure 7: P90 TTFT breakdown — queueing dominates TTFT in PD
disaggregation (Observation 3's mechanism)."""
import numpy as np

from benchmarks.common import default_configs, emit, slo_regimes, timed
from repro.sim.simulator import build_cluster
from repro.sim.workload import SHAREGPT


def _run(policy_name, sc, slo, qps=110.0, n=250):
    cluster = build_cluster(sc, slo)
    reqs = SHAREGPT.sample_requests(n, qps, seed=3)
    # estimate execution time of each request's prefill from the cost
    # model; queueing = TTFT - exec
    cluster.run(reqs)
    cm = cluster.cost
    rows = []
    for r in reqs:
        if r.ttft() is None:
            continue
        inst = next(i for i in cluster.instances
                    if i.iid == r.prefill_instance)
        exec_t = cm.prefill_time(r.prompt_len, max(inst.chunk_size, 1))
        rows.append((r.ttft(), min(exec_t, r.ttft())))
    ttfts = np.array([a for a, _ in rows])
    p90 = np.percentile(ttfts, 90)
    idx = np.argsort(ttfts)[int(0.9 * len(ttfts))]
    exec_t = rows[idx][1]
    queue_t = rows[idx][0] - exec_t
    return p90, exec_t, queue_t


def run():
    slo = slo_regimes()["balanced"]
    out = {}
    for pname, sc in default_configs().items():
        with timed() as t:
            p90, exec_t, queue_t = _run(pname, sc, slo)
        frac = queue_t / max(p90, 1e-9)
        out[pname] = frac
        emit(f"fig7.{pname}", t.us,
             f"p90_ttft={p90:.2f}s;exec={exec_t:.2f}s;queue={queue_t:.2f}s;"
             f"queue_frac={frac:.2f}")
    emit("fig7.claim_obs3", 0,
         "queueing_dominates_disagg_ttft="
         f"{out['disaggregation'] > out['aggregation']}")
    return out


if __name__ == "__main__":
    run()
