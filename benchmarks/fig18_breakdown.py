"""Paper Figure 18: stepwise technique breakdown on the summarization
workload — Base (small-chunk aggregation) -> +Arch (differentiated
instances) -> +Flowing Decode -> +Length-Aware Prefill.

Claim C6: each technique raises SLO attainment (paper: 66.6% -> 91.2%)."""
from benchmarks.common import MODEL, TP, emit, timed
from benchmarks.fig1516_goodput import _slos
from repro.core.policies import Sliders
from repro.sim.simulator import ServingConfig, run_sim
from repro.sim.workload import ARXIV

QPS = 8.0
N = 300


def run():
    slo = _slos("arxiv")["slo1"]
    steps = {
        "base_cp256": dict(
            sc=ServingConfig(MODEL, TP, "aggregation",
                             Sliders(2, 2, 256, 256)), flags=None),
        "arch": dict(
            sc=ServingConfig(MODEL, TP, "taichi",
                             Sliders(2, 2, 1024, 256)),
            flags={"enable_flowing": False, "length_aware": False}),
        "arch_flowing": dict(
            sc=ServingConfig(MODEL, TP, "taichi",
                             Sliders(2, 2, 1024, 256)),
            flags={"enable_flowing": True, "length_aware": False}),
        "arch_flowing_lengthaware": dict(
            sc=ServingConfig(MODEL, TP, "taichi",
                             Sliders(2, 2, 1024, 256)),
            flags={"enable_flowing": True, "length_aware": True}),
    }
    out = {}
    for name, d in steps.items():
        with timed() as t:
            st = run_sim(d["sc"], slo, ARXIV, QPS, N, seed=5,
                         taichi_flags=d["flags"])
        out[name] = st.slo_attainment
        emit(f"fig18.{name}", t.us,
             f"attainment={st.slo_attainment:.3f};"
             f"p90_ttft={st.p90_ttft:.2f}s;p90_tpot={st.p90_tpot*1e3:.1f}ms")
    improved = out["arch_flowing_lengthaware"] > out["base_cp256"]
    emit("fig18.claim_C6", 0,
         f"full_stack_beats_base={improved};"
         f"base={out['base_cp256']:.3f};"
         f"full={out['arch_flowing_lengthaware']:.3f}")
    return out


if __name__ == "__main__":
    run()
