"""Online slider controller vs. static / offline-searched sliders under
workload drift.

The scenario is ``sim.workload.DRIFT``: a single-token prompt-heavy
burst (wants every instance prefilling — aggregation-ward), a
decode-heavy tsunami (wants small chunks and a D-rich ratio —
disaggregation-ward), then multiturn chat (wants hybrid).  Every static
slider setting aces at most one phase; the adaptive controller retunes
S_D and drain-and-flips instance roles at epoch boundaries and must
deliver strictly higher goodput (SLO-attained requests per second over
the whole drift) than ANY static setting — including the
offline-searched one, which is the hindsight-best static on this exact
trace (a DistServe-style search-and-freeze upper bound).

Emits CSV rows via benchmarks.common.emit and a JSON result file
(benchmarks/out/controller_bench.json) with per-phase attainment,
controller moves, and the telemetry snapshot log; CI uploads the JSON
as an artifact.
"""
from __future__ import annotations

import time

from benchmarks.common import emit, write_json
from repro.core.latency import SLO
from repro.core.policies import Sliders
from repro.serving import ControllerConfig, ServingLoop, SliderController
from repro.sim.simulator import ServingConfig, build_cluster
from repro.sim.workload import DRIFT

MODEL = "qwen2.5-14b"
TP = 4
QPS = 18.0
SEED = 0
MAX_NEW = 768
SLO_DRIFT = SLO(ttft=1.2, tpot=0.024)
HBM_BLOCKS = 16384

#: the static grid: the paper's corner configurations plus hybrid
#: settings at both chunk knees and a D-rich ratio
STATIC_GRID = {
    "agg_1024": Sliders(2, 2, 1024, 1024),
    "hybrid_256": Sliders(2, 2, 1024, 256),
    "hybrid_64": Sliders(2, 2, 1024, 64),
    "d_rich_64": Sliders(1, 3, 1024, 64),
    "disagg": Sliders(2, 2, 4096, 0),
}

#: the controller starts from the D-rich config — "yesterday's tuning"
#: for decode-heavy traffic — and must walk to whatever each phase needs
CONTROLLER_START = Sliders(1, 3, 1024, 64)


def _phase_windows():
    t0, wins = 0.0, []
    for ph in DRIFT.phases:
        wins.append((t0, t0 + ph.duration))
        t0 += ph.duration
    return wins


def _run_one(sliders: Sliders, controller: bool):
    sc = ServingConfig(model=MODEL, tp=TP, policy="taichi",
                       sliders=sliders, hbm_blocks=HBM_BLOCKS)
    cluster = build_cluster(sc, SLO_DRIFT)
    ctl = SliderController(ControllerConfig(epoch=2.0, cooldown=1)) \
        if controller else None
    loop = ServingLoop(cluster, SLO_DRIFT,
                       arrivals=DRIFT.iter_requests(QPS, seed=SEED,
                                                    max_new_tokens=MAX_NEW),
                       controller=ctl, window=4.0, snapshot_every=4.0)
    loop.run()
    reqs = loop.requests
    ok = sum(SLO_DRIFT.satisfied(r) for r in reqs)
    goodput = ok / DRIFT.total_duration
    phases = []
    for lo, hi in _phase_windows():
        sel = [r for r in reqs if lo <= r.arrival < hi]
        phases.append(round(sum(SLO_DRIFT.satisfied(r) for r in sel)
                            / max(len(sel), 1), 4))
    st = loop.stats(QPS)
    return {
        "n": len(reqs), "ok": ok,
        "goodput_rps": round(goodput, 3),
        "attainment": round(ok / len(reqs), 4),
        "phase_attainment": phases,
        "role_flips": st.role_flips,
        "slider_moves": st.slider_moves,
        "early_rejections": st.early_rejections,
        "moves": list(ctl.moves) if ctl else [],
        # the decision audit trail: every epoch's input signals and
        # either its actions or the reason it held — the artifact that
        # explains every slider move above
        "audit": list(ctl.audit) if ctl else [],
        "snapshots": loop.log.snapshots if ctl else [],
    }


def run():
    results = {"qps": QPS, "slo": {"ttft": SLO_DRIFT.ttft,
                                   "tpot": SLO_DRIFT.tpot},
               "phases": [(p.spec.name, p.duration, p.qps_scale)
                          for p in DRIFT.phases],
               "static": {}, "online": None}
    best_static, best_name = None, None
    for name, sliders in STATIC_GRID.items():
        t0 = time.time()
        r = _run_one(sliders, controller=False)
        r["wall_s"] = round(time.time() - t0, 1)
        results["static"][name] = r
        emit(f"controller_bench.static.{name}", r["wall_s"] * 1e6,
             f"goodput_rps={r['goodput_rps']};att={r['attainment']};"
             f"phases={'/'.join(str(p) for p in r['phase_attainment'])}")
        if best_static is None or r["goodput_rps"] > best_static:
            best_static, best_name = r["goodput_rps"], name
    # "offline-searched" baseline == hindsight-best static on this trace
    results["offline_searched"] = {"name": best_name,
                                   "goodput_rps": best_static}
    emit("controller_bench.offline_searched", 0.0,
         f"config={best_name};goodput_rps={best_static}")

    t0 = time.time()
    on = _run_one(CONTROLLER_START, controller=True)
    on["wall_s"] = round(time.time() - t0, 1)
    results["online"] = on
    gain = on["goodput_rps"] / best_static if best_static else float("inf")
    emit("controller_bench.online", on["wall_s"] * 1e6,
         f"goodput_rps={on['goodput_rps']};att={on['attainment']};"
         f"phases={'/'.join(str(p) for p in on['phase_attainment'])};"
         f"flips={on['role_flips']};moves={on['slider_moves']};"
         f"gain_vs_best_static={gain:.3f}")
    path = write_json("controller_bench", results)
    emit("controller_bench.json", 0.0, f"path={path}")
    assert on["goodput_rps"] > best_static, (
        f"online controller goodput {on['goodput_rps']} must strictly "
        f"beat every static setting (best: {best_name}={best_static})")
    return results


if __name__ == "__main__":
    run()
