"""Paper Figure 8 / Observation 3: prefill processing capacity (tokens/s)
by configuration — larger chunks raise capacity; disaggregation's capacity
is bounded by its prefill-instance count."""
from benchmarks.common import cost_model, emit, timed


def run():
    cm = cost_model()
    out = {}
    with timed() as t:
        # chunked-prefill aggregation: all 4 instances prefill
        for chunk in [256, 512, 1024, 2048]:
            cap = 4 * cm.prefill_capacity(chunk, decode_batch=16)
            out[f"CP{chunk}"] = cap
        # disaggregation PxDy: only x instances prefill, full-prompt chunks
        for x in [1, 2, 3]:
            cap = x * cm.prefill_capacity(16384, decode_batch=0)
            out[f"P{x}D{4-x}"] = cap
    for k, v in out.items():
        emit(f"fig8.{k}", t.us / len(out), f"prefill_tokens_per_s={v:.0f}")
    c3a = out["CP2048"] > out["CP512"] > out["CP256"]
    c3b = out["CP1024"] > out["P3D1"]
    emit("fig8.claim_C3", 0,
         f"capacity_grows_with_chunk={c3a};"
         f"aggregation_capacity_exceeds_disagg={c3b}")
    return out


if __name__ == "__main__":
    run()
