"""Multi-tier KV under memory pressure: goodput and TTFT with the pool
sized to force eviction, with and without the tier stack.

The scenario is multiturn chat (shared system prompts + growing session
history) against an HBM block pool far smaller than the traffic's
prefix working set, so refcount-0 cached blocks are continually
evicted.  Four variants at the same arrival trace:

* ``drop``       — prefix cache only: eviction discards blocks, a later
                   hit on the evicted range silently recomputes.
* ``spill``      — host-RAM spill tier: evicted blocks are copied out
                   and promoted back on the next radix hit, so the
                   recompute spikes (the TTFT tail) disappear.
* ``spill_repl`` — plus epoch-boundary hot-prefix replication: the
                   controller copies each instance's hottest prefixes
                   to the coldest peer, so cache-aware routing can
                   place hot-prefix traffic on any instance instead of
                   pinning it to the one holder.
* ``int8_tiers`` — the full stack at the SAME HBM byte budget: the
                   measured int8 effective-capacity ratio (live probe
                   on the bench model, vs an fp16 pool) buys
                   proportionally more blocks, plus spill+replication.

Every variant runs at ``len(SEEDS)`` seeds; assertions are on the
seed-aggregated numbers (the sim is deterministic per seed, so these
reproduce exactly across machines): the int8 ratio clears the 1.8x
acceptance floor, spill beats drop-and-recompute on mean and p99 TTFT,
and the tier stack wins goodput.

Emits CSV rows via benchmarks.common.emit and JSON to
benchmarks/out/kv_pressure.json; the slow-CI regression gate
(benchmarks/check_regression.py --kv) re-checks the recorded floors.
"""
from __future__ import annotations

import time

from benchmarks.common import emit, slo_regimes, write_json
from repro.core.policies import Sliders
from repro.serving import ControllerConfig, ServingLoop, SliderController
from repro.sim.simulator import ServingConfig, build_cluster
from repro.sim.workload import MULTITURN

QPS = 24.0
N_REQUESTS = 200
SEEDS = (0, 1, 2)
MAX_NEW = 512
POOL_BLOCKS = 768        # per instance; multiturn's prefix working set
                         # at this rate is several times larger
SPILL_BLOCKS = 4096      # host tier: "RAM is cheap" sizing
SLIDERS = Sliders(2, 2, 1024, 256)
CAPACITY_FLOOR = 1.8     # acceptance: int8 tokens/byte vs fp16 pool


def _int8_capacity_ratio() -> float:
    """Live probe: bytes per resident token, fp16 pool vs int8+scales,
    on the bench model config (no pool materialized beyond one block)."""
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.engine.paged import PagedKVCache
    cfg = get_config("qwen2.5-14b")
    fp16 = PagedKVCache.token_bytes_for(cfg, dtype=jnp.bfloat16)
    q = PagedKVCache.token_bytes_for(cfg, quant="int8")
    return fp16 / q


def _run_one(slo, seed: int, blocks: int, spill: int, replicate: bool):
    sc = ServingConfig(policy="taichi", sliders=SLIDERS,
                       hbm_blocks=blocks, prefix_cache=True,
                       spill_blocks=spill)
    cluster = build_cluster(sc, slo, seed=seed)
    ctl = None
    if replicate:
        # replication only: min_evidence keeps the slider/flip machinery
        # inert so the comparison isolates the cache tiers
        ctl = SliderController(ControllerConfig(
            epoch=2.0, replicate=True, min_evidence=10**9))
    loop = ServingLoop(cluster, slo,
                       arrivals=MULTITURN.iter_requests(
                           QPS, seed=seed, max_new_tokens=MAX_NEW,
                           limit=N_REQUESTS),
                       controller=ctl, window=4.0)
    loop.run()
    st = loop.stats(QPS)
    ok = sum(slo.satisfied(r) for r in st.reqs)
    pcs = [i.prefix_cache for i in cluster.instances
           if i.prefix_cache is not None]
    return {
        "n": len(st.reqs), "ok": ok,
        "goodput_rps": ok / st.wall,
        "attainment": round(st.slo_attainment, 4),
        "mean_ttft_s": st.mean_ttft,
        "p99_ttft_s": st.ttft_percentile(99),
        "cache_hit_rate": round(st.cache_hit_rate, 4),
        "saved_prefill_tokens": st.saved_prefill_tokens,
        "spilled_blocks": sum(pc.spill.spilled for pc in pcs if pc.spill),
        "promoted_blocks": sum(pc.spill.promoted for pc in pcs if pc.spill),
        "replications": cluster.replication_count,
    }


def _agg(runs):
    """Mean over seeds of every numeric field."""
    out = {}
    for k in runs[0]:
        out[k] = round(sum(r[k] for r in runs) / len(runs), 4)
    return out


def run():
    ratio = _int8_capacity_ratio()
    emit("kv_pressure.int8_capacity_ratio", 0.0,
         f"tokens_per_byte_vs_fp16={ratio:.3f};floor={CAPACITY_FLOOR}")

    slo = slo_regimes()["balanced"]
    variants = {
        "drop": (POOL_BLOCKS, 0, False),
        "spill": (POOL_BLOCKS, SPILL_BLOCKS, False),
        "spill_repl": (POOL_BLOCKS, SPILL_BLOCKS, True),
        # same HBM byte budget, quantized: ratio x the blocks
        "int8_tiers": (int(POOL_BLOCKS * ratio), SPILL_BLOCKS, True),
    }
    results = {"qps": QPS, "n_requests": N_REQUESTS, "seeds": list(SEEDS),
               "pool_blocks": POOL_BLOCKS, "spill_blocks": SPILL_BLOCKS,
               "slo": {"ttft_s": slo.ttft, "tpot_s": slo.tpot},
               "int8_capacity_ratio": round(ratio, 4),
               "variants": {}}
    agg = {}
    for name, (blocks, spill, repl) in variants.items():
        t0 = time.time()
        runs = [_run_one(slo, s, blocks, spill, repl) for s in SEEDS]
        a = _agg(runs)
        agg[name] = a
        results["variants"][name] = {
            "hbm_blocks": blocks, "spill_blocks": spill,
            "replicate": repl, "per_seed": runs, "agg": a,
            "wall_s": round(time.time() - t0, 1)}
        emit(f"kv_pressure.{name}", results["variants"][name]["wall_s"] * 1e6,
             f"goodput_rps={a['goodput_rps']:.3f};att={a['attainment']:.3f};"
             f"mean_ttft_s={a['mean_ttft_s']:.4f};"
             f"p99_ttft_s={a['p99_ttft_s']:.4f};"
             f"hit={a['cache_hit_rate']:.3f};"
             f"spilled={a['spilled_blocks']:.0f};"
             f"promoted={a['promoted_blocks']:.0f};"
             f"repl={a['replications']:.0f}")

    drop, spill = agg["drop"], agg["spill"]
    best_tiered = max((agg[n] for n in ("spill", "spill_repl", "int8_tiers")),
                      key=lambda a: a["goodput_rps"])
    results["summary"] = {
        "spill_mean_ttft_reduction":
            round(1.0 - spill["mean_ttft_s"] / drop["mean_ttft_s"], 4),
        "spill_p99_ttft_reduction":
            round(1.0 - spill["p99_ttft_s"] / drop["p99_ttft_s"], 4),
        "tiered_goodput_gain":
            round(best_tiered["goodput_rps"] / drop["goodput_rps"], 4),
        "int8_goodput_gain":
            round(agg["int8_tiers"]["goodput_rps"] / drop["goodput_rps"], 4),
    }
    s = results["summary"]
    emit("kv_pressure.summary", 0.0,
         f"spill_mean_ttft_reduction={s['spill_mean_ttft_reduction']:.3f};"
         f"spill_p99_ttft_reduction={s['spill_p99_ttft_reduction']:.3f};"
         f"tiered_goodput_gain={s['tiered_goodput_gain']:.3f};"
         f"int8_goodput_gain={s['int8_goodput_gain']:.3f}")
    path = write_json("kv_pressure", results)
    emit("kv_pressure.json", 0.0, f"path={path}")

    assert ratio >= CAPACITY_FLOOR, (
        f"int8 effective capacity {ratio:.3f}x < {CAPACITY_FLOOR}x floor")
    assert agg["drop"]["spilled_blocks"] == 0 and spill["spilled_blocks"] > 0, \
        "pool must be sized to force eviction for the comparison to mean " \
        "anything"
    assert spill["mean_ttft_s"] < drop["mean_ttft_s"], (
        f"spill mean TTFT {spill['mean_ttft_s']:.4f} must beat "
        f"drop-and-recompute {drop['mean_ttft_s']:.4f}")
    assert spill["p99_ttft_s"] < drop["p99_ttft_s"], (
        f"spill p99 TTFT {spill['p99_ttft_s']:.4f} must beat "
        f"drop-and-recompute {drop['p99_ttft_s']:.4f}")
    assert best_tiered["goodput_rps"] > drop["goodput_rps"], (
        f"tier stack goodput {best_tiered['goodput_rps']:.3f} must beat "
        f"no-tiers {drop['goodput_rps']:.3f}")
    return results


if __name__ == "__main__":
    run()
