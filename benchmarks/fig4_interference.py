"""Paper Figure 4 / Observation 2: per-request TPOT is linear in
interference intensity (prefill tokens per output token), R^2 ~ 0.99.

We run PD aggregation (CP1024) under load and regress each finished
request's measured TPOT on its measured interference intensity."""
import numpy as np

from benchmarks.common import default_configs, emit, slo_regimes, timed
from repro.sim.simulator import run_sim
from repro.sim.workload import SHAREGPT


def run():
    slo = slo_regimes()["balanced"]
    sc = default_configs()["aggregation"]
    with timed() as t:
        st = run_sim(sc, slo, SHAREGPT, qps=110.0, n_requests=400, seed=1)
    pts = [(r.interference_intensity(), r.tpot()) for r in st.reqs
           if r.tpot() is not None and r.interference_intensity() is not None
           and r.output_len >= 8]
    x = np.array([p[0] for p in pts])
    y = np.array([p[1] for p in pts])
    slope, intercept = np.polyfit(x, y, 1)
    resid = y - (slope * x + intercept)
    r2 = 1 - resid.var() / y.var()
    emit("fig4.linear_fit", t.us,
         f"n={len(pts)};slope_ms_per_tok={slope*1e3:.4f};"
         f"intercept_ms={intercept*1e3:.2f};r2={r2:.4f}")
    emit("fig4.claim_C2", 0, f"tpot_linear_in_interference_r2>0.9={r2 > 0.9}")
    return {"slope": slope, "intercept": intercept, "r2": r2}


if __name__ == "__main__":
    run()
