"""Tracing overhead benchmark: what does the observability layer cost?

Runs the same deterministic simulated serving workload three ways —
tracing off (the default), tracing on (phases + events), and tracing on
with per-request events disabled (phases only) — and measures:

* **bit-identicality** — with tracing off OR on, every request's
  (state, finish_time, output_len, first_token_time) must match
  exactly: the tracer is observational only, so virtual-time outcomes
  (and therefore goodput) cannot move at all;
* **wall overhead** — host seconds per run (min over repeats): the real
  cost of tracing is Python bookkeeping time, and the acceptance bound
  is that it stays a small fraction of the untraced run.

Emits CSV rows via benchmarks.common.emit and JSON to
benchmarks/out/trace_overhead_bench.json; the slow-CI gate
(benchmarks/check_regression.py --trace) re-checks bit-identicality,
the goodput ratio floor, and the wall-overhead ceiling.
"""
from __future__ import annotations

import time

from benchmarks.common import emit, write_json

N_REQUESTS = 400
QPS = 60.0
SEED = 17
REPEATS = 3

#: acceptance bounds re-checked by check_regression.py --trace
GOODPUT_RATIO_FLOOR = 0.95      # traced/untraced goodput (sim: == 1.0)
WALL_OVERHEAD_CEIL = 1.00       # traced wall time <= 2x untraced —
                                # loose for CI jitter (~20-45% measured
                                # locally); catches the tracer leaking
                                # onto the hot path structurally


def _run_once(tracing):
    from repro.core.latency import SLO
    from repro.core.policies import Sliders
    from repro.serving import ServingLoop
    from repro.sim.simulator import ServingConfig, build_cluster
    from repro.sim.workload import SHAREGPT

    slo = SLO(ttft=1.5, tpot=0.030)
    reqs = SHAREGPT.sample_requests(N_REQUESTS, QPS, seed=SEED)
    sc = ServingConfig(sliders=Sliders(2, 2, 1024, 256), hbm_blocks=4096)
    cluster = build_cluster(sc, slo)
    loop = ServingLoop(cluster, slo, arrivals=iter(reqs), steal=False,
                       tracing=tracing)
    t0 = time.perf_counter()
    loop.run()
    wall = time.perf_counter() - t0
    st = loop.stats(QPS)
    # rids come from a process-global counter, so they differ run to
    # run — the outcome signature keys on everything else
    sig = [(r.state.value, r.finish_time, r.output_len,
            r.first_token_time) for r in loop.requests]
    # virtual-time goodput: SLO-attained requests per second offered
    return wall, st.slo_attainment * QPS, sig, loop


def _best_of(tracing):
    walls, out = [], None
    for _ in range(REPEATS):
        wall, goodput, sig, loop = _run_once(tracing)
        walls.append(wall)
        out = (goodput, sig, loop)
    return min(walls), out[0], out[1], out[2]


def run():
    from repro.serving import TraceConfig

    wall_off, gp_off, sig_off, _ = _best_of(None)
    wall_on, gp_on, sig_on, loop_on = _best_of(TraceConfig())
    wall_ph, gp_ph, sig_ph, _ = _best_of(TraceConfig(events=False))

    bit_identical = (sig_on == sig_off) and (sig_ph == sig_off)
    overhead = (wall_on - wall_off) / wall_off if wall_off else 0.0
    overhead_ph = (wall_ph - wall_off) / wall_off if wall_off else 0.0
    tr = loop_on.tracer
    n_spans = sum(len(t.spans) for t in tr.traces())
    n_events = sum(len(t.events) for t in tr.traces())

    emit("trace_overhead.off", wall_off * 1e6 / N_REQUESTS,
         f"wall_s={wall_off:.3f}")
    emit("trace_overhead.on", wall_on * 1e6 / N_REQUESTS,
         f"wall_s={wall_on:.3f};overhead={overhead * 100:.1f}%")
    emit("trace_overhead.phases_only", wall_ph * 1e6 / N_REQUESTS,
         f"wall_s={wall_ph:.3f};overhead={overhead_ph * 100:.1f}%")
    emit("trace_overhead.bit_identical", 0.0,
         f"{bit_identical};spans={n_spans};events={n_events}")

    path = write_json("trace_overhead_bench", {
        "n_requests": N_REQUESTS, "qps": QPS, "seed": SEED,
        "repeats": REPEATS,
        "wall_s": {"off": round(wall_off, 4), "on": round(wall_on, 4),
                   "phases_only": round(wall_ph, 4)},
        "wall_overhead_frac": round(overhead, 4),
        "wall_overhead_frac_phases_only": round(overhead_ph, 4),
        "goodput_rps": {"off": round(gp_off, 4), "on": round(gp_on, 4)},
        "goodput_ratio": round(gp_on / gp_off, 6) if gp_off else 1.0,
        "bit_identical": bit_identical,
        "spans": n_spans, "events": n_events,
        "traced_requests": len(tr),
        "bounds": {"goodput_ratio_floor": GOODPUT_RATIO_FLOOR,
                   "wall_overhead_ceil": WALL_OVERHEAD_CEIL},
    })
    print(f"# wrote {path}")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
