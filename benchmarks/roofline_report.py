"""Roofline table from the dry-run artifacts (runs/dryrun/*.json):
per (arch x shape x mesh) the three terms, dominant bottleneck, model-
flops ratio, and HBM fit."""
import glob
import json
import os

from benchmarks.common import emit

RUNS = os.path.join(os.path.dirname(__file__), "..", "runs", "dryrun")


def run():
    files = sorted(glob.glob(os.path.join(RUNS, "*.json")))
    if not files:
        emit("roofline.missing", 0,
             "no dry-run artifacts; run python -m repro.launch.dryrun --all")
        return {}
    out = {}
    for f in files:
        r = json.load(open(f))
        rf = r["roofline"]
        key = f"{r['arch']}.{r['shape']}.{r['mesh']}"
        out[key] = rf
        emit(f"roofline.{key}", r.get("compile_s", 0) * 1e6,
             f"compute_s={rf['compute_s']};memory_s={rf['memory_s']};"
             f"collective_s={rf['collective_s']};dominant={rf['dominant']};"
             f"useful_ratio={rf['useful_flops_ratio']};"
             f"peak_GiB={r['peak_bytes_per_device']/2**30:.2f};"
             f"fits_hbm={rf['fits_hbm']}")
    return out


if __name__ == "__main__":
    run()
