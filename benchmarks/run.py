"""Benchmark harness entry point: one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows (see benchmarks/common.py).

  PYTHONPATH=src python -m benchmarks.run [--only fig4,table2]
"""
import argparse
import importlib
import sys
import traceback

MODULES = [
    "table2_slo_matrix",     # Table 2 / Figs 1-2 (Observation 1)
    "fig3_chunk_breakdown",  # Fig 3
    "fig4_interference",     # Fig 4 (Observation 2)
    "fig56_latency_configs", # Figs 5-6
    "fig7_ttft_breakdown",   # Fig 7 (Observation 3)
    "fig8_prefill_capacity", # Fig 8
    "fig1516_goodput",       # Figs 15-16 (headline C4)
    "fig17_latency_reduction",  # Fig 17 (C5)
    "fig18_breakdown",       # Fig 18 (C6)
    "fig19_overhead",        # Fig 19 (C7)
    "prefix_cache_bench",    # shared-prefix KV cache vs. no-cache baseline
    "controller_bench",      # online slider controller vs. static/offline
    "kv_pressure_bench",     # multi-tier KV under a constrained pool
    "chaos_bench",           # goodput under injected faults vs fail-stop
    "frontend_bench",        # HTTP/SSE front-end socket-level smoke
    "trace_overhead_bench",  # lifecycle tracing cost + bit-identicality
    "kernel_bench",          # kernels microbench
    "roofline_report",       # dry-run roofline table
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None
    print("name,us_per_call,derived")
    failed = []
    for m in MODULES:
        if only and m not in only and not any(m.startswith(o) for o in only):
            continue
        try:
            mod = importlib.import_module(f"benchmarks.{m}")
            mod.run()
        except Exception as e:
            failed.append(m)
            print(f"{m}.ERROR,0,{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
