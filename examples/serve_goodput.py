"""Goodput comparison (the paper's headline experiment, Figs 15/16) at
simulator scale: PD aggregation vs PD disaggregation vs TaiChi on the
ShareGPT-like chatbot workload under a balanced SLO.

  PYTHONPATH=src python examples/serve_goodput.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.latency import SLO
from repro.core.policies import Sliders
from repro.sim.simulator import ServingConfig, goodput_sweep
from repro.sim.workload import SHAREGPT


def main():
    slo = SLO(ttft=1.5, tpot=0.030)
    grid = [60, 80, 100, 110, 120, 130]
    configs = {
        "PD aggregation   ": ServingConfig(
            policy="aggregation", sliders=Sliders(2, 2, 1024, 1024)),
        "PD disaggregation": ServingConfig(
            policy="disaggregation", sliders=Sliders(2, 2, 0, 0)),
        "TaiChi (hybrid)  ": ServingConfig(
            policy="taichi", sliders=Sliders(2, 2, 1024, 256)),
    }
    print(f"balanced SLO: TTFT<{slo.ttft}s TPOT<{slo.tpot*1e3:.0f}ms; "
          f"goodput = max QPS with >=90% attainment\n")
    results = {}
    for name, sc in configs.items():
        g, stats = goodput_sweep(sc, slo, SHAREGPT, grid, n_requests=250)
        results[name] = g
        curve = "  ".join(f"{s.qps:g}:{s.slo_attainment:.2f}"
                          for s in stats)
        print(f"{name} goodput={g:>5g} qps   [{curve}]")
    tai = results["TaiChi (hybrid)  "]
    for name, g in results.items():
        if "TaiChi" not in name and g > 0:
            print(f"TaiChi vs {name.strip()}: "
                  f"{(tai - g) / g * 100:+.0f}% goodput")


if __name__ == "__main__":
    main()
