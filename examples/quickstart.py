"""Quickstart: serve a small model with batched requests through the
full TaiChi stack (proxy -> P-heavy/D-heavy instances -> real JAX engine)
on CPU.  Tokens are really computed; time is the target-hardware
estimator's (so scheduling behaves as it would on TPU).

  PYTHONPATH=src python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs import reduced_config
from repro.core.cluster import Cluster
from repro.core.estimator import CostModel
from repro.core.hw import InstanceSpec
from repro.core.latency import SLO
from repro.core.policies import Sliders, TaiChiPolicy, build_instances
from repro.engine.engine import JaxExecutor
from repro.models import transformer as tf
from repro.sim.workload import LengthDist, WorkloadSpec


def main():
    cfg = reduced_config("smollm-135m")
    print(f"model: {cfg.name} ({cfg.param_count()/1e6:.1f}M params)")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    cost = CostModel(cfg, InstanceSpec(tp=1))

    # TaiChi sliders: 1 P-heavy (chunk 32) + 1 D-heavy (chunk 16)
    sliders = Sliders(n_p=1, n_d=1, s_p=32, s_d=16)
    instances = build_instances(
        cost, sliders, lambda: JaxExecutor(cfg, params, n_slots=8,
                                           max_seq=512),
        hbm_blocks=256, block_size=16)
    slo = SLO(ttft=5.0, tpot=0.5)
    policy = TaiChiPolicy(instances, cost, slo.ttft, slo.tpot, sliders)
    cluster = Cluster(policy, cost)

    wl = WorkloadSpec("demo",
                      LengthDist(mu=3.2, sigma=0.4, lo=8, hi=96),
                      LengthDist(mu=2.0, sigma=0.5, lo=2, hi=16))
    reqs = wl.sample_requests(16, qps=4.0, seed=0)
    print(f"serving {len(reqs)} requests...")
    cluster.run(reqs)

    for r in reqs[:5]:
        print(f"  req {r.rid}: prompt={r.prompt_len:3d} -> "
              f"{len(r.output_tokens)} tokens "
              f"(ttft={r.ttft()*1e3:6.1f}ms tpot="
              f"{(r.tpot() or 0)*1e3:5.1f}ms "
              f"prefill@inst{r.prefill_instance} "
              f"decode@inst{r.decode_instance}) "
              f"tokens={r.output_tokens[:6]}...")
    st = cluster.stats(reqs, slo, 4.0)
    print(f"attainment={st.slo_attainment:.2f} "
          f"p90_ttft={st.p90_ttft*1e3:.0f}ms "
          f"p90_tpot={st.p90_tpot*1e3:.1f}ms "
          f"transfers={cluster.transfer_count}")


if __name__ == "__main__":
    main()
