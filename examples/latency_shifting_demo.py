"""Latency-shifting under the microscope: drive a small TaiChi cluster
into memory pressure and watch Algorithm 1 (flowing decode) move the
longest-output request to a P-heavy instance and flow it back as its
TPOT approaches the SLO — with REAL token generation preserved across
the migrations (the engine is bit-exact across flows).

  PYTHONPATH=src python examples/latency_shifting_demo.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs import reduced_config
from repro.core.cluster import Cluster
from repro.core.estimator import CostModel
from repro.core.hw import InstanceSpec
from repro.core.latency import SLO
from repro.core.policies import Sliders, TaiChiPolicy, build_instances
from repro.engine.engine import JaxExecutor
from repro.engine.request import Request
from repro.models import transformer as tf


def main():
    cfg = reduced_config("smollm-135m")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    cost = CostModel(cfg, InstanceSpec(tp=1))
    sliders = Sliders(n_p=1, n_d=1, s_p=64, s_d=16,
                      watermark=0.5, alpha=0.9)
    instances = build_instances(
        cost, sliders, lambda: JaxExecutor(cfg, params, n_slots=8,
                                           max_seq=512),
        hbm_blocks=24, block_size=16)          # tiny HBM -> pressure
    slo = SLO(ttft=10.0, tpot=0.2)
    policy = TaiChiPolicy(instances, cost, slo.ttft, slo.tpot, sliders)
    cluster = Cluster(policy, cost)

    # simultaneous burst so decodes overlap and D-heavy HBM crosses the
    # watermark while outputs are mid-flight
    reqs = [Request(prompt_len=48, max_new_tokens=32,
                    hidden_output_len=32, arrival=0.0)
            for i in range(8)]
    cluster.run(reqs)

    print(f"degrade flows: {cluster.degrade_count}  "
          f"backflows: {cluster.backflow_count}  "
          f"total transfers: {cluster.transfer_count}")
    for r in reqs:
        print(f"  req {r.rid}: migrations={r.n_migrations} "
              f"out={len(r.output_tokens)}/{r.target_output_len} "
              f"tpot={(r.tpot() or 0)*1e3:.1f}ms")
    assert cluster.degrade_count > 0, \
        "expected watermark-triggered degradation"
    print("\nflowing decode demonstrated with real token generation.")


if __name__ == "__main__":
    main()
