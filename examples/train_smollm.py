"""End-to-end training driver: train a ~135M-class LM (smollm-135m
family) for a few hundred steps on synthetic LM data with AdamW +
cosine schedule + checkpointing.

On CPU we default to the reduced config and 200 steps so the example
finishes in minutes; pass --full to train the real 135M config (slow on
CPU, the intended path on the TPU meshes via repro.launch.train).

  PYTHONPATH=src python examples/train_smollm.py [--steps 200] [--full]
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse

from repro.configs import get_config, reduced_config
from repro.training.checkpoint import save_checkpoint
from repro.training.train import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--ckpt", default="runs/smollm_ckpt.npz")
    args = ap.parse_args()

    cfg = (get_config("smollm-135m") if args.full
           else reduced_config("smollm-135m"))
    print(f"training {cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"{args.steps} steps, batch={args.batch}, seq={args.seq}")
    params, history = train_loop(cfg, args.steps, args.batch, args.seq,
                                 log_every=max(args.steps // 20, 1))
    for h in history:
        print(f"  step {h['step']:4d}  loss {h['loss']:.4f}  "
              f"gnorm {h['grad_norm']:.3f}  ({h['elapsed_s']}s)")
    first, last = history[0]["loss"], history[-1]["loss"]
    print(f"loss: {first:.3f} -> {last:.3f} "
          f"({(1 - last / first) * 100:.1f}% reduction)")
    save_checkpoint(args.ckpt, params, meta={"arch": cfg.name,
                                             "steps": args.steps})
    print("checkpoint:", args.ckpt)


if __name__ == "__main__":
    main()
