"""Checkpointing: save/restore params + optimizer state as a flat .npz
(no orbax offline).  Tree structure is reconstructed from the config, so
a checkpoint restores exactly onto a freshly-initialized model."""
from __future__ import annotations

import json
import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.training.optimizer import OptState


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in sorted(tree.items()):
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (tuple, list)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix.rstrip("/")] = np.asarray(tree)
    return out


def save_checkpoint(path: str, params, opt_state: Optional[OptState] = None,
                    meta: Optional[dict] = None):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    arrays = {"params/" + k: v for k, v in _flatten(params).items()}
    if opt_state is not None:
        arrays["opt/step"] = np.asarray(opt_state.step)
        arrays.update({"opt/m/" + k: v
                       for k, v in _flatten(opt_state.m).items()})
        arrays.update({"opt/v/" + k: v
                       for k, v in _flatten(opt_state.v).items()})
    np.savez(path, **arrays)
    if meta is not None:
        with open(path + ".meta.json", "w") as f:
            json.dump(meta, f, indent=2)


def _unflatten_into(template, flat, prefix):
    if isinstance(template, dict):
        return {k: _unflatten_into(v, flat, f"{prefix}{k}/")
                for k, v in template.items()}
    if isinstance(template, tuple):
        return tuple(_unflatten_into(v, flat, f"{prefix}{i}/")
                     for i, v in enumerate(template))
    if isinstance(template, list):
        return [_unflatten_into(v, flat, f"{prefix}{i}/")
                for i, v in enumerate(template)]
    arr = flat[prefix.rstrip("/")]
    return jnp.asarray(arr, dtype=template.dtype)


def load_checkpoint(path: str, params_template,
                    opt_template: Optional[OptState] = None):
    data = np.load(path)
    flat = {k: data[k] for k in data.files}
    pflat = {k[len("params/"):]: v for k, v in flat.items()
             if k.startswith("params/")}
    params = _unflatten_into(params_template, pflat, "")
    opt_state = None
    if opt_template is not None and "opt/step" in flat:
        mflat = {k[len("opt/m/"):]: v for k, v in flat.items()
                 if k.startswith("opt/m/")}
        vflat = {k[len("opt/v/"):]: v for k, v in flat.items()
                 if k.startswith("opt/v/")}
        opt_state = OptState(
            step=jnp.asarray(flat["opt/step"]),
            m=_unflatten_into(opt_template.m, mflat, ""),
            v=_unflatten_into(opt_template.v, vflat, ""))
    return params, opt_state
