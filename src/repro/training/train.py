"""Training step + loop: next-token LM objective on any registered arch.

``make_train_step`` returns the jittable (params, opt_state, batch) ->
(params, opt_state, metrics) function used both by the CPU example
(train a ~100M smollm on synthetic data) and by the multi-pod dry-run
(lowered with ShapeDtypeStructs under the production mesh).
"""
from __future__ import annotations

import functools
import time
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import transformer as tf
from repro.models.config import ModelConfig
from repro.training.optimizer import (AdamWConfig, OptState, adamw_update,
                                      init_opt_state)


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    accum_steps: int = 1) -> Callable:
    """accum_steps > 1 splits the global batch into microbatches and
    accumulates grads in f32 via lax.scan — activation / MoE-dispatch
    peak memory scales down by ~accum_steps at the cost of re-running
    the forward pass per microbatch (a §Perf lever for memory-bound
    training shapes like arctic-480b x train_4k)."""

    def _grads(params, batch):
        return jax.value_and_grad(
            lambda p: tf.train_loss(p, cfg, batch))(params)

    def train_step(params, opt_state: OptState, batch: Dict):
        if accum_steps == 1:
            loss, grads = _grads(params, batch)
        else:
            def split(a):
                return a.reshape((accum_steps, a.shape[0] // accum_steps)
                                 + a.shape[1:])
            micro = jax.tree.map(split, batch)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def body(carry, mb):
                g_acc, l_acc = carry
                loss, g = _grads(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + loss), ()

            (g_sum, l_sum), _ = jax.lax.scan(
                body, (zeros, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda g: g / accum_steps, g_sum)
            loss = l_sum / accum_steps
        params, opt_state, gnorm = adamw_update(opt_cfg, params, grads,
                                                opt_state)
        metrics = {"loss": loss, "grad_norm": gnorm,
                   "step": opt_state.step}
        return params, opt_state, metrics

    return train_step


def make_eval_step(cfg: ModelConfig) -> Callable:
    def eval_step(params, batch):
        return tf.train_loss(params, cfg, batch)
    return eval_step


def synthetic_lm_batches(cfg: ModelConfig, batch: int, seq: int,
                         seed: int = 0, structured: bool = True):
    """Infinite synthetic LM stream.  ``structured`` embeds learnable
    bigram patterns so loss measurably decreases (tests assert this)."""
    rng = jax.random.PRNGKey(seed)
    V = cfg.vocab_size
    while True:
        rng, k1, k2 = jax.random.split(rng, 3)
        if structured:
            # Markov-ish: next token = (token * 7 + noise) % V
            first = jax.random.randint(k1, (batch, 1), 0, V)
            noise = jax.random.bernoulli(k2, 0.1, (batch, seq))

            def step(tok, nz):
                nxt = jnp.where(nz, (tok * 31 + 17) % V, (tok * 7 + 3) % V)
                return nxt, nxt

            _, toks = jax.lax.scan(step, first[:, 0],
                                   jnp.moveaxis(noise, 1, 0))
            tokens = jnp.concatenate([first, jnp.moveaxis(toks, 0, 1)],
                                     axis=1)[:, :seq]
        else:
            tokens = jax.random.randint(k1, (batch, seq), 0, V)
        yield {"tokens": tokens, "labels": tokens}


def train_loop(cfg: ModelConfig, steps: int, batch: int, seq: int,
               opt_cfg: Optional[AdamWConfig] = None, seed: int = 0,
               log_every: int = 10, params=None):
    """CPU-scale training driver; returns (params, history)."""
    opt_cfg = opt_cfg or AdamWConfig(total_steps=steps,
                                     warmup_steps=max(steps // 20, 1))
    if params is None:
        params = tf.init_params(jax.random.PRNGKey(seed), cfg)
    opt_state = init_opt_state(params)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg))
    data = synthetic_lm_batches(cfg, batch, seq, seed)
    history = []
    t0 = time.time()
    for i in range(steps):
        batch_data = next(data)
        params, opt_state, metrics = step_fn(params, opt_state, batch_data)
        if i % log_every == 0 or i == steps - 1:
            loss = float(metrics["loss"])
            history.append({"step": i, "loss": loss,
                            "grad_norm": float(metrics["grad_norm"]),
                            "elapsed_s": round(time.time() - t0, 2)})
    return params, history
