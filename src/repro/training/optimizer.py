"""Pure-JAX AdamW with cosine schedule + global-norm clipping.

State is a pytree congruent with params (m, v per leaf) so the same
PartitionSpecs shard it (and a ZeRO-1 variant can reshard it along
``data`` — see distributed/sharding.py)."""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class OptState(NamedTuple):
    step: jnp.ndarray
    m: dict
    v: dict


def init_opt_state(params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(step=jnp.zeros((), jnp.int32), m=zeros,
                    v=jax.tree.map(jnp.copy, zeros))


def schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, params, grads, state: OptState):
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    step = state.step + 1
    lr = schedule(cfg, step.astype(jnp.float32))
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:                    # decay matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, OptState(step=step, m=new_m, v=new_v), gnorm
