"""Multi-process tokenize/detokenize pipeline.

sglang's hybrid TokenizerManager/DetokenizerManager is the exemplar:
string work — encoding prompts, incrementally decoding token streams,
formatting response JSON/SSE frames — is offloaded to worker
*processes* connected by lightweight queues, so the engine's token hot
path (``Instance.token_sink``) does nothing but a queue ``put``.  Each
in-flight request has **affinity** to one worker (``rid % n``), which
keeps its incremental detokenizer state local to that worker and its
frames in order; tokenize jobs are spread the same way by job id.

Wire format over the queues (plain tuples, cheap to pickle):

  main -> worker                       worker -> main
  ("tok", job, text)                   ("tok", job, ids, pid)
  ("open", rid, meta)
  ("tokens", rid, ids, t_event)        ("frames", rid, bytes, t_event, pid)
  ("fin", rid, reason, p_tok, c_tok, t)("done", rid, bytes, t_event, pid)
  ("close", rid)
  None (shutdown)

``meta``: (kind, req_id, model, created, stream) — everything
``repro.frontend.protocol`` needs to format either API flavor.  A
worker answers a non-streaming request with a single ("done", body)
after accumulating deltas; a streaming request gets incremental
("frames", sse-bytes) and a final ("done", last-chunk + [DONE]).

``n_workers=0`` degrades to an inline (in-process) pipeline with the
identical interface — the fast tests and single-process deployments
use it; worker pids then equal the main pid, which is exactly what the
process-isolation test asserts against.
"""
from __future__ import annotations

import itertools
import os
import queue
import threading
from concurrent.futures import Future
from typing import Callable, Dict, Optional

from repro.frontend import protocol
from repro.frontend.tokenizer import ByteTokenizer, IncrementalDetokenizer


class _StreamState:
    """Per-request detok + formatting state (lives on ONE worker)."""

    def __init__(self, meta):
        self.kind, self.req_id, self.model, self.created, self.stream = meta
        self.detok = IncrementalDetokenizer()
        self.text_parts = []          # non-stream accumulation
        self.completion_tokens = 0

    def feed(self, ids) -> str:
        self.completion_tokens += len(ids)
        return "".join(self.detok.feed(i) for i in ids)


def _handle(msg, streams: Dict[int, _StreamState], emit) -> bool:
    """Shared worker logic (mp worker loop AND inline mode).  ``emit``
    receives the outbox tuple; returns False on shutdown."""
    if msg is None:
        return False
    op = msg[0]
    if op == "tok":
        _, job, text = msg
        emit(("tok", job, ByteTokenizer.encode(text), os.getpid()))
    elif op == "open":
        _, rid, meta = msg
        streams[rid] = _StreamState(meta)
    elif op == "tokens":
        _, rid, ids, t_event = msg
        st = streams.get(rid)
        if st is None:
            return True
        text = st.feed(ids)
        if st.stream:
            if text:
                emit(("frames", rid, protocol.stream_chunk(
                    st.kind, st.req_id, st.model, st.created, text),
                    t_event, os.getpid()))
        else:
            st.text_parts.append(text)
    elif op == "fin":
        _, rid, reason, p_tok, t_event = msg
        st = streams.pop(rid, None)
        if st is None:
            return True
        tail = st.detok.flush()
        if st.stream:
            payload = b""
            if tail:
                payload += protocol.stream_chunk(
                    st.kind, st.req_id, st.model, st.created, tail)
            payload += protocol.stream_chunk(
                st.kind, st.req_id, st.model, st.created, "", reason)
            payload += protocol.SSE_DONE
        else:
            st.text_parts.append(tail)
            payload = protocol.final_response(
                st.kind, st.req_id, st.model, st.created,
                "".join(st.text_parts), reason, p_tok,
                st.completion_tokens)
        emit(("done", rid, payload, t_event, os.getpid()))
    elif op == "close":
        streams.pop(msg[1], None)
    return True


def _worker_main(inbox, outbox):
    """Worker process entry point: drain the inbox forever.  Imports in
    this module are string-only (protocol/tokenizer — no jax, no
    numpy), so spawn start-up stays cheap."""
    streams: Dict[int, _StreamState] = {}
    while True:
        if not _handle(inbox.get(), streams, outbox.put):
            break


class TokenPipeline:
    """Main-process façade: routes jobs to workers, routes results to
    per-request callbacks via a reader thread.

    Callbacks (called from the reader thread — register thread-safe
    consumers, e.g. ``asyncio.loop.call_soon_threadsafe``):
      on_frames(rid, payload: bytes, done: bool, t_event, worker_pid)
    """

    def __init__(self, n_workers: int = 2, start_method: str = "spawn"):
        self.n_workers = n_workers
        self._start_method = start_method
        self._job_ids = itertools.count()
        self._tok_futures: Dict[int, Future] = {}
        self._sinks: Dict[int, Callable] = {}
        self._lock = threading.Lock()
        self._procs = []
        self._inboxes = []
        self._outbox = None
        self._reader: Optional[threading.Thread] = None
        self._inline_streams: Dict[int, _StreamState] = {}
        self.started = False

    # ------------------------------------------------------------------
    def start(self):
        if self.started:
            return
        self.started = True
        if self.n_workers <= 0:
            return                      # inline mode: nothing to spawn
        import multiprocessing as mp
        ctx = mp.get_context(self._start_method)
        self._outbox = ctx.Queue()
        for _ in range(self.n_workers):
            inbox = ctx.Queue()
            p = ctx.Process(target=_worker_main,
                            args=(inbox, self._outbox), daemon=True)
            p.start()
            self._inboxes.append(inbox)
            self._procs.append(p)
        self._reader = threading.Thread(target=self._read_loop,
                                        name="detok-reader", daemon=True)
        self._reader.start()

    def stop(self):
        if not self.started:
            return
        self.started = False
        for inbox in self._inboxes:
            try:
                inbox.put(None)
            except (ValueError, OSError):
                pass
        for p in self._procs:
            p.join(timeout=2.0)
            if p.is_alive():
                p.terminate()
        if self._outbox is not None:
            self._outbox.put(None)      # unblock the reader
        if self._reader is not None:
            self._reader.join(timeout=2.0)
        self._procs, self._inboxes = [], []

    # ------------------------------------------------------------------
    def _send(self, idx: int, msg):
        if self.n_workers <= 0:
            _handle(msg, self._inline_streams, self._dispatch)
        else:
            self._inboxes[idx % self.n_workers].put(msg)

    def _read_loop(self):
        while True:
            msg = self._outbox.get()
            if msg is None:
                break
            self._dispatch(msg)

    def _dispatch(self, msg):
        op = msg[0]
        if op == "tok":
            _, job, ids, _pid = msg
            with self._lock:
                fut = self._tok_futures.pop(job, None)
            if fut is not None:
                fut.set_result(ids)
        elif op in ("frames", "done"):
            _, rid, payload, t_event, pid = msg
            done = op == "done"
            with self._lock:
                sink = self._sinks.get(rid)
                if done:
                    self._sinks.pop(rid, None)
            if sink is not None:
                sink(rid, payload, done, t_event, pid)

    # ------------------------------------------------------------------
    # tokenize side
    # ------------------------------------------------------------------
    def tokenize(self, text: str) -> Future:
        """Offload one prompt encoding; resolves to the token id
        list."""
        job = next(self._job_ids)
        fut: Future = Future()
        with self._lock:
            self._tok_futures[job] = fut
        self._send(job, ("tok", job, text))
        return fut

    # ------------------------------------------------------------------
    # detokenize side (per-request affinity: everything keys on rid)
    # ------------------------------------------------------------------
    def open_stream(self, rid: int, kind: str, req_id: str, model: str,
                    created: int, stream: bool, on_frames: Callable):
        with self._lock:
            self._sinks[rid] = on_frames
        self._send(rid, ("open", rid,
                         (kind, req_id, model, created, stream)))

    def push_tokens(self, rid: int, ids, t_event: float):
        """THE token hot path: one queue put, no string work."""
        self._send(rid, ("tokens", rid, ids, t_event))

    def finish(self, rid: int, reason: str, prompt_tokens: int,
               t_event: float):
        self._send(rid, ("fin", rid, reason, prompt_tokens, t_event))

    def close(self, rid: int):
        with self._lock:
            self._sinks.pop(rid, None)
        self._send(rid, ("close", rid))

    # ------------------------------------------------------------------
    def __enter__(self):
        self.start()
        return self

    def __exit__(self, *exc):
        self.stop()
