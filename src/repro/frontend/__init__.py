"""Network front-end: OpenAI-compatible HTTP/SSE serving on top of the
online serving runtime.

Three layers (each usable alone):

* ``repro.frontend.protocol`` — wire schema: OpenAI ``/v1/completions``
  and ``/v1/chat/completions`` request parsing, response/chunk
  formatting, and SSE framing.  Pure functions over plain values, so
  the detokenizer workers can format responses out-of-process.
* ``repro.frontend.pipeline`` — the multi-process token pipeline
  (TokenizerManager/DetokenizerManager): tokenization and incremental
  detokenization + response formatting run in worker processes with
  per-request affinity, so ``Instance.token_sink`` events never block
  on host-side string work.
* ``repro.frontend.http`` + ``repro.frontend.gateway`` — the asyncio
  HTTP/SSE server and the bridge that runs a ``ServingLoop`` on an
  engine thread behind it (ingress queue, admission, graceful drain,
  ``/healthz`` + ``/metrics``).

``repro.frontend.admission`` holds the router-side admission queue
(priority/fairness classes, bounded depth) that the serving loop uses
to absorb bursts instead of rejecting them.
"""
from repro.frontend.admission import (AdmissionConfig, AdmissionQueue,
                                      PRIORITY_CLASSES)
from repro.frontend.pipeline import TokenPipeline
from repro.frontend.tokenizer import ByteTokenizer, IncrementalDetokenizer


def __getattr__(name):
    # the gateway imports repro.serving (which itself imports this
    # package for the admission queue) — load it lazily to keep the
    # import graph acyclic
    if name in ("FrontendConfig", "FrontendServer"):
        from repro.frontend import gateway
        return getattr(gateway, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

__all__ = [
    "AdmissionConfig", "AdmissionQueue", "ByteTokenizer",
    "FrontendConfig", "FrontendServer", "IncrementalDetokenizer",
    "PRIORITY_CLASSES", "TokenPipeline",
]
