"""Minimal asyncio HTTP/1.1 server with SSE streaming.

Hand-rolled on ``asyncio.start_server`` — the repo carries no web
framework, and the server needs exactly four things a framework would
mostly get in the way of: request parsing, chunked SSE streaming,
connection-level backpressure (``await drain()``), and graceful drain
(stop accepting, let in-flight streams flush, then close).

The application side implements ``handle(method, path, headers, body)
-> Response``.  A ``Response`` either carries a complete ``body`` or a
``stream`` — an async iterator of byte frames written with chunked
transfer encoding (each SSE frame is one chunk, flushed immediately).
"""
from __future__ import annotations

import asyncio
import dataclasses
from typing import AsyncIterator, Callable, Dict, Optional, Tuple

#: request line + headers size cap (sanity, not security)
MAX_HEADER_BYTES = 32768
MAX_BODY_BYTES = 8 * 1024 * 1024

REASONS = {200: "OK", 400: "Bad Request", 404: "Not Found",
           405: "Method Not Allowed", 413: "Payload Too Large",
           500: "Internal Server Error", 503: "Service Unavailable"}


@dataclasses.dataclass
class Response:
    status: int = 200
    content_type: str = "application/json"
    body: Optional[bytes] = None
    stream: Optional[AsyncIterator[bytes]] = None
    #: extra response headers (e.g. Retry-After on overload 503s)
    headers: Optional[Dict[str, str]] = None
    #: called when the client goes away mid-stream (cleanup hook)
    on_disconnect: Optional[Callable[[], None]] = None


class HttpError(Exception):
    def __init__(self, status: int, message: str):
        super().__init__(message)
        self.status = status


async def _read_request(reader: asyncio.StreamReader
                        ) -> Optional[Tuple[str, str, Dict[str, str], bytes]]:
    """Parse one request; None on clean EOF before a request line."""
    try:
        head = await reader.readuntil(b"\r\n\r\n")
    except asyncio.IncompleteReadError as e:
        if not e.partial:
            return None
        raise HttpError(400, "truncated request head")
    except asyncio.LimitOverrunError:
        raise HttpError(413, "request head too large")
    if len(head) > MAX_HEADER_BYTES:
        raise HttpError(413, "request head too large")
    lines = head.decode("latin-1").split("\r\n")
    parts = lines[0].split(" ")
    if len(parts) != 3:
        raise HttpError(400, f"malformed request line: {lines[0]!r}")
    method, path, _version = parts
    headers: Dict[str, str] = {}
    for ln in lines[1:]:
        if not ln:
            continue
        k, _, v = ln.partition(":")
        headers[k.strip().lower()] = v.strip()
    n = int(headers.get("content-length", "0") or "0")
    if n > MAX_BODY_BYTES:
        raise HttpError(413, "request body too large")
    body = await reader.readexactly(n) if n else b""
    return method, path, headers, body


def _head(status: int, content_type: str, extra: str = "",
          length: Optional[int] = None) -> bytes:
    reason = REASONS.get(status, "")
    h = (f"HTTP/1.1 {status} {reason}\r\n"
         f"Content-Type: {content_type}\r\n")
    if length is not None:
        h += f"Content-Length: {length}\r\n"
    return (h + extra + "\r\n").encode("latin-1")


class HttpServer:
    def __init__(self, handler, host: str = "127.0.0.1", port: int = 0):
        """``handler``: async callable (method, path, headers, body) ->
        Response.  ``port=0`` binds an ephemeral port (tests)."""
        self.handler = handler
        self.host = host
        self.port = port
        self._server: Optional[asyncio.AbstractServer] = None
        self._conns: set = set()
        self.refusing = False          # graceful drain: 503 new requests

    # ------------------------------------------------------------------
    async def start(self):
        self._server = await asyncio.start_server(
            self._client, self.host, self.port,
            limit=MAX_HEADER_BYTES)
        self.port = self._server.sockets[0].getsockname()[1]
        return self

    async def stop(self, flush_timeout: float = 30.0):
        """Graceful: stop accepting, wait for in-flight connections to
        flush (SSE streams run to completion), then close stragglers."""
        self.refusing = True
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
        deadline = asyncio.get_event_loop().time() + flush_timeout
        while self._conns and asyncio.get_event_loop().time() < deadline:
            await asyncio.sleep(0.02)
        for task in list(self._conns):
            task.cancel()

    # ------------------------------------------------------------------
    async def _client(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter):
        task = asyncio.current_task()
        self._conns.add(task)
        try:
            while True:                # keep-alive request loop
                try:
                    parsed = await _read_request(reader)
                except HttpError as e:
                    await self._plain(writer, e.status, str(e))
                    break
                if parsed is None:
                    break
                method, path, headers, body = parsed
                if self.refusing:
                    await self._plain(writer, 503, "server is draining")
                    break
                try:
                    resp = await self.handler(method, path, headers, body)
                except HttpError as e:
                    resp = Response(e.status, body=(
                        b'{"error": {"message": "%s"}}'
                        % str(e).encode()))
                if resp.stream is not None:
                    await self._stream(writer, resp)
                    break              # one stream per connection
                await self._respond(writer, resp)
                if headers.get("connection", "").lower() == "close":
                    break
        except (ConnectionResetError, BrokenPipeError,
                asyncio.CancelledError):
            pass
        finally:
            self._conns.discard(task)
            writer.close()
            try:
                await writer.wait_closed()
            except (ConnectionResetError, BrokenPipeError):
                pass

    async def _plain(self, writer, status: int, message: str):
        body = (b'{"error": {"message": "%s"}}'
                % message.encode("utf-8", "replace"))
        writer.write(_head(status, "application/json",
                           "Connection: close\r\n", len(body)) + body)
        await writer.drain()

    async def _respond(self, writer, resp: Response):
        body = resp.body or b""
        extra = "".join(f"{k}: {v}\r\n"
                        for k, v in (resp.headers or {}).items())
        writer.write(_head(resp.status, resp.content_type,
                           extra=extra, length=len(body)) + body)
        await writer.drain()

    async def _stream(self, writer, resp: Response):
        """Chunked SSE: one chunk per frame, drained per write so the
        client sees tokens the moment the engine emits them and a slow
        client applies backpressure instead of ballooning buffers."""
        writer.write(_head(resp.status, "text/event-stream",
                           "Cache-Control: no-cache\r\n"
                           "Connection: close\r\n"
                           "Transfer-Encoding: chunked\r\n"))
        await writer.drain()
        try:
            async for frame in resp.stream:
                writer.write(b"%x\r\n" % len(frame) + frame + b"\r\n")
                await writer.drain()
            writer.write(b"0\r\n\r\n")
            await writer.drain()
        except (ConnectionResetError, BrokenPipeError):
            if resp.on_disconnect is not None:
                resp.on_disconnect()
            raise
