"""Router-side admission queue: priority/fairness classes, bounded
depth.

Today's router either places an arrival immediately or (with early
rejection) drops it on the floor — a burst at 2x the sustainable rate
turns into a rejection storm even though the cluster could absorb it
over the next few seconds.  The admission queue sits between
``ServingLoop.submit`` and ``Cluster.submit``:

* arrivals enqueue under a **priority class** (``interactive`` >
  ``standard`` > ``batch`` by default, configurable);
* the loop **releases** requests to the cluster only while the
  in-flight population is below ``max_inflight`` — bursts queue here,
  bounded by ``max_depth``, instead of flooding instance queues;
* dequeue order is strict priority between classes of different
  priority and **weighted stride fairness** between classes of equal
  priority (FIFO within a class), so one chatty tenant class cannot
  starve its peers;
* when the queue is full, the *lowest-priority newest* entry is
  displaced in favor of a higher-priority arrival (the displaced
  request is rejected); an arrival that is itself lowest priority is
  rejected outright;
* ``shed`` drops from the back of the lowest classes — the
  controller's admission actuator when both TTFT and TPOT are starved
  (sliders cannot conjure capacity; shedding the cheapest queued work
  can).

Queue wait (release time - arrival) is a first-class telemetry span:
the loop reports it to ``TelemetryWindow.on_queue_wait`` and exports
depth gauges per class.
"""
from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Dict, List, Optional, Tuple

#: name -> (priority rank, fairness weight); lower rank wins, weight
#: splits service among classes of equal rank
PRIORITY_CLASSES: Dict[str, Tuple[int, float]] = {
    "interactive": (0, 1.0),
    "standard": (1, 3.0),
    "batch": (2, 1.0),
}


@dataclasses.dataclass
class AdmissionConfig:
    max_depth: int = 256          # queued entries across all classes
    max_inflight: int = 64        # released-but-unfinished cap
    classes: Dict[str, Tuple[int, float]] = dataclasses.field(
        default_factory=lambda: dict(PRIORITY_CLASSES))
    default_class: str = "standard"
    shed_fraction: float = 0.5    # controller actuator: share shed/epoch
    # per-class token-rate limits: class -> tokens (prompt +
    # max_new_tokens, charged at release) per ``budget_window`` seconds.
    # Classes absent from the map are unlimited; None disables the
    # mechanism entirely (bit-identical to the pre-budget queue).
    token_budgets: Optional[Dict[str, float]] = None
    budget_window: float = 1.0


@dataclasses.dataclass
class Entry:
    req: object                   # repro.engine.request.Request
    cls: str
    enq_time: float


class AdmissionQueue:
    def __init__(self, cfg: Optional[AdmissionConfig] = None):
        self.cfg = cfg or AdmissionConfig()
        if self.cfg.default_class not in self.cfg.classes:
            raise ValueError(
                f"default class {self.cfg.default_class!r} not in classes")
        self._q: Dict[str, deque] = {c: deque() for c in self.cfg.classes}
        # stride scheduling between equal-priority classes: each dequeue
        # advances the class's pass by 1/weight; the smallest pass among
        # the non-empty top-priority classes serves next
        self._pass: Dict[str, float] = {c: 0.0 for c in self.cfg.classes}
        self.enqueued = 0
        self.released = 0
        self.released_by_class: Dict[str, int] = \
            {c: 0 for c in self.cfg.classes}
        self.displaced = 0
        self.shed_count = 0
        # token-rate limiting: tumbling window of tokens charged per
        # class (charged at release — the moment load hits the cluster)
        self._budget_window_start = 0.0
        self._window_tokens: Dict[str, float] = \
            {c: 0.0 for c in self.cfg.classes}
        self.budget_deferrals = 0     # pops refused by budget gating
        # drain-rate estimate for Retry-After: recent release timestamps
        self._release_times: deque = deque(maxlen=32)

    # ------------------------------------------------------------------
    def resolve_class(self, name: Optional[str]) -> str:
        return name if name in self._q else self.cfg.default_class

    def __len__(self) -> int:
        return sum(len(d) for d in self._q.values())

    def depth_by_class(self) -> Dict[str, int]:
        return {c: len(d) for c, d in self._q.items()}

    def oldest_wait(self, now: float) -> float:
        heads = [d[0].enq_time for d in self._q.values() if d]
        return (now - min(heads)) if heads else 0.0

    def _rank(self, cls: str) -> int:
        return self.cfg.classes[cls][0]

    # ------------------------------------------------------------------
    def push(self, req, cls: str, now: float) -> Tuple[bool, List[Entry]]:
        """Enqueue under ``cls``.  Returns ``(accepted, displaced)``:
        at bounded depth a strictly lower-priority queued entry is
        displaced (newest first) to make room; an arrival no better
        than everything queued is refused."""
        cls = self.resolve_class(cls)
        displaced: List[Entry] = []
        if len(self) >= self.cfg.max_depth:
            victim_cls = self._displacement_victim(self._rank(cls))
            if victim_cls is None:
                return False, displaced
            displaced.append(self._q[victim_cls].pop())   # newest waited
            self.displaced += 1                           # least: drop it
        self._q[cls].append(Entry(req, cls, now))
        self.enqueued += 1
        return True, displaced

    def _displacement_victim(self, incoming_rank: int) -> Optional[str]:
        worst = None
        for c, d in self._q.items():
            if d and self._rank(c) > incoming_rank:
                if worst is None or self._rank(c) > self._rank(worst):
                    worst = c
        return worst

    # ------------------------------------------------------------------
    def _roll_budget_window(self, now: float):
        if now - self._budget_window_start >= self.cfg.budget_window:
            self._budget_window_start = now
            for c in self._window_tokens:
                self._window_tokens[c] = 0.0

    def _under_budget(self, cls: str) -> bool:
        budgets = self.cfg.token_budgets
        if budgets is None or cls not in budgets:
            return True
        return self._window_tokens[cls] < budgets[cls]

    def pop(self, now: Optional[float] = None) -> Optional[Entry]:
        """Strict priority between ranks; weighted stride fairness
        within a rank; FIFO within a class.  With ``token_budgets``
        configured, classes over their window budget are skipped; None
        with a non-empty queue means nothing is releasable this tick
        (callers must stop draining, not spin)."""
        live = [c for c, d in self._q.items() if d]
        if not live:
            return None
        if self.cfg.token_budgets is not None and now is not None:
            self._roll_budget_window(now)
            eligible = [c for c in live if self._under_budget(c)]
            if not eligible:
                self.budget_deferrals += 1
                return None
            live = eligible
        top = min(self._rank(c) for c in live)
        cands = [c for c in live if self._rank(c) == top]
        cls = min(cands, key=lambda c: (self._pass[c], c))
        self._pass[cls] += 1.0 / self.cfg.classes[cls][1]
        # keep an idle class from banking unbounded credit: floor every
        # pass at the serving class's new pass minus one full quantum
        floor = self._pass[cls] - 1.0
        for c in self._q:
            if self._pass[c] < floor:
                self._pass[c] = floor
        self.released += 1
        self.released_by_class[cls] += 1
        entry = self._q[cls].popleft()
        # charge the request's worst-case token footprint against the
        # class window (output length is unknown a priori, so the cap
        # is the honest ceiling)
        self._window_tokens[cls] += (
            getattr(entry.req, "prompt_len", 0)
            + getattr(entry.req, "max_new_tokens", 0))
        if now is not None:
            self._release_times.append(now)
        return entry

    # ------------------------------------------------------------------
    def shed(self, fraction: Optional[float] = None,
             max_rank_protect: int = 0) -> List[Entry]:
        """Admission control as an actuator: drop ``fraction`` of the
        queue from the back of the lowest-priority classes upward,
        never touching classes ranked <= ``max_rank_protect``.
        Newest-first within a class — they have waited least and their
        TTFT clocks have the most headroom left to re-submit."""
        n = int(len(self) * (self.cfg.shed_fraction
                             if fraction is None else fraction))
        out: List[Entry] = []
        for c in sorted(self._q, key=self._rank, reverse=True):
            if self._rank(c) <= max_rank_protect:
                break
            d = self._q[c]
            while d and len(out) < n:
                out.append(d.pop())
            if len(out) >= n:
                break
        self.shed_count += len(out)
        return out

    def remove(self, rid: int) -> Optional[Entry]:
        """Pull one queued entry out by request id (client abandoned it
        before release).  Returns the entry, or None if ``rid`` is not
        queued here (already released, or never admitted)."""
        for d in self._q.values():
            for e in d:
                if e.req.rid == rid:
                    d.remove(e)
                    return e
        return None

    def retry_after_hint(self, now: Optional[float] = None) -> int:
        """Whole seconds a refused client should wait before retrying.
        With enough release history the hint is backlog / observed
        drain rate (how long the current queue actually takes to empty
        at the measured pace); otherwise it falls back to release-cycle
        counting (depth / max_inflight).  Clamped to [1, 60]."""
        if len(self._release_times) >= 2:
            span = self._release_times[-1] - self._release_times[0]
            if span > 0.0:
                rate = (len(self._release_times) - 1) / span
                return int(max(1, min(60, math.ceil(len(self) / rate))))
        cycles = len(self) / max(1, self.cfg.max_inflight)
        return int(max(1, min(60, 1 + cycles)))

    def drain(self) -> List[Entry]:
        """Empty the queue (graceful shutdown: these resolve
        cancelled)."""
        out = [e for c in sorted(self._q, key=self._rank)
               for e in self._q[c]]
        for d in self._q.values():
            d.clear()
        return out

    # ------------------------------------------------------------------
    def gauges(self, now: float) -> dict:
        out = {
            "depth": len(self),
            "depth_by_class": self.depth_by_class(),
            "oldest_wait_s": round(self.oldest_wait(now), 4),
            "enqueued_total": self.enqueued,
            "released_total": self.released,
            "released_by_class": dict(self.released_by_class),
            "displaced_total": self.displaced,
            "shed_total": self.shed_count,
        }
        if self.cfg.token_budgets is not None:
            out["budget_deferrals_total"] = self.budget_deferrals
            out["window_tokens_by_class"] = {
                c: self._window_tokens[c] for c in self.cfg.token_budgets}
        return out
