"""OpenAI-compatible wire schema.

Request parsing for ``/v1/completions`` (string prompt) and
``/v1/chat/completions`` (message list), plus the response and SSE
chunk builders.  Everything here is a pure function over plain values
— no engine types, no numpy — because response formatting runs inside
the detokenizer worker *processes* (``repro.frontend.pipeline``) and
the objects must cross a ``multiprocessing`` queue cheaply.

Greedy-only engine: ``temperature``/``top_p`` are accepted and ignored
(the toy models sample greedily on-device), ``n`` must be 1.  The
priority class for the router-side admission queue rides either in the
body (``"priority": "interactive"``) or the ``x-priority`` header.
"""
from __future__ import annotations

import dataclasses
import json
from typing import List, Optional

#: admission classes, highest priority first (see frontend.admission)
DEFAULT_PRIORITY = "standard"

COMPLETIONS = "/v1/completions"
CHAT_COMPLETIONS = "/v1/chat/completions"


class ProtocolError(Exception):
    """Maps to an HTTP error response in OpenAI's error envelope."""

    def __init__(self, status: int, message: str,
                 err_type: str = "invalid_request_error"):
        super().__init__(message)
        self.status = status
        self.err_type = err_type

    def body(self) -> bytes:
        return json.dumps({"error": {
            "message": str(self), "type": self.err_type,
            "param": None, "code": None}}).encode()


@dataclasses.dataclass
class ApiRequest:
    """One parsed API call, engine-agnostic."""
    kind: str                       # "completion" | "chat"
    model: str
    prompt_text: str                # chat messages flattened to one text
    max_tokens: int
    stream: bool
    priority: str
    echo: bool = False


def _flatten_chat(messages) -> str:
    """Deterministic chat template: ``role: content`` lines plus the
    assistant cue.  A real deployment would use the model's template —
    the toy tokenizer only needs a stable, injective flattening."""
    if not isinstance(messages, list) or not messages:
        raise ProtocolError(400, "'messages' must be a non-empty list")
    parts = []
    for m in messages:
        if not isinstance(m, dict) or "role" not in m or "content" not in m:
            raise ProtocolError(
                400, "each message needs 'role' and 'content'")
        parts.append(f"{m['role']}: {m['content']}")
    parts.append("assistant:")
    return "\n".join(parts)


def parse_request(path: str, body: bytes,
                  headers: Optional[dict] = None) -> ApiRequest:
    """Parse one POST body into an ``ApiRequest`` (raises
    ``ProtocolError`` on anything malformed)."""
    try:
        obj = json.loads(body or b"{}")
    except (json.JSONDecodeError, UnicodeDecodeError) as e:
        raise ProtocolError(400, f"request body is not valid JSON: {e}")
    if not isinstance(obj, dict):
        raise ProtocolError(400, "request body must be a JSON object")
    if obj.get("n", 1) != 1:
        raise ProtocolError(400, "only n=1 is supported")
    max_tokens = obj.get("max_tokens", 16)
    if not isinstance(max_tokens, int) or max_tokens < 1:
        raise ProtocolError(400, "'max_tokens' must be a positive integer")
    stream = bool(obj.get("stream", False))
    priority = obj.get("priority") or (headers or {}).get(
        "x-priority", DEFAULT_PRIORITY)
    if path == COMPLETIONS:
        prompt = obj.get("prompt")
        if isinstance(prompt, list):      # OpenAI allows a 1-element list
            if len(prompt) != 1 or not isinstance(prompt[0], str):
                raise ProtocolError(
                    400, "'prompt' must be a string (or [string])")
            prompt = prompt[0]
        if not isinstance(prompt, str) or not prompt:
            raise ProtocolError(400, "'prompt' must be a non-empty string")
        return ApiRequest("completion", obj.get("model", ""),
                          prompt, max_tokens, stream, str(priority),
                          echo=bool(obj.get("echo", False)))
    if path == CHAT_COMPLETIONS:
        return ApiRequest("chat", obj.get("model", ""),
                          _flatten_chat(obj.get("messages")),
                          max_tokens, stream, str(priority))
    raise ProtocolError(404, f"unknown endpoint {path}")


# ---------------------------------------------------------------------------
# response / chunk builders (run in the detokenizer workers)
# ---------------------------------------------------------------------------

def sse_event(payload: dict) -> bytes:
    """One SSE frame: ``data: <json>\\n\\n``."""
    return b"data: " + json.dumps(payload, separators=(",", ":")).encode() \
        + b"\n\n"


SSE_DONE = b"data: [DONE]\n\n"


def _ident(kind: str, req_id: str, model: str, created: int) -> dict:
    return {"id": req_id,
            "object": ("chat.completion.chunk" if kind == "chat"
                       else "text_completion"),
            "created": created, "model": model}


def stream_chunk(kind: str, req_id: str, model: str, created: int,
                 text: str, finish_reason: Optional[str] = None) -> bytes:
    """One streamed delta as an SSE frame (both API flavors)."""
    if kind == "chat":
        delta = {"content": text} if text else {}
        choice = {"index": 0, "delta": delta,
                  "finish_reason": finish_reason}
    else:
        choice = {"index": 0, "text": text, "logprobs": None,
                  "finish_reason": finish_reason}
    return sse_event({**_ident(kind, req_id, model, created),
                      "choices": [choice]})


def final_response(kind: str, req_id: str, model: str, created: int,
                   text: str, finish_reason: str,
                   prompt_tokens: int, completion_tokens: int) -> bytes:
    """The single non-streaming response body."""
    if kind == "chat":
        choice = {"index": 0,
                  "message": {"role": "assistant", "content": text},
                  "finish_reason": finish_reason}
        obj = "chat.completion"
    else:
        choice = {"index": 0, "text": text, "logprobs": None,
                  "finish_reason": finish_reason}
        obj = "text_completion"
    return json.dumps({
        **_ident(kind, req_id, model, created), "object": obj,
        "choices": [choice],
        "usage": {"prompt_tokens": prompt_tokens,
                  "completion_tokens": completion_tokens,
                  "total_tokens": prompt_tokens + completion_tokens},
    }).encode()
