"""Deterministic byte-level tokenizer + incremental detokenizer.

The repro models are randomly initialized, so no pretrained vocabulary
exists to load (and the container must not download one).  The wire
still needs a *real* text<->token boundary with the hard parts of
production detokenization, so we use a byte tokenizer:

* ``encode``: UTF-8 bytes, one token id per byte (ids 0..255) — always
  within every registered config's vocab.
* ``IncrementalDetokenizer``: streaming decode with the classic
  incremental-detok hazard handled — a multi-byte UTF-8 sequence split
  across decode steps is *held* until its continuation bytes arrive,
  so no replacement characters leak mid-stream.  Token ids >= 256
  (the model decodes over its full vocab) render as a deterministic
  ``⟨id⟩`` marker, flushing any pending partial sequence first.

Both directions are pure Python over small state, safe to run inside
``multiprocessing`` workers (no jax, no numpy).
"""
from __future__ import annotations

import codecs
from typing import List

#: ids below this are raw UTF-8 bytes; at/above render as markers
BYTE_VOCAB = 256


class ByteTokenizer:
    """Stateless encode side (the TokenizerManager's unit of work)."""

    vocab_size = BYTE_VOCAB

    @staticmethod
    def encode(text: str) -> List[int]:
        return list(text.encode("utf-8"))

    @staticmethod
    def decode(ids: List[int]) -> str:
        """Batch decode (oracle for tests): identical output to feeding
        an ``IncrementalDetokenizer`` one id at a time."""
        det = IncrementalDetokenizer()
        return "".join(det.feed(i) for i in ids) + det.flush()


class IncrementalDetokenizer:
    """Per-request streaming decoder (the DetokenizerManager's state)."""

    def __init__(self):
        self._dec = codecs.getincrementaldecoder("utf-8")(errors="replace")

    def feed(self, token_id: int) -> str:
        """Text newly completed by this token (may be '' while a
        multi-byte sequence is pending)."""
        if 0 <= token_id < BYTE_VOCAB:
            return self._dec.decode(bytes([token_id]))
        # out-of-byte-range id: close any dangling partial sequence
        # (renders as U+FFFD — the bytes can no longer complete), then
        # emit the deterministic marker
        return self._dec.decode(b"", True) + f"⟨{token_id}⟩"

    def flush(self) -> str:
        """End of stream: force out any incomplete trailing sequence."""
        return self._dec.decode(b"", True)
