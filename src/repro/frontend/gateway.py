"""Front-end gateway: HTTP server <-> token pipeline <-> serving loop.

Thread architecture (three worlds, queue boundaries between all):

  asyncio thread          engine thread              detok workers
  --------------          -------------              -------------
  HTTP accept/parse  -->  loop.serve(stop):          per-rid detok +
  tokenize (worker)       ingress drain, admission   response/SSE
  SubmitMsg -> ingress    queue, event core          formatting
  per-req asyncio.Queue   on_token: ONE queue put -> ("frames"/"done")
  <- call_soon_threadsafe <------ reader thread ------------+
  chunked SSE writes

The engine's token hot path (``RequestHandle`` on_token) does exactly
one ``Queue.put`` — every string operation (incremental UTF-8 decode,
JSON formatting, SSE framing) happens in the detokenizer worker
processes.  Each SSE frame carries the ``time.monotonic()`` stamp of
the engine event that produced it; the asyncio writer reports the
engine->socket span to ``TelemetryWindow.record_wire``.

Graceful shutdown (SIGINT/SIGTERM or ``shutdown()``): stop accepting
HTTP, signal the engine's drain (queued admission entries resolve
CANCELLED, in-flight requests run to completion and their SSE streams
flush), then close the HTTP server and the pipeline.
"""
from __future__ import annotations

import asyncio
import dataclasses
import json
import signal
import threading
import time
from typing import Optional, Set

from repro.frontend import protocol
from repro.frontend.http import HttpServer, Response
from repro.frontend.pipeline import TokenPipeline
from repro.serving.server import AbortMsg, ServingLoop, SubmitMsg

from repro.engine.request import Request, State


@dataclasses.dataclass
class FrontendConfig:
    host: str = "127.0.0.1"
    port: int = 8000                  # 0 = ephemeral (tests)
    model: str = "repro"
    tok_workers: int = 2              # 0 = inline pipeline (one process)
    max_tokens_cap: int = 512         # server-side clamp on max_tokens
    drain_timeout: float = 30.0       # flush window for graceful stop


class _ReqCtx:
    """Per-request bridge state living on the asyncio thread."""

    def __init__(self, rid: int, req_id: str, stream: bool):
        self.rid = rid
        self.req_id = req_id
        self.stream = stream
        self.frames: asyncio.Queue = asyncio.Queue()
        self.done_fired = False       # once-only guard for the done path


class FrontendServer:
    """Deployable server in front of a ``ServingLoop``.

    The loop is built by the caller (any executor: sim or JAX; for a
    real deployment use ``WallClock`` + ``pace=True`` and an
    ``AdmissionConfig``).  ``run()`` blocks until ``shutdown()`` or a
    signal; tests run it on a background thread and wait on
    ``started``."""

    def __init__(self, loop: ServingLoop,
                 cfg: Optional[FrontendConfig] = None):
        self.loop = loop
        self.cfg = cfg or FrontendConfig()
        self.pipeline = TokenPipeline(n_workers=self.cfg.tok_workers)
        self.http: Optional[HttpServer] = None
        self.port: Optional[int] = None
        self.started = threading.Event()
        self.seen_worker_pids: Set[int] = set()
        self._stop_engine = threading.Event()
        self._engine_thread: Optional[threading.Thread] = None
        self._aio: Optional[asyncio.AbstractEventLoop] = None
        self._shutdown_ev: Optional[asyncio.Event] = None
        self._ctxs = {}               # rid -> _ReqCtx (asyncio thread)

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------
    def run(self, install_signals: bool = False):
        """Blocking entry point: starts the pipeline, the engine thread
        and the HTTP server, runs until shutdown."""
        asyncio.run(self._main(install_signals))

    def shutdown(self):
        """Thread-safe graceful-stop trigger."""
        if self._aio is not None and self._shutdown_ev is not None:
            self._aio.call_soon_threadsafe(self._shutdown_ev.set)

    async def _main(self, install_signals: bool):
        self._aio = asyncio.get_running_loop()
        self._shutdown_ev = asyncio.Event()
        if install_signals:
            for sig in (signal.SIGINT, signal.SIGTERM):
                self._aio.add_signal_handler(sig, self._shutdown_ev.set)
        self.pipeline.start()
        self._engine_thread = threading.Thread(
            target=self.loop.serve, args=(self._stop_engine,),
            name="engine", daemon=True)
        self._engine_thread.start()
        self.http = HttpServer(self._handle, self.cfg.host, self.cfg.port)
        await self.http.start()
        self.port = self.http.port
        self.started.set()
        try:
            await self._shutdown_ev.wait()
        finally:
            # drain order matters: refuse new HTTP work, let the engine
            # finish its in-flight population (frames keep flowing into
            # open SSE streams while we wait), THEN flush connections
            self.http.refusing = True
            self._stop_engine.set()
            await self._aio.run_in_executor(
                None, self._engine_thread.join, self.cfg.drain_timeout)
            await self.http.stop(self.cfg.drain_timeout)
            self.pipeline.stop()
            self.started.clear()

    # ------------------------------------------------------------------
    # HTTP routing
    # ------------------------------------------------------------------
    async def _handle(self, method: str, path: str, headers: dict,
                      body: bytes) -> Response:
        if method == "GET" and path == "/healthz":
            alive = (self._engine_thread is not None
                     and self._engine_thread.is_alive())
            insts = [{"iid": i.iid, "itype": i.itype,
                      "health": getattr(i, "health", "ok"),
                      "draining": i.draining}
                     for i in self.loop.cluster.instances]
            healthy = alive and any(i["health"] == "ok" for i in insts)
            status = ("ok" if healthy else
                      "engine down" if not alive else
                      "no healthy instances")
            return Response(200 if healthy else 503, body=json.dumps(
                {"status": status, "instances": insts}).encode())
        if method == "GET" and (path == "/metrics"
                                or path.startswith("/metrics?")):
            return self._metrics(headers, path)
        if path in (protocol.COMPLETIONS, protocol.CHAT_COMPLETIONS):
            if method != "POST":
                return Response(405, body=protocol.ProtocolError(
                    405, f"{method} not allowed").body())
            try:
                api = protocol.parse_request(path, body, headers)
            except protocol.ProtocolError as e:
                return Response(e.status, body=e.body())
            return await self._completion(api)
        return Response(404, body=protocol.ProtocolError(
            404, f"no route for {method} {path}").body())

    def _metrics(self, headers: dict, path: str) -> Response:
        """Serialize the loop's telemetry snapshot.  The window's lock
        makes the snapshot internally consistent against the engine
        thread's event ingestion (the former retry-on-RuntimeError loop
        is gone with it).  Content negotiation: JSON by default;
        Prometheus exposition text when the client asks for text/plain
        or OpenMetrics (or forces it with ``?format=prometheus``)."""
        snap = self.loop.snapshot(self.loop.receipt_now())
        accept = headers.get("accept", "")
        if ("text/plain" in accept or "openmetrics" in accept
                or "format=prometheus" in path):
            from repro.serving.tracing import prometheus_text
            return Response(
                200, content_type="text/plain; version=0.0.4",
                body=prometheus_text(snap).encode())
        return Response(200, body=json.dumps(snap, default=str).encode())

    # ------------------------------------------------------------------
    # completion lifecycle
    # ------------------------------------------------------------------
    async def _completion(self, api: protocol.ApiRequest) -> Response:
        receipt = self.loop.receipt_now()     # connection-receipt truth
        ids = await asyncio.wrap_future(
            self.pipeline.tokenize(api.prompt_text))
        req = Request(prompt_len=len(ids),
                      max_new_tokens=min(api.max_tokens,
                                         self.cfg.max_tokens_cap),
                      arrival=receipt, prompt_tokens=list(ids))
        rid = req.rid
        prefix = "chatcmpl" if api.kind == "chat" else "cmpl"
        ctx = _ReqCtx(rid, f"{prefix}-{rid}", api.stream)
        self._ctxs[rid] = ctx
        self.pipeline.open_stream(
            rid, api.kind, ctx.req_id, api.model or self.cfg.model,
            int(time.time()),
            api.stream, self._on_frames)
        aio = self._aio

        def on_token(r, t, tok):
            # ENGINE THREAD hot path: one queue put, zero string work
            if tok is not None:
                self.pipeline.push_tokens(rid, [tok], time.monotonic())

        def reply(handle):
            # engine thread, after submit: resolution (immediate
            # rejection included) happens on this same thread, so
            # setting on_done here is race-free; if the request already
            # resolved during submit, fire the path ourselves
            handle.on_done = on_done
            if handle.done:
                on_done(handle.req)

        def on_done(r):
            if r.state == State.FINISHED:
                # EOS before the token budget ran out reports "stop";
                # hitting max_tokens reports "length"
                self.pipeline.finish(rid, r.finish_reason or "length",
                                     len(ids), time.monotonic())
            else:                     # rejected / cancelled: bypass the
                aio.call_soon_threadsafe(     # worker, report status
                    ctx.frames.put_nowait, ("status", r.state.value))

        self.loop.ingress.put(SubmitMsg(
            req=req, priority=api.priority, receipt=receipt,
            on_token=on_token, reply=reply))

        # first item decides the response shape: a request rejected
        # before any output must answer 503, not an empty 200 stream
        first = await ctx.frames.get()
        if first[0] == "status":
            self._close_ctx(rid)
            status = first[1]
            headers = None
            if status == "rejected":
                # overload refusal: tell the client when the current
                # admission backlog should have cleared
                q = self.loop.admission
                headers = {"Retry-After": str(
                    q.retry_after_hint() if q is not None else 1)}
            return Response(503, headers=headers,
                            body=protocol.ProtocolError(
                503, f"request {status} by the server"
                     + (" (overloaded)" if status == "rejected" else ""),
                err_type="server_error").body())
        if not api.stream:
            # non-streaming: the worker sent one ("frames", body, done)
            payload, done, t_event, _pid = first[1:]
            self.loop.telemetry.record_wire(time.monotonic() - t_event)
            self._close_ctx(rid)
            return Response(200, body=payload)
        return Response(200, stream=self._sse(ctx, first),
                        on_disconnect=lambda: self._close_ctx(rid))

    async def _sse(self, ctx: _ReqCtx, first):
        item = first
        try:
            while True:
                if item[0] == "status":
                    # cancelled/rejected mid-stream: close the stream
                    # honestly with a finish_reason instead of hanging
                    yield protocol.sse_event(
                        {"id": ctx.req_id,
                         "object": "error",
                         "error": {"message":
                                   f"request {item[1]} by the server"}})
                    yield protocol.SSE_DONE
                    return
                payload, done, t_event, _pid = item[1:]
                self.loop.telemetry.record_wire(
                    time.monotonic() - t_event)
                yield payload
                if done:
                    return
                item = await ctx.frames.get()
        finally:
            self._close_ctx(ctx.rid)

    # ------------------------------------------------------------------
    def _on_frames(self, rid: int, payload: bytes, done: bool,
                   t_event: float, pid: int):
        """Pipeline reader thread -> the request's asyncio queue."""
        self.seen_worker_pids.add(pid)
        ctx = self._ctxs.get(rid)
        if ctx is None or self._aio is None:
            return
        try:
            self._aio.call_soon_threadsafe(
                ctx.frames.put_nowait, ("frames", payload, done, t_event,
                                        pid))
        except RuntimeError:
            pass                      # event loop already closed

    def _close_ctx(self, rid: int):
        self._ctxs.pop(rid, None)
        self.pipeline.close(rid)
        # client gone before the request resolved (SSE disconnect, or a
        # dropped non-streaming connection): propagate the abort so the
        # engine stops generating into a dead socket and frees the
        # request's KV blocks.  A no-op for normally-completed requests.
        handle = self.loop._handles.get(rid)
        if handle is not None and not handle.done:
            self.loop.ingress.put(AbortMsg(rid))
