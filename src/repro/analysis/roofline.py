"""Roofline analysis from compiled dry-run artifacts.

Three terms, all in seconds, per device (cost_analysis on an SPMD module
reports per-device FLOPs/bytes; collective bytes are summed from the
compiled HLO text and are likewise per-device):

  compute    = HLO_FLOPs / peak_FLOP/s               (197 TF bf16, v5e)
  memory     = HLO_bytes / HBM_bw                    (819 GB/s)
  collective = sum(operand bytes of all-gather|all-reduce|reduce-scatter|
                   all-to-all|collective-permute) / (links x link_bw)
                                                     (4 x 50 GB/s ICI)

Also reports MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE) per device
and the usefulness ratio MODEL_FLOPS / HLO_FLOPs (catches remat and
redundant compute).
"""
from __future__ import annotations

import re
from typing import Dict, Optional

from repro.core.hw import V5E

_COLL_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start|-done)?(?:\.\d+)?\s*=?\s*"
    r"([a-z0-9]+\[[^\]]*\]|\([^)]*\))", re.IGNORECASE)

_SHAPE_RE = re.compile(r"(u8|u16|u32|u64|s8|s16|s32|s64|f16|bf16|f32|f64|"
                       r"pred|c64|c128)\[([0-9,]*)\]")

_DTYPE_BYTES = {"pred": 1, "u8": 1, "s8": 1, "u16": 2, "s16": 2, "f16": 2,
                "bf16": 2, "u32": 4, "s32": 4, "f32": 4, "c64": 8,
                "u64": 8, "s64": 8, "f64": 8, "c128": 16}


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo: str) -> int:
    """Sum output-shape bytes of every collective op (the data each
    device moves; -start/-done pairs are deduplicated by counting only
    -start or the plain op)."""
    total = 0
    for m in re.finditer(
            r"^\s*(?:[%\w.\-]+)\s*=\s*((?:\([^)]*\)|[a-z0-9]+\[[^\]]*\]))"
            r"[^=]*?\b(all-gather|all-reduce|reduce-scatter|all-to-all|"
            r"collective-permute)(?!-done)", hlo, re.MULTILINE):
        total += _shape_bytes(m.group(1))
    return total


def model_flops_per_device(arch: str, shape: str, n_devices: int) -> float:
    from repro.configs import get_config
    from repro.launch.specs import SHAPES
    cfg = get_config(arch)
    info = SHAPES[shape]
    n = cfg.active_param_count()
    if info["kind"] == "train":
        tokens = info["seq"] * info["batch"]
        return 6.0 * n * tokens / n_devices
    if info["kind"] == "prefill":
        tokens = info["seq"] * info["batch"]
        return 2.0 * n * tokens / n_devices
    tokens = info["batch"]          # decode: one token per sequence
    return 2.0 * n * tokens / n_devices


def roofline_report(rec: Dict) -> Dict:
    """Three terms (seconds, per device):
      compute    — probe-corrected HLO FLOPs / peak
      memory     — working-set stream: peak live bytes (memory_analysis)
                   / HBM bw.  (HLO 'bytes accessed' is NOT used: XLA's
                   static analysis counts a dynamic-update-slice as
                   touching the whole operand, which overstates cache
                   writes by orders of magnitude; the live working set
                   streamed once is the faithful first-order model.)
      collective — probe-corrected collective operand bytes / ICI bw
    """
    hw = V5E
    compute_s = rec["flops_per_device"] / hw.peak_flops
    memory_s = rec["peak_bytes_per_device"] / hw.hbm_bw
    coll_s = rec["collective_bytes_per_device"] / (hw.ici_bw * hw.ici_links)
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": coll_s}
    dominant = max(terms, key=terms.get)
    mflops = model_flops_per_device(rec["arch"], rec["shape"],
                                    rec["n_devices"])
    return {
        **{k: float(f"{v:.6g}") for k, v in terms.items()},
        "dominant": dominant.replace("_s", ""),
        "model_flops_per_device": float(f"{mflops:.6g}"),
        "useful_flops_ratio": float(
            f"{mflops / max(rec['flops_per_device'], 1):.4g}"),
        "fits_hbm": rec["peak_bytes_per_device"] <= hw.hbm_bytes,
    }
