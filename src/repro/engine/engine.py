"""Real JAX serving engine: executes mixed chunked-prefill + decode
batches on an actual model (runnable on CPU with small configs; the same
code path jit-lowers for the TPU meshes in the dry-run).

Shapes are static per compiled variant: decode always runs the full slot
batch (inactive rows are harmless — masks derive validity from each
row's own position, and recurrent state is zeroed at slot assignment);
prefill chunks run row-wise with exact shapes (distinct chunk lengths
compile once each — the demo quantizes prompt lengths to bound variants).
"""
from __future__ import annotations

import functools
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine import migrate
from repro.engine.kvcache import SlotTable
from repro.engine.request import Request
from repro.models import transformer as tf
from repro.models.config import ModelConfig


class JaxExecutor:
    """Implements the core.instance.Executor protocol with a real model."""

    def __init__(self, cfg: ModelConfig, params, n_slots: int, max_seq: int,
                 eos_id: Optional[int] = None, greedy: bool = True,
                 seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.eos_id = eos_id
        self.greedy = greedy
        self.cache = tf.init_cache(cfg, n_slots, max_seq)
        self.slots = SlotTable(n_slots)
        self.positions = np.zeros(n_slots, np.int32)
        self.last_token = np.zeros(n_slots, np.int32)
        self._rng = np.random.default_rng(seed)

        @jax.jit
        def _decode(params, cache, tokens, pos):
            logits, cache, _ = tf.forward(params, cfg, tokens, pos[:, None],
                                          cache)
            return logits[:, -1], cache

        self._decode = _decode

        @functools.partial(jax.jit, static_argnames=("T",))
        def _prefill_row(params, row_cache, tokens, start, T):
            del T
            positions = start[:, None] + jnp.arange(
                tokens.shape[1], dtype=jnp.int32)[None]
            logits, row_cache, _ = tf.forward(params, cfg, tokens, positions,
                                              row_cache)
            return logits[:, -1], row_cache

        self._prefill_row = _prefill_row

    # ------------------------------------------------------------------
    def add_request(self, req: Request):
        if req.rid in getattr(self, "_preadded", set()):
            # state already inserted by a migration (insert_state)
            self._preadded.discard(req.rid)
            return
        slot = self.slots.acquire(req.rid)
        self.cache = migrate.zero_row(self.cache, slot)
        self.positions[slot] = 0
        if req.prompt_tokens is None:
            req.prompt_tokens = list(
                self._rng.integers(1, self.cfg.vocab_size,
                                   size=req.prompt_len))

    def release(self, req: Request):
        self.slots.release(req.rid)

    # ------------------------------------------------------------------
    def _row_cache(self, slot: int):
        return {"segments": jax.tree.map(
            lambda a: a[:, slot:slot + 1], self.cache["segments"])}

    def _write_row_cache(self, slot: int, row_cache):
        self.cache = {"segments": jax.tree.map(
            lambda a, r: a.at[:, slot:slot + 1].set(r),
            self.cache["segments"], row_cache["segments"])}

    def _sample(self, logits_row) -> int:
        if self.greedy:
            return int(jnp.argmax(logits_row))
        p = np.asarray(jax.nn.softmax(logits_row.astype(jnp.float32)))
        return int(self._rng.choice(len(p), p=p / p.sum()))

    # ------------------------------------------------------------------
    def execute(self, plan) -> Dict[int, bool]:
        eos: Dict[int, bool] = {}
        # --- chunked prefill (row-wise, exact shapes) ---
        for req, take in plan.prefill_items:
            slot = self.slots.slot(req.rid)
            chunk = np.asarray(
                req.prompt_tokens[req.prefill_pos:req.prefill_pos + take],
                np.int32)[None]
            start = jnp.full((1,), req.prefill_pos, jnp.int32)
            last, row_cache = self._prefill_row(
                self.params, self._row_cache(slot), jnp.asarray(chunk),
                start, T=take)
            self._write_row_cache(slot, row_cache)
            self.positions[slot] = req.prefill_pos + take
            if take == req.prefill_remaining:
                # the sampled first token is NOT yet in the cache; it is
                # written when fed to the next decode step at position
                # == prompt_len (positions[slot] already points there).
                tok = self._sample(last[0])
                req.output_tokens.append(tok)
                self.last_token[slot] = tok
        # --- decode (full slot batch, one call) ---
        if plan.decode_reqs:
            tokens = jnp.asarray(self.last_token[:, None])
            pos = jnp.asarray(self.positions)
            logits, self.cache = self._decode(self.params, self.cache,
                                              tokens, pos)
            active = [(r, self.slots.slot(r.rid)) for r in plan.decode_reqs]
            for req, slot in active:
                tok = self._sample(logits[slot])
                req.output_tokens.append(tok)
                self.last_token[slot] = tok
                self.positions[slot] += 1
                if self.eos_id is not None and tok == self.eos_id:
                    eos[req.rid] = True
        return eos

    # ------------------------------------------------------------------
    def extract_state(self, req: Request):
        slot = self.slots.slot(req.rid)
        row = migrate.extract_row(self.cache, slot)
        return {"row": row, "pos": int(self.positions[slot]),
                "last_token": int(self.last_token[slot])}

    def insert_state(self, req: Request, state):
        slot = self.slots.acquire(req.rid)
        self.cache = migrate.insert_row(self.cache, state["row"], slot)
        self.positions[slot] = state["pos"]
        self.last_token[slot] = state["last_token"]
        # re-acquired below by add_request semantics: mark as pre-added
        self._preadded = getattr(self, "_preadded", set())
        self._preadded.add(req.rid)

    def migration_bytes(self, req: Request) -> int:
        slot = self.slots.slot(req.rid)
        return migrate.row_bytes(migrate.extract_row(self.cache, slot))


class SimExecutor:
    """Token oracle for the event-driven simulator: no tensors, no
    compute.  EOS arrives when the request's hidden output length is
    reached (the instance observes it only as done())."""

    def execute(self, plan) -> Dict[int, bool]:
        return {}

    def add_request(self, req: Request):
        pass

    def release(self, req: Request):
        pass

    def extract_state(self, req: Request):
        return None

    def insert_state(self, req: Request, state):
        pass
