"""Real JAX serving engine: executes mixed chunked-prefill + decode
batches on an actual model (runnable on CPU with small configs; the same
code path jit-lowers for the TPU meshes in the dry-run).

Two executor paths share one cache layout:

* **batched** (default): all prefill chunks of an iteration are packed
  into one padded ``[B, T_bucket]`` jit call with per-row start
  positions, valid lengths, and cache-slot indices.  Cache rows are
  gathered/scattered *inside* the jitted step (slot-indexed, donated
  buffers), and sampling (greedy argmax / temperature categorical) is
  fused into the step so only token ids cross the host boundary.  Both
  batch axes are bucketed (see ``repro.engine.batching``) to bound the
  number of compile variants.  Families with recurrent or windowed
  per-layer state (mamba2 / zamba2 / gemma3-local / whisper) and
  capacity-dropping MoE cannot be T-padded without changing results;
  they fall back to an on-device slot-indexed row path (exact shapes,
  still jit-fused sampling, no host-side cache gather/scatter).
* **row-wise reference** (``batched=False``): the original executor —
  per-request exact-shape prefill with host-side cache row
  gather/scatter and host-side sampling.  Kept as the token-exact
  oracle the batched path is tested against.

Decode always runs the full slot batch (inactive rows are harmless —
masks derive validity from each row's own position, and recurrent state
is zeroed at slot assignment).
"""
from __future__ import annotations

import functools
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.cache.prefix_tree import PrefixTree
from repro.engine import batching, migrate
from repro.engine.kvcache import SlotTable
from repro.engine.request import Request
from repro.models import transformer as tf
from repro.models.config import ATTN, ModelConfig


def packable(cfg: ModelConfig) -> bool:
    """True if T-padded packed prefill is token-exact for this config:
    every layer is full-cache global attention (padding KV writes are
    dropped and padded positions are masked by causality).  Ring-buffer
    windows would be overwritten by padding slots, recurrent SSM state
    would advance through padding, and capacity-dropping MoE would route
    padding tokens into expert capacity."""
    return all(b == ATTN for seg in cfg.segments() for b in seg.pattern)


class JaxExecutor:
    """Implements the core.instance.Executor protocol with a real model."""

    def __init__(self, cfg: ModelConfig, params, n_slots: int, max_seq: int,
                 eos_id: Optional[int] = None, greedy: bool = True,
                 seed: int = 0, batched: bool = True,
                 t_buckets: Optional[Sequence[int]] = None,
                 temperature: float = 1.0, prefix_cache: bool = False,
                 cache_block_size: int = 16):
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.eos_id = eos_id
        self.greedy = greedy
        self.temperature = temperature
        self.batched = batched
        self.packed = batched and packable(cfg)
        self.t_buckets = (batching.default_t_buckets(max_seq)
                          if t_buckets is None else tuple(sorted(t_buckets)))
        self.cache = tf.init_cache(cfg, n_slots, max_seq)
        self.slots = SlotTable(n_slots)
        self.positions = np.zeros(n_slots, np.int32)
        self.last_token = np.zeros(n_slots, np.int32)
        self._rng = np.random.default_rng(seed)
        self._base_key = jax.random.PRNGKey(seed)
        self._step = 0
        # prefix-KV reuse: donor index over resident/retained slot rows.
        # KV at position p depends only on tokens [0, p] iff every layer
        # is full-cache global attention — same gate as T-padding.
        self.prefix_cache_enabled = prefix_cache and packable(cfg)
        self.cache_block_size = cache_block_size
        self._donors = PrefixTree(cache_block_size)
        self._claimed: set = set()
        self._preadded: set = set()
        self.prefix_adoptions = 0
        self.prefix_copies = 0

        def _sample_on_device(logits, key):
            if self.greedy:
                return jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return jax.random.categorical(
                key, logits.astype(jnp.float32) / self.temperature,
                axis=-1).astype(jnp.int32)

        # ---- reference path (host-side sampling, logits cross) ----
        @jax.jit
        def _decode(params, cache, tokens, pos):
            logits, cache, _ = tf.forward(params, cfg, tokens, pos[:, None],
                                          cache)
            return logits[:, -1], cache

        self._decode = _decode

        @functools.partial(jax.jit, static_argnames=("T",))
        def _prefill_row(params, row_cache, tokens, start, T):
            del T
            positions = start[:, None] + jnp.arange(
                tokens.shape[1], dtype=jnp.int32)[None]
            logits, row_cache, _ = tf.forward(params, cfg, tokens, positions,
                                              row_cache)
            return logits[:, -1], row_cache

        self._prefill_row = _prefill_row

        # ---- batched path (fused sampling, tokens cross) ----
        @functools.partial(jax.jit, donate_argnames=("cache",))
        def _decode_fused(params, cache, tokens, pos, key):
            logits, cache, _ = tf.forward(params, cfg, tokens, pos[:, None],
                                          cache)
            return _sample_on_device(logits[:, -1], key), cache

        self._decode_fused = _decode_fused

        @functools.partial(jax.jit, donate_argnames=("cache",))
        def _prefill_packed(params, cache, tokens, start, valid, slots, key):
            # compile variants keyed on the bucketed (B, T) shape only
            T = tokens.shape[1]
            positions = jnp.minimum(
                start[:, None] + jnp.arange(T, dtype=jnp.int32)[None],
                max_seq - 1)                   # padding must not wrap slots
            rows = jax.tree.map(lambda a: a[:, slots], cache["segments"])
            hidden, new_rows, _ = tf.forward(
                params, cfg, tokens, positions, {"segments": rows},
                compute_logits=False, valid_len=valid)
            # pad rows carry slot == n_slots: scatter drops them on-device
            segs = jax.tree.map(
                lambda a, r: a.at[:, slots].set(r.astype(a.dtype),
                                                mode="drop"),
                cache["segments"], new_rows["segments"])
            last = jnp.take_along_axis(
                hidden, jnp.maximum(valid - 1, 0)[:, None, None], axis=1)[:, 0]
            logits = jnp.einsum("bd,dv->bv", last, params["lm_head"])
            return _sample_on_device(logits, key), {"segments": segs}

        self._prefill_packed = _prefill_packed

        @functools.partial(jax.jit, donate_argnames=("cache",))
        def _prefill_slot(params, cache, tokens, start, slot, key):
            # exact-shape fallback for families where padding is unsafe;
            # the cache row is still gathered/scattered on-device.
            positions = start[:, None] + jnp.arange(
                tokens.shape[1], dtype=jnp.int32)[None]
            row = jax.tree.map(
                lambda a: jax.lax.dynamic_slice_in_dim(a, slot, 1, axis=1),
                cache["segments"])
            hidden, new_row, _ = tf.forward(
                params, cfg, tokens, positions, {"segments": row},
                compute_logits=False)
            segs = jax.tree.map(
                lambda a, r: jax.lax.dynamic_update_slice_in_dim(
                    a, r.astype(a.dtype), slot, axis=1),
                cache["segments"], new_row["segments"])
            logits = jnp.einsum("bd,dv->bv", hidden[:, -1],
                                params["lm_head"])
            return _sample_on_device(logits, key), {"segments": segs}

        self._prefill_slot = _prefill_slot

    # ------------------------------------------------------------------
    def _acquire_slot(self, rid: int) -> int:
        """Acquire a free slot, preferring rows that are NOT retained
        prefix donors; whatever row is reused stops being a donor."""
        avoid = set(self._donors.bids()) if self.prefix_cache_enabled else ()
        slot = self.slots.acquire(rid, avoid=avoid)
        self._donors.remove_bid(slot)
        return slot

    def claim_prefix(self, req: Request, max_tokens: int) -> int:
        """Reuse cached KV for the longest donor-resident prefix of
        ``req.prompt_tokens`` (capped at ``max_tokens``, full blocks).

        Adopts the donor row outright when it is free (a finished
        request's retained slot — zero copies), otherwise gathers the
        matched columns from the live donor's row into a fresh slot.
        Acquires the request's slot either way; ``add_request`` then
        skips its own acquisition.  Returns the claimed token count."""
        if not self.prefix_cache_enabled or not req.prompt_tokens:
            return 0
        bs = self.cache_block_size
        cap = min(max_tokens, len(req.prompt_tokens) - 1,
                  self.max_seq - 1) // bs
        path = self._donors.match(req.prompt_tokens, cap) if cap > 0 else []
        if not path:
            return 0
        donor = path[-1].bid                  # deepest node's row holds
        h = len(path) * bs                    # the whole matched prefix
        if self.slots.is_free(donor):
            self.slots.acquire_slot(req.rid, donor)
            self._donors.remove_bid(donor)
            slot = donor
            self.prefix_adoptions += 1
        else:
            slot = self._acquire_slot(req.rid)
            self.cache = migrate.copy_prefix(self.cache, donor, slot, h)
            self.prefix_copies += 1
        # stale columns >= h are dead: masked by position until prefill/
        # decode overwrites them in order (same argument as zero_row).
        self.positions[slot] = h
        self.last_token[slot] = 0
        self._claimed.add(req.rid)
        return h

    def add_request(self, req: Request):
        if req.rid in self._preadded:
            # state already inserted by a migration (insert_state)
            self._preadded.discard(req.rid)
            return
        if req.rid in self._claimed:
            # slot acquired + prefix columns populated by claim_prefix;
            # zeroing would wipe the inherited KV
            self._claimed.discard(req.rid)
            return
        slot = self._acquire_slot(req.rid)
        self.cache = migrate.zero_row(self.cache, slot)
        self.positions[slot] = 0
        if req.prompt_tokens is None:
            req.prompt_tokens = list(
                self._rng.integers(1, self.cfg.vocab_size,
                                   size=req.prompt_len))

    def release(self, req: Request):
        # the freed row keeps its donor registration: its prompt KV
        # stays adoptable until the slot is reacquired
        if req.rid in self._claimed and self.slots.has(req.rid):
            # claim never consumed (admission unwound): the row's prefix
            # columns are valid KV — re-register it as a retained donor
            # instead of forfeiting what adoption deregistered
            slot = self.slots.slot(req.rid)
            h = int(self.positions[slot])
            n = h // self.cache_block_size
            if n > 0 and req.prompt_tokens:
                self._donors.insert(
                    req.prompt_tokens[:n * self.cache_block_size],
                    [slot] * n)
        self._claimed.discard(req.rid)
        self.slots.release(req.rid)

    def _register_donor(self, req: Request, slot: int):
        """Prefill complete: the row now holds valid KV for the whole
        prompt — publish its full blocks to the donor index."""
        if not self.prefix_cache_enabled or not req.prompt_tokens:
            return
        n = len(req.prompt_tokens) // self.cache_block_size
        if n > 0:
            self._donors.insert(
                req.prompt_tokens[:n * self.cache_block_size], [slot] * n)

    # ------------------------------------------------------------------
    def _row_cache(self, slot: int):
        return {"segments": jax.tree.map(
            lambda a: a[:, slot:slot + 1], self.cache["segments"])}

    def _write_row_cache(self, slot: int, row_cache):
        self.cache = {"segments": jax.tree.map(
            lambda a, r: a.at[:, slot:slot + 1].set(r),
            self.cache["segments"], row_cache["segments"])}

    def _sample(self, logits_row) -> int:
        if self.greedy:
            return int(jnp.argmax(logits_row))
        p = np.asarray(jax.nn.softmax(
            logits_row.astype(jnp.float32) / self.temperature))
        return int(self._rng.choice(len(p), p=p / p.sum()))

    def _next_key(self):
        key = jax.random.fold_in(self._base_key, self._step)
        self._step += 1
        return key

    # ------------------------------------------------------------------
    def execute(self, plan) -> Dict[int, bool]:
        if self.batched:
            return self._execute_batched(plan)
        return self._execute_reference(plan)

    # ---- batched hot path --------------------------------------------
    def _execute_batched(self, plan) -> Dict[int, bool]:
        eos: Dict[int, bool] = {}
        if plan.prefill_items:
            rows = plan.prefill_rows()
            if self.packed:
                self._prefill_packed_call(rows, eos)
            else:
                self._prefill_slot_calls(rows, eos)
        if plan.decode_reqs:
            toks, self.cache = self._decode_fused(
                self.params, self.cache,
                jnp.asarray(self.last_token[:, None]),
                jnp.asarray(self.positions), self._next_key())
            toks = np.asarray(toks)
            for req in plan.decode_reqs:
                slot = self.slots.slot(req.rid)
                tok = int(toks[slot])
                req.output_tokens.append(tok)
                self.last_token[slot] = tok
                self.positions[slot] += 1
                if self.eos_id is not None and tok == self.eos_id:
                    eos[req.rid] = True
        return eos

    def _prefill_packed_call(self, rows, eos):
        chunks = [req.prompt_tokens[start:start + take]
                  for req, start, take, _ in rows]
        row_slots = self.slots.slots_of([req.rid for req, _, _, _ in rows])
        packed = batching.pack_prefill(
            chunks, [start for _, start, _, _ in rows], row_slots,
            self.n_slots, self.t_buckets)
        toks, self.cache = self._prefill_packed(
            self.params, self.cache, packed.tokens, packed.start,
            packed.valid, packed.slots, self._next_key())
        toks = np.asarray(toks)
        for i, (req, start, take, completes) in enumerate(rows):
            slot = row_slots[i]
            self.positions[slot] = start + take
            if completes:
                tok = int(toks[i])
                req.output_tokens.append(tok)
                self.last_token[slot] = tok
                self._register_donor(req, slot)
                if self.eos_id is not None and tok == self.eos_id:
                    eos[req.rid] = True

    def _prefill_slot_calls(self, rows, eos):
        for req, start, take, completes in rows:
            slot = self.slots.slot(req.rid)
            chunk = np.asarray(req.prompt_tokens[start:start + take],
                               np.int32)[None]
            tok, self.cache = self._prefill_slot(
                self.params, self.cache, jnp.asarray(chunk),
                jnp.full((1,), start, jnp.int32),
                jnp.int32(slot), self._next_key())
            self.positions[slot] = start + take
            if completes:
                tok = int(tok[0])
                req.output_tokens.append(tok)
                self.last_token[slot] = tok
                self._register_donor(req, slot)
                if self.eos_id is not None and tok == self.eos_id:
                    eos[req.rid] = True

    # ---- row-wise reference path (token-exact oracle) ----------------
    def _execute_reference(self, plan) -> Dict[int, bool]:
        eos: Dict[int, bool] = {}
        # --- chunked prefill (row-wise, exact shapes) ---
        for req, take in plan.prefill_items:
            slot = self.slots.slot(req.rid)
            chunk = np.asarray(
                req.prompt_tokens[req.prefill_pos:req.prefill_pos + take],
                np.int32)[None]
            start = jnp.full((1,), req.prefill_pos, jnp.int32)
            last, row_cache = self._prefill_row(
                self.params, self._row_cache(slot), jnp.asarray(chunk),
                start, T=take)
            self._write_row_cache(slot, row_cache)
            self.positions[slot] = req.prefill_pos + take
            if take == req.prefill_remaining:
                # the sampled first token is NOT yet in the cache; it is
                # written when fed to the next decode step at position
                # == prompt_len (positions[slot] already points there).
                tok = self._sample(last[0])
                req.output_tokens.append(tok)
                self.last_token[slot] = tok
                self._register_donor(req, slot)
                if self.eos_id is not None and tok == self.eos_id:
                    eos[req.rid] = True
        # --- decode (full slot batch, one call) ---
        if plan.decode_reqs:
            tokens = jnp.asarray(self.last_token[:, None])
            pos = jnp.asarray(self.positions)
            logits, self.cache = self._decode(self.params, self.cache,
                                              tokens, pos)
            active = [(r, self.slots.slot(r.rid)) for r in plan.decode_reqs]
            for req, slot in active:
                tok = self._sample(logits[slot])
                req.output_tokens.append(tok)
                self.last_token[slot] = tok
                self.positions[slot] += 1
                if self.eos_id is not None and tok == self.eos_id:
                    eos[req.rid] = True
        return eos

    # ------------------------------------------------------------------
    def extract_state(self, req: Request):
        slot = self.slots.slot(req.rid)
        row = migrate.extract_row(self.cache, slot)
        return {"row": row, "pos": int(self.positions[slot]),
                "last_token": int(self.last_token[slot])}

    def insert_state(self, req: Request, state):
        slot = self._acquire_slot(req.rid)
        self.cache = migrate.insert_row(self.cache, state["row"], slot)
        self.positions[slot] = state["pos"]
        self.last_token[slot] = state["last_token"]
        # re-acquired below by add_request semantics: mark as pre-added
        self._preadded.add(req.rid)

    def migration_bytes(self, req: Request) -> int:
        slot = self.slots.slot(req.rid)
        return migrate.row_bytes(migrate.extract_row(self.cache, slot))


class SimExecutor:
    """Token oracle for the event-driven simulator: no tensors, no
    compute.  EOS arrives when the request's hidden output length is
    reached (the instance observes it only as done())."""

    def execute(self, plan) -> Dict[int, bool]:
        return {}

    def add_request(self, req: Request):
        pass

    def claim_prefix(self, req: Request, max_tokens: int) -> int:
        """No physical rows to gather — the instance-level block cache
        is the full model of HBM retention in simulation."""
        return max_tokens

    def release(self, req: Request):
        pass

    def extract_state(self, req: Request):
        return None

    def insert_state(self, req: Request, state):
        pass
