"""Real JAX serving engine: executes mixed chunked-prefill + decode
batches on an actual model (runnable on CPU with small configs; the same
code path jit-lowers for the TPU meshes in the dry-run).

Three executor paths:

* **paged** (default for all-ATTN configs): KV lives in a physical
  block pool ([L, num_blocks, block_size, Hkv, Dh], flat token axis)
  addressed through per-slot int32 block tables
  (``repro.engine.paged``).  Every iteration — prefill chunks AND
  decode steps together — executes as ONE fused jit call: decode rows
  are packed as length-1 chunks next to the prefill rows, attention
  reads KV through the block tables (Pallas paged kernels when
  ``attention.use_kernels`` is on, jnp gather reference otherwise),
  sampling is fused, and only token ids cross the host boundary.
  Prefix reuse and migration become block-table pointer updates, and
  HBM admission is bounded by blocks actually referenced instead of
  ``n_slots x max_seq`` reserved rows.
* **batched dense** (fallback for families that cannot page: recurrent
  / windowed state, capacity-dropping MoE): packed T-padded prefill
  where safe, else exact-shape slot-indexed rows; full-slot-batch fused
  decode over the slot-contiguous dense cache.
* **row-wise reference** (``batched=False``): the original executor —
  per-request exact-shape prefill with host-side cache row
  gather/scatter and host-side sampling.  Kept as the token-exact
  oracle the paged and batched paths are tested against.

Decode on the dense paths always runs the full slot batch (inactive
rows are harmless — masks derive validity from each row's own position,
and recurrent state is zeroed at slot assignment); the paged path runs
exactly the scheduled rows.

Two serving-loop-facing mechanisms sit on top of the three paths:

* **multi-step decode horizon** — a decode-only iteration whose plan
  carries ``horizon == K > 1`` executes as ONE jitted ``lax.scan`` over
  K decode steps (paged and packed-dense paths): sampling stays on
  device between steps, per-row done-masks freeze rows that emit EOS or
  exhaust their per-row budget (their KV writes drop via ``valid_len``),
  and block tables are pre-grown to the end-of-horizon frontier so the
  in-loop write pointer advances through them.  One host sync then
  retires up to ``K x B`` tokens.
* **non-blocking ``step_async``** — every executor path dispatches its
  jit calls and returns a :class:`PendingStep` immediately (JAX async
  dispatch keeps the device busy); the single blocking ``np.asarray``
  readback happens at ``resolve()``, so the serving loop can ingest
  arrivals, schedule other instances, and stream the *previous*
  horizon's tokens while this one computes.  ``execute`` remains the
  synchronous wrapper (``step_async(plan).resolve()``).
"""
from __future__ import annotations

import functools
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.cache.prefix_cache import PrefixCache
from repro.cache.prefix_tree import PrefixTree
from repro.engine import batching, migrate
from repro.engine.kvcache import SlotTable
from repro.engine.paged import PagedKVCache
from repro.engine.request import Request
from repro.models import transformer as tf
from repro.models.config import ATTN, ModelConfig


class MigrationFormatError(ValueError):
    """A migrated engine state's KV format (dense row vs. paged blocks)
    does not match the destination executor's format.  Dense<->paged
    cross-migration is unsupported — migrate between like engines."""


class PendingStep:
    """An in-flight executor iteration: the jit calls are dispatched, the
    host readback is deferred.

    ``resolve()`` performs the (single) blocking host sync, applies
    tokens/EOS to the step's requests through the executor-supplied
    closure, and returns the eos dict — the same contract as
    ``execute``.  ``ready()`` / ``prefetch()`` let an idle serving loop
    materialize the device results without blocking once the device has
    finished, so the later ``resolve()`` costs nothing.

    ``emitted`` maps rid -> tokens produced this step (populated at
    resolve; consumers fall back to the plan's per-row budgets when a
    rid is absent)."""

    def __init__(self, executor, arrays, apply_fn, horizon: int = 1):
        self._ex = executor
        self._arrays = tuple(arrays)
        self._apply = apply_fn
        self.horizon = horizon
        self._np: Optional[list] = None
        self.eos: Optional[Dict[int, bool]] = None
        self.emitted: Dict[int, int] = {}
        self.resolved = False

    def ready(self) -> bool:
        """True once every dispatched array has landed (non-blocking)."""
        if self._np is not None:
            return True
        try:
            return all(a.is_ready() for a in self._arrays)
        except AttributeError:      # older jax: no readiness probe
            return False

    def prefetch(self):
        """Materialize the device results on the host.  Every
        materialization counts as a readback; it additionally counts as
        a blocking sync unless the arrays were already ready (the
        serving loop calls this from idle pacing gaps, where it is
        free)."""
        if self._np is None:
            if self._arrays:
                self._ex.host_readbacks += 1
                if not self.ready():
                    self._ex.host_syncs += 1
            self._np = [np.asarray(a) for a in self._arrays]
        return self._np

    def resolve(self) -> Dict[int, bool]:
        if not self.resolved:
            arrays = self.prefetch()
            self.eos = self._apply(arrays, self)
            self.resolved = True
            if self._ex is not None and self._ex._pending is self:
                self._ex._pending = None
        return self.eos


class ImmediateStep:
    """Trivial pending step for executors with nothing in flight (the
    simulator's token oracle, empty plans)."""

    horizon = 1

    def __init__(self, eos: Optional[Dict[int, bool]] = None):
        self.eos = dict(eos or {})
        self.emitted: Dict[int, int] = {}
        self.resolved = False

    def ready(self) -> bool:
        return True

    def prefetch(self):
        return []

    def resolve(self) -> Dict[int, bool]:
        self.resolved = True
        return self.eos


def _prefill_window(req: Request, start: int, take: int):
    """Map an instance-space prefill window to (token chunk, cache
    position).  Normally the identity on ``prompt_tokens``; after a
    preemption-by-recompute the request re-prefills from negative
    ``prefill_pos`` and the true stream is prompt + the output tokens
    generated before eviction, at position ``start + recompute_offset``
    (see Request.recompute_offset)."""
    off = req.recompute_offset
    if not off:
        return req.prompt_tokens[start:start + take], start
    pos = start + off
    stream = list(req.prompt_tokens) + list(req.output_tokens[:off])
    return stream[pos:pos + take], pos


def packable(cfg: ModelConfig) -> bool:
    """True if T-padded packed prefill is token-exact for this config:
    every layer is full-cache global attention (padding KV writes are
    dropped and padded positions are masked by causality).  Ring-buffer
    windows would be overwritten by padding slots, recurrent SSM state
    would advance through padding, and capacity-dropping MoE would route
    padding tokens into expert capacity."""
    return all(b == ATTN for seg in cfg.segments() for b in seg.pattern)


class JaxExecutor:
    """Implements the core.instance.Executor protocol with a real model."""

    #: decode-growth headroom (tokens) reserved beyond the known context
    #: at admission — mirrors Instance._admit_prefill
    HEADROOM = 64

    def __init__(self, cfg: ModelConfig, params, n_slots: int, max_seq: int,
                 eos_id: Optional[int] = None, greedy: bool = True,
                 seed: int = 0, batched: bool = True,
                 t_buckets: Optional[Sequence[int]] = None,
                 temperature: float = 1.0, prefix_cache: bool = False,
                 cache_block_size: int = 16,
                 paged: Optional[bool] = None,
                 hbm_blocks: Optional[int] = None,
                 kv_quant: Optional[str] = None,
                 kv_spill_blocks: int = 0):
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.eos_id = eos_id
        self.greedy = greedy
        self.temperature = temperature
        self.batched = batched
        self.packed = batched and packable(cfg)
        self.t_buckets = (batching.default_t_buckets(max_seq)
                          if t_buckets is None else tuple(sorted(t_buckets)))
        self.slots = SlotTable(n_slots)
        self.positions = np.zeros(n_slots, np.int32)
        self.last_token = np.zeros(n_slots, np.int32)
        self._rng = np.random.default_rng(seed)
        self._base_key = jax.random.PRNGKey(seed)
        self._step = 0
        # prefix-KV reuse: KV at position p depends only on tokens [0, p]
        # iff every layer is full-cache global attention — same gate as
        # T-padding (and as paging).
        self.prefix_cache_enabled = prefix_cache and packable(cfg)
        self.cache_block_size = cache_block_size
        self._donors = PrefixTree(cache_block_size)
        self._claimed: set = set()
        self._preadded: set = set()
        self._deferred_states: dict = {}
        self.prefix_adoptions = 0
        self.prefix_copies = 0
        # async-step pipeline state + observability (test hooks):
        # host_readbacks counts every host<->device result
        # materialization (the horizon acceptance bound is readbacks
        # per generated token <= 1/K); host_syncs counts only the
        # BLOCKING ones (device not yet done when the host asked)
        self._pending: Optional[PendingStep] = None
        self.host_readbacks = 0
        self.host_syncs = 0
        self.horizon_calls = 0
        self.horizon_tokens = 0
        # ---- paged physical cache (default wherever paging is exact) --
        self.paged = (batched and packable(cfg) if paged is None
                      else bool(paged) and batched and packable(cfg))
        if kv_quant not in (None, "int8"):
            raise ValueError(f"unsupported kv_quant: {kv_quant!r}")
        self.kv_quant = kv_quant if self.paged else None
        self.kv: Optional[PagedKVCache] = None
        self.prefix_cache_obj: Optional[PrefixCache] = None
        # True once an Instance drives allocate/extend/free on our
        # allocator (unified bookkeeping) — the executor then only READS
        # owned-block lists; False = executor self-manages (standalone /
        # legacy construction with a separate instance allocator).
        self._external_bookkeeping = False
        if self.paged:
            max_blocks = -(-max_seq // cache_block_size)
            # default pool: dense-equivalent capacity + per-slot growth
            # headroom (admission is still per-block by actual context;
            # benches pass a smaller pool to realize the memory win)
            nb = (hbm_blocks if hbm_blocks is not None else
                  n_slots * (max_blocks
                             + self.HEADROOM // cache_block_size))
            alloc = None
            if self.prefix_cache_enabled:
                self.prefix_cache_obj = PrefixCache(
                    nb, cache_block_size, spill_blocks=kv_spill_blocks)
                alloc = self.prefix_cache_obj.allocator
            self.kv = PagedKVCache(cfg, n_slots, max_seq, nb,
                                   cache_block_size, allocator=alloc,
                                   quant=kv_quant)
            if self.prefix_cache_obj is not None:
                self._bind_spill(self.prefix_cache_obj)
            self.cache = None            # no dense rows: that's the point
        else:
            self.cache = tf.init_cache(cfg, n_slots, max_seq)
        # only the paged path lands a migration by aliasing cached prefix
        # blocks; the dense path ships and scatters the full row, so its
        # transfers must be charged in full (cluster._start_transfer)
        self.prefix_aware_transfer = self.paged

        def _sample_on_device(logits, key):
            if self.greedy:
                return jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return jax.random.categorical(
                key, logits.astype(jnp.float32) / self.temperature,
                axis=-1).astype(jnp.int32)

        # ---- reference path (host-side sampling, logits cross) ----
        @jax.jit
        def _decode(params, cache, tokens, pos):
            logits, cache, _ = tf.forward(params, cfg, tokens, pos[:, None],
                                          cache)
            return logits[:, -1], cache

        self._decode = _decode

        @functools.partial(jax.jit, static_argnames=("T",))
        def _prefill_row(params, row_cache, tokens, start, T):
            del T
            positions = start[:, None] + jnp.arange(
                tokens.shape[1], dtype=jnp.int32)[None]
            logits, row_cache, _ = tf.forward(params, cfg, tokens, positions,
                                              row_cache)
            return logits[:, -1], row_cache

        self._prefill_row = _prefill_row

        # ---- batched path (fused sampling, tokens cross) ----
        @functools.partial(jax.jit, donate_argnames=("cache",))
        def _decode_fused(params, cache, tokens, pos, key):
            logits, cache, _ = tf.forward(params, cfg, tokens, pos[:, None],
                                          cache)
            return _sample_on_device(logits[:, -1], key), cache

        self._decode_fused = _decode_fused

        @functools.partial(jax.jit, donate_argnames=("cache",))
        def _prefill_packed(params, cache, tokens, start, valid, slots, key):
            # compile variants keyed on the bucketed (B, T) shape only
            T = tokens.shape[1]
            positions = jnp.minimum(
                start[:, None] + jnp.arange(T, dtype=jnp.int32)[None],
                max_seq - 1)                   # padding must not wrap slots
            rows = jax.tree.map(lambda a: a[:, slots], cache["segments"])
            hidden, new_rows, _ = tf.forward(
                params, cfg, tokens, positions, {"segments": rows},
                compute_logits=False, valid_len=valid)
            # pad rows carry slot == n_slots: scatter drops them on-device
            segs = jax.tree.map(
                lambda a, r: a.at[:, slots].set(r.astype(a.dtype),
                                                mode="drop"),
                cache["segments"], new_rows["segments"])
            last = jnp.take_along_axis(
                hidden, jnp.maximum(valid - 1, 0)[:, None, None], axis=1)[:, 0]
            logits = jnp.einsum("bd,dv->bv", last, params["lm_head"])
            return _sample_on_device(logits, key), {"segments": segs}

        self._prefill_packed = _prefill_packed

        @functools.partial(jax.jit, donate_argnames=("cache",))
        def _prefill_slot(params, cache, tokens, start, slot, key):
            # exact-shape fallback for families where padding is unsafe;
            # the cache row is still gathered/scattered on-device.
            positions = start[:, None] + jnp.arange(
                tokens.shape[1], dtype=jnp.int32)[None]
            row = jax.tree.map(
                lambda a: jax.lax.dynamic_slice_in_dim(a, slot, 1, axis=1),
                cache["segments"])
            hidden, new_row, _ = tf.forward(
                params, cfg, tokens, positions, {"segments": row},
                compute_logits=False)
            segs = jax.tree.map(
                lambda a, r: jax.lax.dynamic_update_slice_in_dim(
                    a, r.astype(a.dtype), slot, axis=1),
                cache["segments"], new_row["segments"])
            logits = jnp.einsum("bd,dv->bv", hidden[:, -1],
                                params["lm_head"])
            return _sample_on_device(logits, key), {"segments": segs}

        self._prefill_slot = _prefill_slot

        # ---- paged path: ONE fused mixed prefill+decode call ----------
        block_size = cache_block_size

        @functools.partial(jax.jit, donate_argnames=("pool",))
        def _mixed_fused(params, pool, tokens, start, valid, tables, key):
            # compile variants keyed on the bucketed (B, T, NB) shape
            T = tokens.shape[1]
            positions = jnp.minimum(
                start[:, None] + jnp.arange(T, dtype=jnp.int32)[None],
                max_seq - 1)               # padding must not leave range
            hidden, pool, _ = tf.forward(
                params, cfg, tokens, positions, pool,
                compute_logits=False, valid_len=valid,
                block_tables=(tables, block_size))
            last = jnp.take_along_axis(
                hidden, jnp.maximum(valid - 1, 0)[:, None, None], axis=1)[:, 0]
            logits = jnp.einsum("bd,dv->bv", last, params["lm_head"])
            return _sample_on_device(logits, key), pool

        self._mixed_fused = _mixed_fused

        # ---- rowwise-path device sampler (only token ids cross) -------
        @jax.jit
        def _sample_batch(logits, key):
            return _sample_on_device(logits, key)

        self._sample_batch = _sample_batch

        # ---- multi-step decode horizon: K fused steps, one readback ---
        eos_id = self.eos_id

        @functools.partial(jax.jit, static_argnames=("K",),
                           donate_argnames=("pool",))
        def _horizon_paged(params, pool, tok0, pos0, budget, tables,
                           key, K):
            # One lax.scan over K decode steps: sampling feeds the next
            # step on device, rows freeze (done-mask) once they emit EOS
            # or exhaust their per-row budget — frozen rows' KV writes
            # drop (valid_len == 0) and their position/token hold still,
            # so the returned carries are exact per-row final states.
            B = tok0.shape[0]

            def body(carry, s):
                pool, last, pos, emitted, done = carry
                active = (~done) & (emitted < budget)
                step = active.astype(jnp.int32)
                p = jnp.minimum(pos, max_seq - 1)
                hidden, pool, _ = tf.forward(
                    params, cfg, last[:, None], p[:, None], pool,
                    compute_logits=False, valid_len=step,
                    block_tables=(tables, block_size))
                logits = jnp.einsum("bd,dv->bv", hidden[:, 0],
                                    params["lm_head"])
                tok = _sample_on_device(logits, jax.random.fold_in(key, s))
                tok = jnp.where(active, tok, last)
                if eos_id is not None:
                    done = done | (active & (tok == eos_id))
                return (pool, tok, pos + step, emitted + step, done), tok

            init = (pool, tok0, pos0, jnp.zeros_like(pos0),
                    jnp.zeros((B,), bool))
            (pool, last, pos, emitted, done), toks = jax.lax.scan(
                body, init, jnp.arange(K, dtype=jnp.int32))
            return toks, emitted, last, pos, done, pool

        self._horizon_paged = _horizon_paged

        @functools.partial(jax.jit, static_argnames=("K",),
                           donate_argnames=("cache",))
        def _horizon_dense(params, cache, tok0, pos0, budget, key, K):
            # Packed-dense variant over the full slot batch: rows with
            # budget 0 (unscheduled slots, padding) never write — unlike
            # the K=1 dense decode, whose harmless-garbage writes rely
            # on later overwrites that a K-step loop cannot guarantee.
            B = tok0.shape[0]

            def body(carry, s):
                cache, last, pos, emitted, done = carry
                active = (~done) & (emitted < budget)
                step = active.astype(jnp.int32)
                logits, cache, _ = tf.forward(
                    params, cfg, last[:, None], pos[:, None], cache,
                    valid_len=step)
                tok = _sample_on_device(logits[:, -1],
                                        jax.random.fold_in(key, s))
                tok = jnp.where(active, tok, last)
                if eos_id is not None:
                    done = done | (active & (tok == eos_id))
                return (cache, tok, pos + step, emitted + step, done), tok

            init = (cache, tok0, pos0, jnp.zeros_like(pos0),
                    jnp.zeros((B,), bool))
            (cache, last, pos, emitted, done), toks = jax.lax.scan(
                body, init, jnp.arange(K, dtype=jnp.int32))
            return toks, emitted, last, pos, done, cache

        self._horizon_dense = _horizon_dense

        # every jitted entry point, for the recompile gauge below
        self._jitted = [_decode, _prefill_row, _decode_fused,
                        _prefill_packed, _prefill_slot, _mixed_fused,
                        _sample_batch, _horizon_paged, _horizon_dense]

    def jit_compiles(self) -> int:
        """Total traced-and-compiled variants across this executor's
        jitted entry points (shape buckets x static args).  A steadily
        climbing value under a steady workload is a recompile storm —
        usually a shape-bucketing bug — and shows up here long before
        it shows up in latency percentiles."""
        n = 0
        for fn in self._jitted:
            try:
                n += fn._cache_size()
            except Exception:      # private API: absent on some versions
                return -1
        return n

    @property
    def horizon_capable(self) -> bool:
        """True when this executor can fuse K>1 decode steps: the paged
        pool and the packed dense path freeze rows via ``valid_len``,
        which needs full-cache attention everywhere (same gate as
        T-padded packing) — other families stay at K=1."""
        return self.paged or self.packed

    # ------------------------------------------------------------------
    # unified bookkeeping surface (paged mode)
    # ------------------------------------------------------------------
    @property
    def allocator(self):
        """The block allocator whose ids index the physical pool (None on
        the dense paths) — an Instance adopts this so admission and the
        tensors share one source of truth."""
        return self.kv.allocator if self.paged else None

    def use_external_bookkeeping(self):
        """An Instance now drives allocate/extend/free on our allocator;
        the executor only reads owned-block lists from here on."""
        self._external_bookkeeping = True

    def adopt_prefix_cache(self, pc: PrefixCache) -> bool:
        """Bind an instance-owned PrefixCache: its allocator's block ids
        become the pool's physical indices and its radix tree becomes
        the donor index.  Returns False (no rebind) when incompatible —
        the executor then keeps self-managed physical bookkeeping."""
        if not self.paged or pc.block_size != self.cache_block_size:
            return False
        self.prefix_cache_obj = pc
        self.kv.rebind_allocator(pc.allocator)
        self._bind_spill(pc)
        self._external_bookkeeping = True
        return True

    def _bind_spill(self, pc: PrefixCache):
        """Give the prefix cache's host spill tier real tensor legs:
        eviction snapshots a block's pool slice to host RAM, promotion
        scatters it back into whatever block id the allocator hands
        out.  Without this binding the tier still runs (bookkeeping-only
        payloads), which is what the simulator uses."""
        if pc.spill is None:
            return
        pc.bind_tiers(
            fetch_block=lambda bid: jax.tree.map(
                np.asarray, self.kv.extract_blocks([bid])),
            load_block=lambda bid, payload: self.kv.insert_blocks(
                [bid], payload))

    def sync(self):
        """Block until all in-flight cache updates land (benchmarks)."""
        if self.paged:
            jax.block_until_ready(self.kv.pool["segments"])
        else:
            jax.block_until_ready(self.cache["segments"])

    def cache_bytes(self) -> int:
        """Device bytes held by the KV cache (pool or dense rows)."""
        if self.paged:
            return self.kv.pool_bytes()
        return sum(a.size * a.dtype.itemsize
                   for a in jax.tree.leaves(self.cache["segments"]))

    # ------------------------------------------------------------------
    def _acquire_slot(self, rid: int) -> int:
        """Acquire a free slot, preferring rows that are NOT retained
        prefix donors; whatever row is reused stops being a donor."""
        avoid = set(self._donors.bids()) if self.prefix_cache_enabled else ()
        slot = self.slots.acquire(rid, avoid=avoid)
        self._donors.remove_bid(slot)
        return slot

    def claim_prefix(self, req: Request, max_tokens: int) -> int:
        """Reuse cached KV for the longest donor-resident prefix of
        ``req.prompt_tokens`` (capped at ``max_tokens``, full blocks).

        Adopts the donor row outright when it is free (a finished
        request's retained slot — zero copies), otherwise gathers the
        matched columns from the live donor's row into a fresh slot.
        Acquires the request's slot either way; ``add_request`` then
        skips its own acquisition.  Returns the claimed token count."""
        if not self.prefix_cache_enabled or not req.prompt_tokens:
            return 0
        if self.paged:
            return self._claim_prefix_paged(req, max_tokens)
        bs = self.cache_block_size
        cap = min(max_tokens, len(req.prompt_tokens) - 1,
                  self.max_seq - 1) // bs
        path = self._donors.match(req.prompt_tokens, cap) if cap > 0 else []
        if not path:
            return 0
        donor = path[-1].bid                  # deepest node's row holds
        h = len(path) * bs                    # the whole matched prefix
        if self.slots.is_free(donor):
            self.slots.acquire_slot(req.rid, donor)
            self._donors.remove_bid(donor)
            slot = donor
            self.prefix_adoptions += 1
        else:
            slot = self._acquire_slot(req.rid)
            self.cache = migrate.copy_prefix(self.cache, donor, slot, h)
            self.prefix_copies += 1
        # stale columns >= h are dead: masked by position until prefill/
        # decode overwrites them in order (same argument as zero_row).
        self.positions[slot] = h
        self.last_token[slot] = 0
        self._claimed.add(req.rid)
        return h

    def _claim_prefix_paged(self, req: Request, max_tokens: int) -> int:
        """Paged prefix hit = copy-on-write block-table aliasing: the new
        request takes REFERENCES on the matched blocks (no tensor
        gather, no row adoption special case — live and finished donors
        are identical because blocks, not slots, hold the KV)."""
        pc = self.prefix_cache_obj
        bs = self.cache_block_size
        cap = (min(max_tokens, len(req.prompt_tokens) - 1, self.max_seq - 1)
               // bs * bs)
        hit = min(pc.match_tokens(req.prompt_tokens), cap)
        if hit <= 0:
            return 0
        slot = self._acquire_slot(req.rid)
        if not self._external_bookkeeping:
            total = len(req.prompt_tokens) + self.HEADROOM
            if not pc.acquire(req.rid, req.prompt_tokens, hit, total):
                self.slots.release(req.rid)
                return 0
            self.kv.refresh_row(slot, req.rid)
        else:
            # the Instance's PrefixCache.acquire (same allocator) takes
            # the references; the table row is built at add_request
            self.kv.clear_row(slot)
        self.positions[slot] = hit
        self.last_token[slot] = 0
        self._claimed.add(req.rid)
        self.prefix_adoptions += 1
        return hit

    def add_request(self, req: Request):
        if req.rid in self._preadded:
            # state already inserted by a migration (insert_state)
            self._preadded.discard(req.rid)
            return
        if req.rid in self._deferred_states:
            # memory-full at inject time: the admission gate has now
            # cleared this request — land the stashed migrated blocks
            # (plain allocation; the prefix-aliasing fast path is only
            # taken when the pool had room at inject)
            state = self._deferred_states.pop(req.rid)
            slot = self._acquire_slot(req.rid)
            if not self._external_bookkeeping:
                self.kv.ensure(req.rid, state["pos"] + self.HEADROOM)
            self._land_blocks(req, state, slot)
            return
        if req.rid in self._claimed:
            # slot acquired + prefix KV inherited by claim_prefix;
            # zeroing / re-tabling would wipe it
            self._claimed.discard(req.rid)
            if self.paged:
                # unified bookkeeping: the Instance has taken the block
                # references by now — materialize the table row
                self.kv.refresh_row(self.slots.slot(req.rid), req.rid)
            return
        slot = self._acquire_slot(req.rid)
        if self.paged:
            if not self._external_bookkeeping:
                # recompute_offset: a preempted request re-prefills its
                # whole context (prompt + regenerated output), not just
                # the prompt
                self.kv.ensure(req.rid,
                               max(req.prompt_len + req.recompute_offset,
                                   1) + self.HEADROOM)
            self.kv.refresh_row(slot, req.rid)
        else:
            self.cache = migrate.zero_row(self.cache, slot)
        self.positions[slot] = 0
        if req.prompt_tokens is None:
            req.prompt_tokens = list(
                self._rng.integers(1, self.cfg.vocab_size,
                                   size=req.prompt_len))

    def release(self, req: Request):
        if self.paged:
            # retention is block-level: freeing decrefs, and registered
            # (committed) blocks are RETAINED in the allocator's LRU —
            # no slot-donor bookkeeping needed
            self._claimed.discard(req.rid)
            self._preadded.discard(req.rid)
            self._deferred_states.pop(req.rid, None)
            slot = self.slots.release(req.rid)
            if slot is not None:
                self.kv.clear_row(slot)
            if not self._external_bookkeeping:
                self.kv.allocator.free(req.rid)    # no-op if never held
            return
        # the freed row keeps its donor registration: its prompt KV
        # stays adoptable until the slot is reacquired
        if req.rid in self._claimed and self.slots.has(req.rid):
            # claim never consumed (admission unwound): the row's prefix
            # columns are valid KV — re-register it as a retained donor
            # instead of forfeiting what adoption deregistered
            slot = self.slots.slot(req.rid)
            h = int(self.positions[slot])
            n = h // self.cache_block_size
            if n > 0 and req.prompt_tokens:
                self._donors.insert(
                    req.prompt_tokens[:n * self.cache_block_size],
                    [slot] * n)
        self._claimed.discard(req.rid)
        self.slots.release(req.rid)

    def _register_donor(self, req: Request, slot: int):
        """Prefill complete (or migrated-in state landed): the row now
        holds valid KV for the whole prompt — publish its full blocks to
        the donor index."""
        if not self.prefix_cache_enabled or not req.prompt_tokens:
            return
        if self.paged:
            # blocks ARE the donor currency: publish + retain them in
            # the shared radix tree (idempotent — the Instance commits
            # through the same PrefixCache at prefill completion)
            if (self.prefix_cache_obj is not None
                    and self.kv.allocator.holds(req.rid)):
                self.prefix_cache_obj.commit(req.rid, req.prompt_tokens)
            return
        n = len(req.prompt_tokens) // self.cache_block_size
        if n > 0:
            self._donors.insert(
                req.prompt_tokens[:n * self.cache_block_size], [slot] * n)

    # ------------------------------------------------------------------
    def _row_cache(self, slot: int):
        return {"segments": jax.tree.map(
            lambda a: a[:, slot:slot + 1], self.cache["segments"])}

    def _write_row_cache(self, slot: int, row_cache):
        self.cache = {"segments": jax.tree.map(
            lambda a, r: a.at[:, slot:slot + 1].set(r),
            self.cache["segments"], row_cache["segments"])}

    def _next_key(self):
        key = jax.random.fold_in(self._base_key, self._step)
        self._step += 1
        return key

    # ------------------------------------------------------------------
    def execute(self, plan) -> Dict[int, bool]:
        """Synchronous wrapper: dispatch + immediately resolve."""
        return self.step_async(plan).resolve()

    def step_async(self, plan) -> PendingStep:
        """Dispatch one planned iteration WITHOUT waiting for device
        results.  Host-deterministic bookkeeping (prefill position
        advances, block-table growth) happens now so the serving loop
        may keep scheduling; token-dependent state (output tokens,
        ``last_token``, EOS, donor registration) lands at
        ``resolve()``.  At most one step may be in flight per
        executor."""
        if self._pending is not None and not self._pending.resolved:
            raise RuntimeError(
                "step_async: previous step not resolved — the pipeline "
                "must be flushed (commit the in-flight iteration) first")
        if self.paged:
            step = self._step_paged(plan)
        elif self.batched:
            step = self._step_batched(plan)
        else:
            step = self._step_reference(plan)
        if isinstance(step, PendingStep):
            self._pending = step
        return step

    def abort_step(self, pending=None):
        """Fault path: abandon an in-flight dispatched step without
        resolving it.  The device work is discarded — no tokens are
        applied, no donor registration happens — and the single-step
        pipeline guard is released so the instance can dispatch again
        after recovery."""
        step = pending if pending is not None else self._pending
        if step is not None:
            step.resolved = True
        if self._pending is step:
            self._pending = None

    def on_crash(self):
        """Total HBM loss: forget everything device-side that outlives
        individual requests — slot rows, donor registrations, deferred
        migration payloads.  Per-request frees happened via ``release``
        during evacuation; this drops the residue (and any rows whose
        requests already finished but stayed adoptable)."""
        self.abort_step()
        self._donors = PrefixTree(self.cache_block_size)
        self._claimed.clear()
        self._preadded.clear()
        self._deferred_states.clear()
        for rid in list(self.slots._slot_of):
            slot = self.slots.release(rid)
            if self.paged and slot is not None:
                self.kv.clear_row(slot)
            if self.paged and not self._external_bookkeeping \
                    and self.kv.allocator.holds(rid):
                self.kv.allocator.free(rid)

    # ---- paged hot path: one fused mixed-batch jit call ---------------
    def _step_paged(self, plan) -> PendingStep:
        """Dispatch a whole TaiChi iteration — every prefill chunk AND
        every decode step — as ONE jit call over the block pool.  Decode
        rows ride along as length-1 chunks (token = last sampled token,
        start = row position); per-row valid lengths and block tables
        make the geometry uniform.  Decode-only plans with ``horizon >
        1`` take the K-step fused loop instead."""
        K = getattr(plan, "horizon", 1)
        if K > 1 and not plan.prefill_items and plan.decode_reqs:
            return self._step_horizon_paged(plan, K)
        rows = []   # (req, slot, start, chunk, completes, is_decode)
        if plan.prefill_items:
            for req, start, take, completes in plan.prefill_rows():
                chunk, pos = _prefill_window(req, start, take)
                rows.append((req, self.slots.slot(req.rid), pos,
                             chunk, completes, False))
        for req in plan.decode_reqs:
            slot = self.slots.slot(req.rid)
            # clamp like the jit step does: contexts past max_seq keep
            # rewriting the last position (the dense ring would wrap)
            rows.append((req, slot,
                         min(int(self.positions[slot]), self.max_seq - 1),
                         [int(self.last_token[slot])], False, True))
        if not rows:
            return ImmediateStep()
        table_rows = [
            self.kv.grow_for(slot, req.rid,
                             min(start + len(chunk), self.max_seq),
                             self._external_bookkeeping)
            for req, slot, start, chunk, _, _ in rows]
        packed = batching.pack_mixed(
            [chunk for _, _, _, chunk, _, _ in rows],
            [start for _, _, start, _, _, _ in rows],
            table_rows, self.t_buckets, self.kv.max_blocks,
            self.cache_block_size)
        toks_dev, self.kv.pool = self._mixed_fused(
            self.params, self.kv.pool, jnp.asarray(packed.tokens),
            jnp.asarray(packed.start), jnp.asarray(packed.valid),
            jnp.asarray(packed.tables), self._next_key())
        # position advances are token-independent: land them at dispatch
        # so the next plan (and the decode rows' next dispatch) sees the
        # post-iteration frontier without waiting on the device
        for req, slot, start, chunk, _, is_dec in rows:
            if is_dec:
                self.positions[slot] += 1
            else:
                self.positions[slot] = start + len(chunk)

        def apply(arrays, handle) -> Dict[int, bool]:
            toks = arrays[0]
            eos: Dict[int, bool] = {}
            for i, (req, slot, start, chunk, completes, is_dec) in \
                    enumerate(rows):
                if is_dec:
                    tok = int(toks[i])
                    req.output_tokens.append(tok)
                    self.last_token[slot] = tok
                    handle.emitted[req.rid] = 1
                    if self.eos_id is not None and tok == self.eos_id:
                        eos[req.rid] = True
                    continue
                if completes:
                    tok = int(toks[i])
                    req.output_tokens.append(tok)
                    self.last_token[slot] = tok
                    self._register_donor(req, slot)
                    if self.eos_id is not None and tok == self.eos_id:
                        eos[req.rid] = True
            return eos

        return PendingStep(self, (toks_dev,), apply)

    def _step_horizon_paged(self, plan, K: int) -> PendingStep:
        """K fused decode steps over the block pool: grow every row's
        table to its end-of-horizon frontier, dispatch one scan, read
        back once."""
        budgets = plan.decode_budgets or [1] * len(plan.decode_reqs)
        rows = []   # (req, slot, pos, budget)
        for req, b in zip(plan.decode_reqs, budgets):
            slot = self.slots.slot(req.rid)
            pos = int(self.positions[slot])
            self.kv.grow_for(slot, req.rid, min(pos + b, self.max_seq),
                             self._external_bookkeeping)
            rows.append((req, slot, pos, b))
        packed = batching.pack_decode(
            [int(self.last_token[s]) for _, s, _, _ in rows],
            [p for _, _, p, _ in rows],
            [b for _, _, _, b in rows],
            [self.kv.tables[s] for _, s, _, _ in rows],
            self.kv.max_blocks, self.cache_block_size)
        toks, emitted, last, pos, done, self.kv.pool = self._horizon_paged(
            self.params, self.kv.pool, jnp.asarray(packed.tokens),
            jnp.asarray(packed.start), jnp.asarray(packed.budget),
            jnp.asarray(packed.tables), self._next_key(), K)
        self.horizon_calls += 1

        def apply(arrays, handle) -> Dict[int, bool]:
            toks_np, em_np, last_np, pos_np, done_np = arrays
            eos: Dict[int, bool] = {}
            for i, (req, slot, _, _) in enumerate(rows):
                n = int(em_np[i])
                handle.emitted[req.rid] = n
                req.output_tokens.extend(
                    int(t) for t in toks_np[:n, i])
                self.last_token[slot] = int(last_np[i])
                self.positions[slot] = int(pos_np[i])
                self.horizon_tokens += n
                if bool(done_np[i]):
                    eos[req.rid] = True
            return eos

        return PendingStep(self, (toks, emitted, last, pos, done),
                           apply, K)

    # ---- batched hot path --------------------------------------------
    def _step_batched(self, plan) -> PendingStep:
        K = getattr(plan, "horizon", 1)
        if K > 1 and self.packed and not plan.prefill_items \
                and plan.decode_reqs:
            return self._step_horizon_dense(plan, K)
        arrays: list = []
        appliers: list = []
        if plan.prefill_items:
            rows = plan.prefill_rows()
            if self.packed:
                self._dispatch_prefill_packed(rows, arrays, appliers)
            else:
                self._dispatch_prefill_slots(rows, arrays, appliers)
        if plan.decode_reqs:
            # the prefill dispatches above already advanced positions
            # for their rows, so this full-batch call's harmless writes
            # to non-decode slots land at post-chunk frontiers — exactly
            # where the synchronous path put them
            toks_dev, self.cache = self._decode_fused(
                self.params, self.cache,
                jnp.asarray(self.last_token[:, None]),
                jnp.asarray(self.positions), self._next_key())
            arrays.append(toks_dev)
            decode_reqs = list(plan.decode_reqs)
            for req in decode_reqs:
                self.positions[self.slots.slot(req.rid)] += 1

            def apply_decode(toks, handle, eos):
                for req in decode_reqs:
                    slot = self.slots.slot(req.rid)
                    tok = int(toks[slot])
                    req.output_tokens.append(tok)
                    self.last_token[slot] = tok
                    handle.emitted[req.rid] = 1
                    if self.eos_id is not None and tok == self.eos_id:
                        eos[req.rid] = True

            appliers.append(apply_decode)
        if not arrays:
            return ImmediateStep()

        def apply(np_arrays, handle) -> Dict[int, bool]:
            eos: Dict[int, bool] = {}
            for arr, fn in zip(np_arrays, appliers):
                fn(arr, handle, eos)
            return eos

        return PendingStep(self, arrays, apply)

    def _step_horizon_dense(self, plan, K: int) -> PendingStep:
        """K fused decode steps over the slot-contiguous dense cache:
        the full slot batch rides through the scan, with per-slot
        budgets freezing everything that is not a scheduled decode
        row."""
        budgets = plan.decode_budgets or [1] * len(plan.decode_reqs)
        slot_budget = np.zeros(self.n_slots, np.int32)
        rows = []   # (req, slot)
        for req, b in zip(plan.decode_reqs, budgets):
            slot = self.slots.slot(req.rid)
            slot_budget[slot] = b
            rows.append((req, slot))
        toks, emitted, last, pos, done, self.cache = self._horizon_dense(
            self.params, self.cache, jnp.asarray(self.last_token),
            jnp.asarray(self.positions), jnp.asarray(slot_budget),
            self._next_key(), K)
        self.horizon_calls += 1

        def apply(arrays, handle) -> Dict[int, bool]:
            toks_np, em_np, last_np, pos_np, done_np = arrays
            eos: Dict[int, bool] = {}
            # update ONLY the scheduled rows' slots: other slots may
            # have been written host-side (e.g. a migration landing)
            # while this step was in flight, and frozen rows carried
            # their inputs through unchanged anyway
            for req, slot in rows:
                n = int(em_np[slot])
                handle.emitted[req.rid] = n
                req.output_tokens.extend(
                    int(t) for t in toks_np[:n, slot])
                self.last_token[slot] = int(last_np[slot])
                self.positions[slot] = int(pos_np[slot])
                self.horizon_tokens += n
                if bool(done_np[slot]):
                    eos[req.rid] = True
            return eos

        return PendingStep(self, (toks, emitted, last, pos, done),
                           apply, K)

    def _dispatch_prefill_packed(self, rows, arrays, appliers):
        windows = [_prefill_window(req, start, take)
                   for req, start, take, _ in rows]
        chunks = [c for c, _ in windows]
        row_slots = self.slots.slots_of([req.rid for req, _, _, _ in rows])
        packed = batching.pack_prefill(
            chunks, [pos for _, pos in windows], row_slots,
            self.n_slots, self.t_buckets)
        toks_dev, self.cache = self._prefill_packed(
            self.params, self.cache, packed.tokens, packed.start,
            packed.valid, packed.slots, self._next_key())
        arrays.append(toks_dev)
        for i, (req, start, take, completes) in enumerate(rows):
            self.positions[row_slots[i]] = windows[i][1] + take

        def apply_prefill(toks, handle, eos):
            for i, (req, start, take, completes) in enumerate(rows):
                if not completes:
                    continue
                slot = row_slots[i]
                tok = int(toks[i])
                req.output_tokens.append(tok)
                self.last_token[slot] = tok
                self._register_donor(req, slot)
                if self.eos_id is not None and tok == self.eos_id:
                    eos[req.rid] = True

        appliers.append(apply_prefill)

    def _dispatch_prefill_slots(self, rows, arrays, appliers):
        for req, start, take, completes in rows:
            slot = self.slots.slot(req.rid)
            tokens, pos = _prefill_window(req, start, take)
            chunk = np.asarray(tokens, np.int32)[None]
            tok_dev, self.cache = self._prefill_slot(
                self.params, self.cache, jnp.asarray(chunk),
                jnp.full((1,), pos, jnp.int32),
                jnp.int32(slot), self._next_key())
            self.positions[slot] = pos + take
            arrays.append(tok_dev)

            def apply_row(toks, handle, eos, req=req, slot=slot,
                          completes=completes):
                if not completes:
                    return
                tok = int(toks[0])
                req.output_tokens.append(tok)
                self.last_token[slot] = tok
                self._register_donor(req, slot)
                if self.eos_id is not None and tok == self.eos_id:
                    eos[req.rid] = True

            appliers.append(apply_row)

    # ---- row-wise reference path (token-exact oracle) ----------------
    def _step_reference(self, plan) -> PendingStep:
        """The oracle keeps its simple one-call-per-row structure; it is
        wrapped lazily so ``step_async`` has a uniform surface (compute
        runs at resolve — there is nothing worth overlapping here)."""
        return PendingStep(
            self, (), lambda arrays, handle: self._execute_reference(plan))

    def _execute_reference(self, plan) -> Dict[int, bool]:
        eos: Dict[int, bool] = {}
        # --- chunked prefill (row-wise, exact shapes) ---
        for req, take in plan.prefill_items:
            slot = self.slots.slot(req.rid)
            tokens, pos = _prefill_window(req, req.prefill_pos, take)
            chunk = np.asarray(tokens, np.int32)[None]
            start = jnp.full((1,), pos, jnp.int32)
            last, row_cache = self._prefill_row(
                self.params, self._row_cache(slot), jnp.asarray(chunk),
                start, T=take)
            self._write_row_cache(slot, row_cache)
            self.positions[slot] = pos + take
            if take == req.prefill_remaining:
                # the sampled first token is NOT yet in the cache; it is
                # written when fed to the next decode step at position
                # == prompt_len (positions[slot] already points there).
                # Sampling happens on device — only the token id crosses.
                tok_dev = self._sample_batch(last, self._next_key())
                self.host_readbacks += 1
                self.host_syncs += 1
                tok = int(np.asarray(tok_dev)[0])
                req.output_tokens.append(tok)
                self.last_token[slot] = tok
                self._register_donor(req, slot)
                if self.eos_id is not None and tok == self.eos_id:
                    eos[req.rid] = True
        # --- decode (full slot batch, one call) ---
        if plan.decode_reqs:
            tokens = jnp.asarray(self.last_token[:, None])
            pos = jnp.asarray(self.positions)
            logits, self.cache = self._decode(self.params, self.cache,
                                              tokens, pos)
            toks = np.asarray(self._sample_batch(logits, self._next_key()))
            self.host_readbacks += 1
            self.host_syncs += 1
            active = [(r, self.slots.slot(r.rid)) for r in plan.decode_reqs]
            for req, slot in active:
                tok = int(toks[slot])
                req.output_tokens.append(tok)
                self.last_token[slot] = tok
                self.positions[slot] += 1
                if self.eos_id is not None and tok == self.eos_id:
                    eos[req.rid] = True
        return eos

    # ------------------------------------------------------------------
    def extract_state(self, req: Request):
        if self._pending is not None and not self._pending.resolved:
            # an eject mid-horizon would read post-horizon tensors
            # against pre-horizon host bookkeeping — the scheduler must
            # commit (flush) the in-flight iteration before migrating
            raise RuntimeError(
                f"extract_state({req.rid}): an async step is in flight; "
                "resolve it (commit the iteration) before ejecting")
        slot = self.slots.slot(req.rid)
        if self.paged:
            # ship only the blocks actually covering the written context
            # (growth headroom stays home)
            ctx = int(self.positions[slot])
            n = self.kv.blocks_for(max(ctx, 1))
            bids = self.kv.allocator.owned(req.rid)[:n]
            return {"paged_blocks": self.kv.extract_blocks(bids),
                    "n_blocks": len(bids), "pos": ctx,
                    "last_token": int(self.last_token[slot]),
                    "prompt_tokens": list(req.prompt_tokens or ()),
                    "kv_format": self.kv_quant or "fp"}
        row = migrate.extract_row(self.cache, slot)
        return {"row": row, "pos": int(self.positions[slot]),
                "last_token": int(self.last_token[slot])}

    def insert_state(self, req: Request, state):
        if self.paged:
            if not isinstance(state, dict) or "paged_blocks" not in state:
                raise MigrationFormatError(
                    f"request {req.rid}: migrated state is in 'dense' "
                    "row format but the destination executor is 'paged' "
                    "— dense<->paged cross-migration is unsupported; "
                    "migrate between like engines")
            return self._insert_state_paged(req, state)
        if not isinstance(state, dict) or "row" not in state:
            raise MigrationFormatError(
                f"request {req.rid}: migrated state is in 'paged' block "
                "format but the destination executor is 'dense' — "
                "dense<->paged cross-migration is unsupported; migrate "
                "between like engines")
        slot = self._acquire_slot(req.rid)
        self.cache = migrate.insert_row(self.cache, state["row"], slot)
        self.positions[slot] = state["pos"]
        self.last_token[slot] = state["last_token"]
        # re-acquired below by add_request semantics: mark as pre-added
        self._preadded.add(req.rid)
        # donor re-registration after migration-in: the landed row holds
        # valid KV for the full prompt — make it adoptable here too
        self._register_donor(req, slot)

    def _insert_state_paged(self, req: Request, state):
        """Land migrated blocks: alias whatever prefix the destination
        already caches (those shipped blocks are discarded), scatter
        only the non-shared suffix, and republish the prompt blocks to
        this instance's donor index.

        When the pool is memory-full the landing is DEFERRED instead of
        raising: the state is stashed and materialized by add_request
        once the instance's admission gate (can_allocate in
        _try_admit_pending) lets the request through — same graceful
        queueing as the dense path's allocation-at-admission contract."""
        fmt = state.get("kv_format", "fp")
        want = self.kv_quant or "fp"
        if fmt != want:
            # int8 blocks carry scale leaves fp pools don't have (and
            # vice versa) — a blind scatter would silently misinterpret
            # the payload; migrate between like-quantized engines
            raise MigrationFormatError(
                f"request {req.rid}: migrated KV is {fmt!r} but the "
                f"destination pool is {want!r} — cross-format "
                "migration is unsupported")
        prompt = req.prompt_tokens or state.get("prompt_tokens") or []
        shared_bids: list = []
        if self.prefix_cache_enabled and self.prefix_cache_obj and prompt:
            pc = self.prefix_cache_obj
            hit = min(pc.match_tokens(prompt),
                      (state["n_blocks"] - 1) * self.cache_block_size)
            if hit > 0:
                shared_bids = pc.matched_bids(prompt, hit)
        alloc = self.kv.allocator
        total = state["pos"] + self.HEADROOM
        if not alloc.can_allocate(total, shared_bids):
            self._deferred_states[req.rid] = state
            return
        slot = self._acquire_slot(req.rid)
        alloc.allocate(req.rid, total, shared=shared_bids)
        self._land_blocks(req, state, slot, len(shared_bids))
        self._preadded.add(req.rid)

    def _land_blocks(self, req: Request, state, slot: int,
                     skip_blocks: int = 0):
        self.kv.refresh_row(slot, req.rid)
        self.kv.insert_blocks(
            self.kv.allocator.owned(req.rid)[:state["n_blocks"]],
            state["paged_blocks"], skip_blocks=skip_blocks)
        self.positions[slot] = state["pos"]
        self.last_token[slot] = state["last_token"]
        # donor re-registration after migration-in (open ROADMAP item):
        # republish the full prompt blocks so the migrated context is
        # adoptable on this instance
        self._register_donor(req, slot)

    def export_request_blocks(self, req: Request, indices):
        """Host copies of the blocks at the given indices of ``req``'s
        owned block run (warm-recovery checkpoint materialization).
        Side-effect free — no refcounts, no LRU touches, no slot state;
        each payload carries its quantization format so the restore
        path can refuse a mismatched destination pool.  None when this
        executor cannot export (dense path, request not held, or an
        async step still in flight — mid-flight tensors are torn)."""
        if not self.paged or not self.kv.allocator.holds(req.rid):
            return None
        if self._pending is not None and not self._pending.resolved:
            return None
        bids = self.kv.allocator.owned(req.rid)
        fmt = self.kv_quant or "fp"
        out = {}
        for i in indices:
            if 0 <= i < len(bids):
                out[i] = {"fmt": fmt, "kv": jax.tree.map(
                    np.asarray, self.kv.extract_blocks([bids[i]]))}
        return out

    # ------------------------------------------------------------------
    # hot-prefix replication (block-granular, no request attached)
    # ------------------------------------------------------------------
    def export_prefix_blocks(self, tokens: Sequence[int]):
        """Gather the cached pool blocks covering the longest resident
        full-block prefix of ``tokens``, for replication to a peer
        instance.  Side-effect free (no refcounts, no LRU touch) and
        deliberately NOT capped like match_tokens: a hot path's last
        full block is worth shipping even when a future request would
        still owe one prefill token."""
        pc = self.prefix_cache_obj
        if not self.paged or pc is None:
            return None
        n = len(tokens) // self.cache_block_size
        path = pc.tree.match(tokens, n, touch=False)
        if not path:
            return None
        bids = [nd.bid for nd in path]
        return {"paged_blocks": self.kv.extract_blocks(bids),
                "n_blocks": len(bids),
                "tokens": list(tokens[:len(bids) * self.cache_block_size]),
                "kv_format": self.kv_quant or "fp"}

    def import_prefix_blocks(self, state) -> int:
        """Land replicated prefix blocks into this pool and publish them
        to the donor tree.  Returns blocks newly admitted (0 when the
        prefix is already resident, nothing fit below the free
        watermark, or the payload carries no tensors — a bookkeeping-
        only payload must never alias garbage pool contents)."""
        pc = self.prefix_cache_obj
        if not self.paged or pc is None:
            return 0
        fmt = state.get("kv_format", "fp")
        want = self.kv_quant or "fp"
        if fmt != want:
            raise MigrationFormatError(
                f"replicated KV is {fmt!r} but the destination pool is "
                f"{want!r} — cross-format replication is unsupported")
        if state.get("paged_blocks") is None:
            return 0
        res = pc.admit_replica(state["tokens"], state["n_blocks"])
        if res is None:
            return 0
        skip, bids = res
        self.kv.insert_blocks(bids, state["paged_blocks"],
                              skip_blocks=skip)
        return len(bids) - skip

    def migration_bytes(self, req: Request) -> int:
        slot = self.slots.slot(req.rid)
        if self.paged:
            n = self.kv.blocks_for(max(int(self.positions[slot]), 1))
            return n * self.cache_block_size * self.kv.token_bytes()
        return migrate.row_bytes(migrate.extract_row(self.cache, slot))


class SimExecutor:
    """Token oracle for the event-driven simulator: no tensors, no
    compute.  EOS arrives when the request's hidden output length is
    reached (the instance observes it only as done())."""

    #: the simulator models the paper system, where migrations ship only
    #: the non-shared suffix when the destination caches the prefix
    prefix_aware_transfer = True

    #: no tensors exist: a warm restore needs only the allocator/slot
    #: bookkeeping (the Instance may resume a request at a checkpointed
    #: position without landing KV — on a real engine that would decode
    #: garbage, so the restore path gates on this attribute)
    bookkeeping_only = True

    def execute(self, plan) -> Dict[int, bool]:
        return {}

    def step_async(self, plan) -> ImmediateStep:
        """Nothing computes, so nothing is ever in flight — but exposing
        the async surface lets the serving loop run its dispatch/commit
        pipeline (and the horizon timing model) deterministically in
        simulation."""
        return ImmediateStep()

    def add_request(self, req: Request):
        pass

    def claim_prefix(self, req: Request, max_tokens: int) -> int:
        """No physical rows to gather — the instance-level block cache
        is the full model of HBM retention in simulation."""
        return max_tokens

    def release(self, req: Request):
        pass

    def extract_state(self, req: Request):
        return None

    def insert_state(self, req: Request, state):
        pass
