"""Packing + bucketing for the batched prefill executor.

All prefill chunks of an iteration are packed into one padded
``[B, T_bucket]`` batch with per-row start positions, valid lengths, and
cache-slot indices.  Both axes are *bucketed* to a small set of sizes so
the number of jit compile variants stays bounded:

  * ``T`` is rounded up to the smallest configured token bucket (powers
    of two by default) — per-row valid lengths mask the padding;
  * ``B`` is rounded up to the next power of two — padding rows carry an
    out-of-range slot index so their cache scatter is dropped on-device.

Worst-case compile variants = ``len(t_buckets) * log2(max_batch)``, vs.
one variant per distinct (chunk length x batch size) pair without
bucketing.  Bigger buckets waste compute on padding; smaller buckets
compile more variants — the knob is ``JaxExecutor(t_buckets=...)``.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np


def default_t_buckets(max_seq: int, smallest: int = 16) -> Tuple[int, ...]:
    """Powers of two from ``smallest`` up to (and including) max_seq."""
    buckets = []
    b = smallest
    while b < max_seq:
        buckets.append(b)
        b *= 2
    buckets.append(max_seq)
    return tuple(buckets)


def bucket(n: int, buckets: Sequence[int]) -> int:
    """Smallest bucket >= n (next power of two beyond the largest)."""
    for b in buckets:
        if n <= b:
            return b
    b = max(buckets)
    while b < n:
        b *= 2
    return b


def bucket_batch(n: int) -> int:
    """Next power of two >= n."""
    b = 1
    while b < n:
        b *= 2
    return b


@dataclasses.dataclass
class PackedPrefill:
    """Host-side arrays for one packed prefill call.

    ``slots`` rows beyond the real batch hold ``n_slots`` (out of range):
    their on-device cache scatter drops, their gather clamps harmlessly.
    """
    tokens: np.ndarray      # [B, T] int32, zero-padded
    start: np.ndarray       # [B] int32 absolute start position per row
    valid: np.ndarray       # [B] int32 valid token count per row (0 = pad row)
    slots: np.ndarray       # [B] int32 cache row per request (or n_slots)


@dataclasses.dataclass
class PackedMixed:
    """Host-side arrays for one fused mixed prefill+decode call over the
    paged cache.  Decode rows are just chunks of length 1 (their token
    is the last sampled token, their start the row's position); padding
    rows have ``valid == 0`` and an all ``-1`` table (every KV write is
    dropped on-device)."""
    tokens: np.ndarray      # [B, T] int32, zero-padded
    start: np.ndarray       # [B] int32 absolute start position per row
    valid: np.ndarray       # [B] int32 valid token count per row (0 = pad)
    tables: np.ndarray      # [B, NB] int32 block tables (-1 = unallocated)


def pack_mixed(chunks, starts: Sequence[int], table_rows,
               t_buckets: Sequence[int], max_blocks: int,
               block_size: int) -> PackedMixed:
    """Pack mixed prefill chunks + decode steps into one bucketed batch.

    ``table_rows[i]`` is row i's full block table (np int32, -1 filled).
    All three batch axes are bucketed: T to the configured token
    buckets, B to the next power of two, and the table width NB to the
    smallest power of two covering every row's read frontier
    ``ceil((start + len) / block_size)`` (capped at ``max_blocks``) —
    so decode-heavy iterations over short contexts attend over far
    fewer kv columns than ``max_seq``.
    """
    B = bucket_batch(len(chunks))
    longest = max(len(c) for c in chunks)
    # decode-only iterations are the steady-state hot path: keep them at
    # T == 1 instead of padding to the smallest prefill bucket
    T = 1 if longest == 1 else bucket(longest, t_buckets)
    need = max(-(-(st + len(c)) // block_size)
               for c, st in zip(chunks, starts))
    NB = min(bucket_batch(max(need, 1)), max_blocks)
    if NB < need:
        raise ValueError(f"row needs {need} blocks, table holds {NB}")
    tokens = np.zeros((B, T), np.int32)
    start = np.zeros(B, np.int32)
    valid = np.zeros(B, np.int32)
    tables = np.full((B, NB), -1, np.int32)
    for i, (toks, st, row) in enumerate(zip(chunks, starts, table_rows)):
        take = len(toks)
        tokens[i, :take] = toks
        start[i] = st
        valid[i] = take
        tables[i] = row[:NB]
    return PackedMixed(tokens, start, valid, tables)


@dataclasses.dataclass
class PackedDecode:
    """Host-side arrays for one K-step fused decode-horizon call over the
    paged cache.  One row per scheduled decode request; padding rows have
    ``budget == 0`` and an all ``-1`` table, so the on-device done-mask
    freezes them at step 0 and every KV write they would make drops."""
    tokens: np.ndarray      # [B] int32 last sampled token per row
    start: np.ndarray       # [B] int32 current cache position per row
    budget: np.ndarray      # [B] int32 tokens this row may emit (0 = pad)
    tables: np.ndarray      # [B, NB] int32 block tables (-1 = unallocated)


def pack_decode(last_tokens: Sequence[int], positions: Sequence[int],
                budgets: Sequence[int], table_rows,
                max_blocks: int, block_size: int) -> PackedDecode:
    """Pack a decode-only horizon batch.  ``B`` buckets to the next power
    of two and the table width ``NB`` to the smallest power of two
    covering every row's end-of-horizon frontier ``ceil((pos + budget) /
    block_size)`` (capped at ``max_blocks`` — positions clamp on-device
    past ``max_seq``, so the cap is never short)."""
    B = bucket_batch(len(last_tokens))
    need = max(-(-(p + b) // block_size)
               for p, b in zip(positions, budgets))
    NB = min(bucket_batch(max(need, 1)), max_blocks)
    tokens = np.zeros(B, np.int32)
    start = np.zeros(B, np.int32)
    budget = np.zeros(B, np.int32)
    tables = np.full((B, NB), -1, np.int32)
    for i, (tok, p, b, row) in enumerate(
            zip(last_tokens, positions, budgets, table_rows)):
        tokens[i] = tok
        start[i] = p
        budget[i] = b
        tables[i] = row[:NB]
    return PackedDecode(tokens, start, budget, tables)


def pack_prefill(chunks, starts: Sequence[int], row_slots: Sequence[int],
                 n_slots: int, t_buckets: Sequence[int]) -> PackedPrefill:
    """Pack per-request prefill chunks (``chunks[i]`` = token list starting
    at absolute position ``starts[i]``, cache row ``row_slots[i]``) into
    one bucketed batch."""
    B = bucket_batch(len(chunks))
    T = bucket(max(len(c) for c in chunks), t_buckets)
    tokens = np.zeros((B, T), np.int32)
    start = np.zeros(B, np.int32)
    valid = np.zeros(B, np.int32)
    slots = np.full(B, n_slots, np.int32)
    for i, (toks, st, sl) in enumerate(zip(chunks, starts, row_slots)):
        take = len(toks)
        tokens[i, :take] = toks
        start[i] = st
        valid[i] = take
        slots[i] = sl
    return PackedPrefill(tokens, start, valid, slots)
