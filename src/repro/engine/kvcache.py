"""Paged KV-cache block allocator (bookkeeping) + slot management.

The allocator tracks HBM occupancy in fixed-size token blocks per request
— this is what drives the memory-watermark decisions of flowing decode
scheduling (Algorithm 1's ``M``).  Invariants (property-tested):

  * a block is owned by at most one request;
  * free + used == total, always;
  * freeing a request returns exactly the blocks it held;
  * utilization() is monotone in the set of live requests' context lens.

The actual tensor cache in the JAX engine is slot-contiguous (slot index
== batch row, position == cache column): the allocator decides
*admission* and *eviction/migration*, the tensors follow.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional


class OutOfBlocks(Exception):
    pass


@dataclasses.dataclass
class BlockAllocator:
    num_blocks: int
    block_size: int = 16

    def __post_init__(self):
        self._owned: Dict[int, int] = {}      # rid -> blocks held
        self._free = self.num_blocks

    # ------------------------------------------------------------------
    def blocks_for(self, tokens: int) -> int:
        return -(-max(tokens, 1) // self.block_size)

    @property
    def free_blocks(self) -> int:
        return self._free

    @property
    def used_blocks(self) -> int:
        return self.num_blocks - self._free

    def utilization(self) -> float:
        return self.used_blocks / self.num_blocks

    def holds(self, rid: int) -> bool:
        return rid in self._owned

    # ------------------------------------------------------------------
    def allocate(self, rid: int, tokens: int):
        """Reserve blocks for a request's current context."""
        need = self.blocks_for(tokens)
        if rid in self._owned:
            raise ValueError(f"rid {rid} already allocated")
        if need > self._free:
            raise OutOfBlocks(f"need {need}, free {self._free}")
        self._owned[rid] = need
        self._free -= need

    def can_allocate(self, tokens: int) -> bool:
        return self.blocks_for(tokens) <= self._free

    def extend(self, rid: int, tokens: int):
        """Grow a request's reservation to cover ``tokens`` total context."""
        need = self.blocks_for(tokens)
        have = self._owned.get(rid)
        if have is None:
            raise KeyError(rid)
        if need <= have:
            return
        extra = need - have
        if extra > self._free:
            raise OutOfBlocks(f"extend needs {extra}, free {self._free}")
        self._owned[rid] = need
        self._free -= extra

    def can_extend(self, rid: int, tokens: int) -> bool:
        need = self.blocks_for(tokens) - self._owned.get(rid, 0)
        return need <= self._free

    def free(self, rid: int) -> int:
        held = self._owned.pop(rid, 0)
        self._free += held
        return held

    def bytes_owned(self, rid: int, bytes_per_token: int) -> int:
        return self._owned.get(rid, 0) * self.block_size * bytes_per_token


@dataclasses.dataclass
class SlotTable:
    """Batch-row slots of the tensor cache: rid <-> row index."""
    n_slots: int

    def __post_init__(self):
        self._slot_of: Dict[int, int] = {}
        self._free = list(range(self.n_slots - 1, -1, -1))

    def acquire(self, rid: int, avoid=()) -> int:
        """Pop a free slot; prefer one not in ``avoid`` (the engine's
        retained prefix-donor slots) so cached rows survive longest."""
        if not self._free:
            raise OutOfBlocks("no free slots")
        s = None
        if avoid:
            for i in range(len(self._free) - 1, -1, -1):
                if self._free[i] not in avoid:
                    s = self._free.pop(i)
                    break
        if s is None:
            s = self._free.pop()
        self._slot_of[rid] = s
        return s

    def acquire_slot(self, rid: int, slot: int) -> int:
        """Claim a SPECIFIC free slot (prefix-donor adoption)."""
        self._free.remove(slot)
        self._slot_of[rid] = slot
        return slot

    def release(self, rid: int) -> Optional[int]:
        s = self._slot_of.pop(rid, None)
        if s is not None:
            self._free.append(s)
        return s

    def slot(self, rid: int) -> int:
        return self._slot_of[rid]

    def slots_of(self, rids) -> List[int]:
        """Batch lookup for packed execution (one row per request)."""
        return [self._slot_of[r] for r in rids]

    def has(self, rid: int) -> bool:
        return rid in self._slot_of

    def is_free(self, slot: int) -> bool:
        return slot in self._free

    @property
    def free_slots(self) -> int:
        return len(self._free)
