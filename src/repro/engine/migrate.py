"""KV/state migration between instances — the substrate of flowing decode
scheduling.

Cache pytrees are segment-stacked: every leaf has layout
``[n_periods, B, ...]`` (batch is axis 1).  A migration extracts one batch
row across all leaves, ships it (in production: ICI point-to-point,
modeled by ``CostModel.transfer_time``), and inserts it into a free slot
of the destination instance's cache.

The row ops are jitted with the slot as a *traced* scalar so each
operation compiles once per cache structure (not once per slot) and runs
as a single device executable instead of one dispatch per leaf.

The paper implements this as many-to-many NCCL transfers decoupled from
the critical path (§3.5); here the copy is an array op and the *time* is
charged by the estimator, keeping the scheduling semantics identical.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


@jax.jit
def _extract(segments, slot):
    return jax.tree.map(
        lambda a: jax.lax.dynamic_index_in_dim(a, slot, 1, keepdims=False),
        segments)


@jax.jit
def _insert(segments, row, slot):
    return jax.tree.map(
        lambda a, r: jax.lax.dynamic_update_index_in_dim(
            a, r.astype(a.dtype), slot, 1), segments, row)


@jax.jit
def _copy_prefix(segments, src, dst, n):
    """Copy the first ``n`` cache columns of one batch row into another
    (prefix-cache hit: the new request's slot inherits the donor's KV up
    to the matched position).  Masked full-row copy so ``n`` stays a
    traced scalar — one compile per cache structure, same dynamic
    index/update ops as the migration row path."""
    def cp(a):
        srow = jax.lax.dynamic_index_in_dim(a, src, 1, keepdims=False)
        drow = jax.lax.dynamic_index_in_dim(a, dst, 1, keepdims=False)
        # row layout [n_periods, seq, ...]: mask along the seq axis
        seq = srow.shape[1]
        mask = (jnp.arange(seq) < n).reshape(
            (1, seq) + (1,) * (srow.ndim - 2))
        out = jnp.where(mask, srow, drow)
        return jax.lax.dynamic_update_index_in_dim(
            a, out.astype(a.dtype), dst, 1)
    return jax.tree.map(cp, segments)


@jax.jit
def _zero(segments, slot):
    return jax.tree.map(
        lambda a: jax.lax.dynamic_update_index_in_dim(
            a, jnp.zeros_like(jax.lax.index_in_dim(a, 0, 1, keepdims=False)),
            slot, 1), segments)


def extract_row(cache, slot: int):
    """Copy one request's state out of a cache pytree (batch axis 1)."""
    return _extract(cache["segments"], jnp.int32(slot))


def insert_row(cache, row, slot: int):
    """Insert an extracted row into a cache at ``slot``; returns new cache."""
    return {"segments": _insert(cache["segments"], row, jnp.int32(slot))}


def copy_prefix(cache, src_slot: int, dst_slot: int, n_tokens: int):
    """Gather the first ``n_tokens`` KV columns of ``src_slot`` into
    ``dst_slot``.  Only valid for full-cache attention families (KV at
    position p depends only on tokens [0, p] — recurrent/windowed state
    cannot be sliced at a token boundary)."""
    return {"segments": _copy_prefix(cache["segments"], jnp.int32(src_slot),
                                     jnp.int32(dst_slot),
                                     jnp.int32(n_tokens))}


def zero_row(cache, slot: int):
    """Reset one slot's state (recurrent SSM/conv state must not leak
    between requests; KV is masked by position so zeroing is belt-and-
    braces)."""
    return {"segments": _zero(cache["segments"], jnp.int32(slot))}


def row_bytes(row) -> int:
    return sum(a.size * a.dtype.itemsize for a in jax.tree.leaves(row))
