"""KV/state migration between instances — the substrate of flowing decode
scheduling.

Cache pytrees are segment-stacked: every leaf has layout
``[n_periods, B, ...]`` (batch is axis 1).  A migration extracts one batch
row across all leaves, ships it (in production: ICI point-to-point,
modeled by ``CostModel.transfer_time``), and inserts it into a free slot
of the destination instance's cache.

The paper implements this as many-to-many NCCL transfers decoupled from
the critical path (§3.5); here the copy is an array op and the *time* is
charged by the estimator, keeping the scheduling semantics identical.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def extract_row(cache, slot: int):
    """Copy one request's state out of a cache pytree (batch axis 1)."""
    return jax.tree.map(lambda a: a[:, slot], cache["segments"])


def insert_row(cache, row, slot: int):
    """Insert an extracted row into a cache at ``slot``; returns new cache."""
    new_segments = jax.tree.map(
        lambda a, r: a.at[:, slot].set(r), cache["segments"], row)
    return {"segments": new_segments}


def zero_row(cache, slot: int):
    """Reset one slot's state (recurrent SSM/conv state must not leak
    between requests; KV is masked by position so zeroing is belt-and-
    braces)."""
    new_segments = jax.tree.map(
        lambda a: a.at[:, slot].set(jnp.zeros_like(a[:, slot])),
        cache["segments"])
    return {"segments": new_segments}


def row_bytes(row) -> int:
    return sum(a.size * a.dtype.itemsize for a in jax.tree.leaves(row))
