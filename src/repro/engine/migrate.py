"""KV/state migration between instances — the substrate of flowing decode
scheduling.

Cache pytrees are segment-stacked: every leaf has layout
``[n_periods, B, ...]`` (batch is axis 1).  A migration extracts one batch
row across all leaves, ships it (in production: ICI point-to-point,
modeled by ``CostModel.transfer_time``), and inserts it into a free slot
of the destination instance's cache.

The row ops are jitted with the slot as a *traced* scalar so each
operation compiles once per cache structure (not once per slot) and runs
as a single device executable instead of one dispatch per leaf.

The paper implements this as many-to-many NCCL transfers decoupled from
the critical path (§3.5); here the copy is an array op and the *time* is
charged by the estimator, keeping the scheduling semantics identical.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


@jax.jit
def _extract(segments, slot):
    return jax.tree.map(
        lambda a: jax.lax.dynamic_index_in_dim(a, slot, 1, keepdims=False),
        segments)


@jax.jit
def _insert(segments, row, slot):
    return jax.tree.map(
        lambda a, r: jax.lax.dynamic_update_index_in_dim(
            a, r.astype(a.dtype), slot, 1), segments, row)


@jax.jit
def _zero(segments, slot):
    return jax.tree.map(
        lambda a: jax.lax.dynamic_update_index_in_dim(
            a, jnp.zeros_like(jax.lax.index_in_dim(a, 0, 1, keepdims=False)),
            slot, 1), segments)


def extract_row(cache, slot: int):
    """Copy one request's state out of a cache pytree (batch axis 1)."""
    return _extract(cache["segments"], jnp.int32(slot))


def insert_row(cache, row, slot: int):
    """Insert an extracted row into a cache at ``slot``; returns new cache."""
    return {"segments": _insert(cache["segments"], row, jnp.int32(slot))}


def zero_row(cache, slot: int):
    """Reset one slot's state (recurrent SSM/conv state must not leak
    between requests; KV is masked by position so zeroing is belt-and-
    braces)."""
    return {"segments": _zero(cache["segments"], jnp.int32(slot))}


def row_bytes(row) -> int:
    return sum(a.size * a.dtype.itemsize for a in jax.tree.leaves(row))
