"""Physical paged KV cache: the tensors behind the block bookkeeping.

``SharedBlockAllocator`` (cache/shared_allocator.py) hands out abstract
block ids with ref-counted sharing, CoW, and LRU retention.  This module
makes those ids PHYSICAL: block id ``b`` owns token slots
``[b*block_size, (b+1)*block_size)`` of a flat pool tensor per attention
layer (``transformer.init_paged_cache``), and each resident request's
batch row carries an int32 block table mapping logical block index ->
block id.  Prefix reuse, migration, and admission all become block-table
pointer updates:

  * a prefix-cache hit takes *references* on the matched blocks — the
    new row's table simply aliases them (no tensor gather);
  * migration ships only the blocks a request owns, and the destination
    aliases whatever prefix blocks it already caches;
  * HBM admission is bounded by blocks actually referenced, not by
    ``n_slots x max_seq`` worth of reserved rows.

The pool is device memory; tables live host-side as numpy (they are
per-iteration jit inputs, bucketed by ``batching.pack_mixed``).
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.cache.shared_allocator import SharedBlockAllocator
from repro.models import transformer as tf


class PagedKVCache:
    def __init__(self, cfg, n_slots: int, max_seq: int, num_blocks: int,
                 block_size: int, dtype=None,
                 allocator: Optional[SharedBlockAllocator] = None,
                 quant: Optional[str] = None):
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.block_size = block_size
        self.dtype = dtype
        self.quant = quant
        # table width: blocks addressable by in-range positions.  The
        # allocator may hold MORE blocks for a request (growth headroom
        # beyond max_seq is never read or written) — tables truncate.
        self.max_blocks = -(-max_seq // block_size)
        self.allocator = allocator or SharedBlockAllocator(
            num_blocks, block_size)
        if self.allocator.block_size != block_size:
            raise ValueError("allocator/pool block_size mismatch")
        self.num_blocks = self.allocator.num_blocks
        self.pool = tf.init_paged_cache(cfg, self.num_blocks, block_size,
                                        dtype, quant=quant)
        self.tables = np.full((n_slots, self.max_blocks), -1, np.int32)
        self._fill = np.zeros(n_slots, np.int32)   # valid entries per row

    # ------------------------------------------------------------------
    # bookkeeping <-> tensors
    # ------------------------------------------------------------------
    def rebind_allocator(self, allocator: SharedBlockAllocator):
        """Adopt an externally owned allocator (the instance's prefix
        cache): its block ids become the pool's physical indices.  Must
        happen before any KV is written (the pool is rebuilt when the
        block count differs)."""
        if allocator is self.allocator:
            return
        if allocator.block_size != self.block_size:
            raise ValueError("allocator/pool block_size mismatch")
        self.allocator = allocator
        if allocator.num_blocks != self.num_blocks:
            self.num_blocks = allocator.num_blocks
            self.pool = tf.init_paged_cache(self.cfg, self.num_blocks,
                                            self.block_size, self.dtype,
                                            quant=self.quant)
        self.tables.fill(-1)
        self._fill.fill(0)

    def blocks_for(self, tokens: int) -> int:
        return self.allocator.blocks_for(tokens)

    def ensure(self, rid: int, tokens: int):
        """Executor-owned bookkeeping growth: reserve blocks so ``rid``
        can hold ``tokens`` total context (no-op when already covered)."""
        if not self.allocator.holds(rid):
            self.allocator.allocate(rid, tokens)
        else:
            self.allocator.extend(rid, tokens)

    def refresh_row(self, slot: int, rid: int):
        """Rebuild a slot's block table from the allocator's ordered
        owned-block list (logical block i == i-th owned block)."""
        self.tables[slot].fill(-1)
        bids = self.allocator.owned(rid)[: self.max_blocks]
        if bids:
            self.tables[slot, : len(bids)] = bids
        self._fill[slot] = len(bids)

    def refresh_row_if_grown(self, slot: int, rid: int):
        """Decode steady-state fast path: a live request's owned list is
        append-only (the engine never CoW-forks blocks it holds — writes
        only ever target exclusively owned tail blocks), so the table is
        stale only when the owned COUNT changed since the last refresh
        — once per block_size tokens, not per step."""
        n = min(self.allocator.owned_count(rid), self.max_blocks)
        if n != self._fill[slot]:
            self.refresh_row(slot, rid)

    def grow_for(self, slot: int, rid: int, tokens: int,
                 external_bookkeeping: bool):
        """Dispatch-time growth: make sure ``rid`` owns blocks covering
        ``tokens`` total context (skipped under external bookkeeping,
        where the Instance already extended the shared allocator) and
        bring the slot's table up to date.  Returns the table row — for
        a decode horizon, ``tokens`` is the END-of-horizon frontier, so
        the fused loop's write pointer can advance through the table
        without host round trips."""
        if not external_bookkeeping:
            self.ensure(rid, tokens)
        self.refresh_row_if_grown(slot, rid)
        return self.tables[slot]

    def clear_row(self, slot: int):
        self.tables[slot].fill(-1)
        self._fill[slot] = 0

    def row_bids(self, slot: int) -> List[int]:
        return [int(b) for b in self.tables[slot] if b >= 0]

    # ------------------------------------------------------------------
    # migration: ship / land owned blocks
    # ------------------------------------------------------------------
    def extract_blocks(self, bids: Sequence[int]):
        """Gather whole blocks out of the pool: leaves
        [n_periods, len(bids)*bs, Hkv, Dh] in logical order."""
        idx = (np.asarray(bids, np.int32)[:, None] * self.block_size
               + np.arange(self.block_size, dtype=np.int32)).reshape(-1)
        idxj = jnp.asarray(idx)
        return jax.tree.map(lambda a: a[:, idxj], self.pool["segments"])

    def insert_blocks(self, bids: Sequence[int], blocks,
                      skip_blocks: int = 0):
        """Scatter shipped blocks into this pool at ``bids`` (logical
        order).  The first ``skip_blocks`` are skipped — the destination
        already caches them and the table aliases its own copies."""
        take = bids[skip_blocks:]
        if not take:
            return
        idx = (np.asarray(take, np.int32)[:, None] * self.block_size
               + np.arange(self.block_size, dtype=np.int32)).reshape(-1)
        idxj = jnp.asarray(idx)
        off = skip_blocks * self.block_size
        self.pool = {"segments": jax.tree.map(
            lambda a, b: a.at[:, idxj].set(
                b[:, off:off + len(take) * self.block_size].astype(a.dtype)),
            self.pool["segments"], blocks)}

    # ------------------------------------------------------------------
    def token_bytes(self) -> int:
        """KV bytes per cached token, summed over layers."""
        total = 0
        for a in jax.tree.leaves(self.pool["segments"]):
            n_periods, P = a.shape[0], a.shape[1]
            total += (a.size // P) * a.dtype.itemsize
        return total

    def pool_bytes(self) -> int:
        return sum(a.size * a.dtype.itemsize
                   for a in jax.tree.leaves(self.pool["segments"]))

    def effective_capacity_ratio(self) -> float:
        """Resident tokens per HBM byte relative to the unquantized pool
        (1.0 when quantization is off): the factor by which a fixed byte
        budget buys more blocks under the int8 tier."""
        if self.quant is None:
            return 1.0
        ref = PagedKVCache.token_bytes_for(self.cfg, self.dtype)
        return ref / self.token_bytes()

    @staticmethod
    def token_bytes_for(cfg, dtype=None, quant: Optional[str] = None) -> int:
        """KV bytes per cached token for a config without materializing
        a pool (sizing block budgets in benchmarks)."""
        probe = tf.init_paged_cache(cfg, 1, 1, dtype, quant=quant)
        return sum(a.size * a.dtype.itemsize
                   for a in jax.tree.leaves(probe["segments"]))

    # ------------------------------------------------------------------
    # invariants (exercised by property tests)
    # ------------------------------------------------------------------
    def check_invariants(self):
        """A block referenced by k live table rows must carry at least k
        request references (every live row's rid holds one per owned
        block — a table must never outlive its blocks), and the
        allocator's conservation law holds."""
        a = self.allocator
        assert a.free_blocks + a.cached_blocks + a.used_blocks \
            == a.num_blocks
        counts: dict = {}
        for slot in range(self.n_slots):
            for b in self.row_bids(slot):
                counts[b] = counts.get(b, 0) + 1
        for b, n in counts.items():
            assert a.refcount(b) >= n, (b, n, a.refcount(b))
