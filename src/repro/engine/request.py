"""Request lifecycle for the serving engine and simulator.

A request moves through:  QUEUED -> PREFILL -> DECODE -> FINISHED,
possibly migrating between instances during DECODE (flowing decode
scheduling) and having its prefill and decode on *different* instances
(disaggregated request handling — hybrid mode's key freedom).

Latency accounting follows the paper (§2.1 / vLLM measurement):
  TTFT  = first-token time - arrival (includes queueing, prefill
          execution, and any decode-queue wait before the first decode).
  TPOT  = (last_token_time - first_token_time) / (n_output - 1),
          i.e. mean per-token latency excluding the first token.
"""
from __future__ import annotations

import dataclasses
import enum
import itertools
from typing import List, Optional

_rid_counter = itertools.count()


class State(enum.Enum):
    QUEUED = "queued"
    PREFILL = "prefill"
    DECODE = "decode"
    MIGRATING = "migrating"
    FINISHED = "finished"
    REJECTED = "rejected"      # early rejection (proxy, Mooncake-style)
    CANCELLED = "cancelled"    # shed from the admission queue, aborted by
                               # the client, or still queued when a
                               # graceful drain began
    FAILED = "failed"          # unrecoverable fault (instance crash under
                               # fail-stop, transfer retries exhausted,
                               # crash-recovery loop bound hit)


#: states a request never leaves — every submitted request must reach one
TERMINAL_STATES = (State.FINISHED, State.REJECTED, State.CANCELLED,
                   State.FAILED)


@dataclasses.dataclass
class Request:
    prompt_len: int
    max_new_tokens: int
    arrival: float = 0.0
    rid: int = dataclasses.field(default_factory=lambda: next(_rid_counter))
    # hidden ground truth for the simulator (the SCHEDULER must never read
    # this — output length is unknown a priori; paper Challenge 2):
    hidden_output_len: Optional[int] = None
    prompt_tokens: Optional[list] = None      # real token ids (engine, or
                                              # tokenized sim workloads)
    # ground truth from the workload generator: how many leading tokens
    # were emitted before in the same session/system-prompt group (the
    # scheduler must never read this — it's for measuring prefix share):
    shared_prefix_len: Optional[int] = None
    # admission-queue priority class (router-side; None = default class)
    priority: Optional[str] = None

    state: State = State.QUEUED
    prefill_pos: int = 0                      # prompt tokens processed
    cached_prefix_len: int = 0                # tokens served from KV cache
    # preemption-by-recompute: output length at the last preemption.  The
    # re-prefill token stream is prompt + the first ``recompute_offset``
    # output tokens, and the true cache position of a re-prefill chunk is
    # ``prefill_pos + recompute_offset`` (prefill_pos restarts negative so
    # the existing accounting — prefill_remaining, context_len — holds).
    recompute_offset: int = 0
    output_len: int = 0                       # tokens emitted so far
    output_tokens: List[int] = dataclasses.field(default_factory=list)

    # timing
    first_token_time: Optional[float] = None
    last_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    prefill_instance: Optional[int] = None
    decode_instance: Optional[int] = None
    n_migrations: int = 0
    # flowing-decode bookkeeping: output length at the last backflow —
    # TPOT of a flowed-back request is "reset" (paper §3.3 step 3)
    tpot_reset_len: int = 0
    tpot_reset_time: Optional[float] = None
    # prefill tokens co-batched during this request's decode iterations
    # (numerator of "interference intensity", paper §2.3.1)
    interference_tokens: int = 0
    # terminal outcome detail: "stop" (EOS) / "length" for FINISHED,
    # "abort" for client-cancelled, a failure reason for FAILED
    finish_reason: Optional[str] = None
    # fault recovery: times this request was evacuated off a failed /
    # quarantined instance (or lost a transfer) and re-prefilled; bounded
    # by FaultToleranceConfig.max_recoveries
    n_recoveries: int = 0
    # warm recovery: a restore plan from the RecoveryManager's latest
    # checkpoint ({"pos": stream position, "engine": optional
    # migration-format state}).  Consumed (and cleared) by the admitting
    # instance; None = ordinary cold recompute-from-0 path.
    restore_state: Optional[dict] = None

    # ----------------------------------------------------------------
    @property
    def target_output_len(self) -> int:
        if self.hidden_output_len is not None:
            return min(self.hidden_output_len, self.max_new_tokens)
        return self.max_new_tokens

    @property
    def prefill_remaining(self) -> int:
        return self.prompt_len - self.prefill_pos

    @property
    def context_len(self) -> int:
        return self.prefill_pos + self.output_len

    def record_token(self, now: float):
        self.output_len += 1
        if self.first_token_time is None:
            self.first_token_time = now
            self.tpot_reset_time = now
        self.last_token_time = now

    def ttft(self) -> Optional[float]:
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.arrival

    def tpot(self) -> Optional[float]:
        """Mean per-output-token latency, excluding the first token."""
        if self.first_token_time is None or self.output_len <= 1:
            return None
        return ((self.last_token_time - self.first_token_time)
                / (self.output_len - 1))

    def current_tpot(self, now: float) -> Optional[float]:
        """TPOT *since the last backflow reset* — Algorithm 1 monitors this
        to decide flow-back (paper: 'logically treated as a new request,
        with its output length reset')."""
        n = self.output_len - self.tpot_reset_len
        if self.tpot_reset_time is None or n <= 1:
            return None
        return (self.last_token_time - self.tpot_reset_time) / (n - 1)

    def reset_tpot_window(self):
        self.tpot_reset_len = self.output_len
        self.tpot_reset_time = self.last_token_time

    def done(self) -> bool:
        return self.output_len >= self.target_output_len

    @property
    def remaining_output(self) -> int:
        """Tokens this request may still emit — the cap on its per-row
        decode-horizon budget (a K-step loop must stop exactly where
        the K=1 schedule would)."""
        return max(self.target_output_len - self.output_len, 0)

    @property
    def effective_output_len(self) -> int:
        """Output length since the last backflow reset — what longest-first
        degradation ranks on (a flowed-back request counts as 'new')."""
        return self.output_len - self.tpot_reset_len

    def interference_intensity(self) -> Optional[float]:
        if self.output_len == 0:
            return None
        return self.interference_tokens / self.output_len
