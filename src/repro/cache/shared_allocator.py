"""Ref-counted, copy-on-write paged block allocator with LRU retention.

Extends the ``BlockAllocator`` invariants (kvcache.py) to shared blocks:

  * a block may be owned by MANY readers — ``refcount(bid) >= 1`` while
    any request holds it, and it is returned to circulation only when
    the count reaches 0;
  * ``free + cached + used == total`` always, where *used* counts
    distinct referenced blocks, *cached* counts refcount-0 blocks
    retained for prefix reuse (registered in a prefix tree), and *free*
    counts immediately reusable blocks;
  * a cached block is only ever reclaimed through ``evict`` — which
    refuses blocks with ``refcount > 0``;
  * writes into a shared block go through ``fork`` (copy-on-write): the
    writer gets a private copy, the original keeps its other readers.

Admission math (``can_allocate`` / ``can_extend``) is over *available*
blocks (free + evictable), so with nothing cached the allocator behaves
bit-identically to the exclusive ``BlockAllocator``.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Sequence, Set

from repro.engine.kvcache import OutOfBlocks


class SharedBlockAllocator:
    def __init__(self, num_blocks: int, block_size: int = 16,
                 on_evict: Optional[Callable] = None,
                 pick_eviction: Optional[Callable] = None):
        """on_evict(bid): eviction notifier (prefix tree node removal).
        pick_eviction(): returns the bid to reclaim next (e.g. LRU leaf
        of the prefix tree); defaults to the internal LRU order."""
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.on_evict = on_evict
        self.pick_eviction = pick_eviction
        self._refcount: Dict[int, int] = {}
        self._owned: Dict[int, List[int]] = {}        # rid -> ordered bids
        self._free: List[int] = list(range(num_blocks - 1, -1, -1))
        self._cached: "OrderedDict[int, None]" = OrderedDict()
        self._registered: Set[int] = set()
        self.eviction_count = 0

    # ------------------------------------------------------------------
    # BlockAllocator-compatible surface
    # ------------------------------------------------------------------
    def blocks_for(self, tokens: int) -> int:
        return -(-max(tokens, 1) // self.block_size)

    @property
    def free_blocks(self) -> int:
        """Immediately free (no eviction needed)."""
        return len(self._free)

    @property
    def cached_blocks(self) -> int:
        """Refcount-0 blocks retained for reuse (evictable on demand)."""
        return len(self._cached)

    @property
    def available_blocks(self) -> int:
        return len(self._free) + len(self._cached)

    @property
    def used_blocks(self) -> int:
        """Distinct blocks referenced by at least one request."""
        return len(self._refcount)

    def utilization(self) -> float:
        return self.used_blocks / self.num_blocks

    def holds(self, rid: int) -> bool:
        return rid in self._owned

    def refcount(self, bid: int) -> int:
        return self._refcount.get(bid, 0)

    def owned(self, rid: int) -> List[int]:
        return list(self._owned.get(rid, ()))

    def owned_count(self, rid: int) -> int:
        """len(owned(rid)) without copying the list (hot-path probe)."""
        return len(self._owned.get(rid, ()))

    def bytes_owned(self, rid: int, bytes_per_token: int) -> int:
        return (len(self._owned.get(rid, ()))
                * self.block_size * bytes_per_token)

    # ------------------------------------------------------------------
    def can_allocate(self, tokens: int, shared=0) -> bool:
        """``shared``: count of prefix blocks, or the bid list itself.
        With the list, currently-cached shared bids are excluded from the
        evictable pool (referencing them removes them from it)."""
        if isinstance(shared, int):
            n_shared, cached_shared = shared, 0
        else:
            n_shared = len(shared)
            cached_shared = sum(1 for b in shared if b in self._cached)
        return (self.blocks_for(tokens) - n_shared
                <= self.available_blocks - cached_shared)

    def allocate(self, rid: int, tokens: int,
                 shared: Sequence[int] = ()) -> None:
        """Reserve blocks for a request: take a reference on each block
        in ``shared`` (the matched prefix, in order) and draw fresh
        exclusive blocks for the remainder."""
        if rid in self._owned:
            raise ValueError(f"rid {rid} already allocated")
        need = self.blocks_for(tokens)
        n_fresh = need - len(shared)
        if n_fresh < 0:
            raise ValueError("shared prefix longer than allocation")
        # refs first, so eviction below can never reclaim the prefix.
        # Roll back on a mid-list failure (a bid evicted between the
        # caller's peek and this claim): partial increfs must not leak.
        taken = 0
        try:
            for bid in shared:
                self._incref(bid)
                taken += 1
        except KeyError:
            for bid in shared[:taken]:
                self._decref(bid)
            raise
        fresh: List[int] = []
        try:
            for _ in range(n_fresh):
                fresh.append(self._take_fresh())
        except OutOfBlocks:
            self._free.extend(fresh)           # return partial draw
            for bid in shared:
                self._decref(bid)
            raise
        self._owned[rid] = list(shared) + fresh
        for bid in fresh:
            self._refcount[bid] = 1

    def can_extend(self, rid: int, tokens: int) -> bool:
        need = self.blocks_for(tokens) - len(self._owned.get(rid, ()))
        return need <= self.available_blocks

    def extend(self, rid: int, tokens: int) -> None:
        held = self._owned.get(rid)
        if held is None:
            raise KeyError(rid)
        extra = self.blocks_for(tokens) - len(held)
        fresh: List[int] = []
        try:
            for _ in range(max(extra, 0)):
                fresh.append(self._take_fresh())
        except OutOfBlocks:
            self._free.extend(fresh)          # atomic: return partial draw
            raise
        for bid in fresh:
            self._refcount[bid] = 1
            held.append(bid)

    def free(self, rid: int) -> int:
        """Drop all of a request's references.  Reversed order puts
        suffix blocks at the LRU end, so prefixes outlive their tails."""
        held = self._owned.pop(rid, [])
        for bid in reversed(held):
            self._decref(bid)
        return len(held)

    # ------------------------------------------------------------------
    # sharing / CoW / retention
    # ------------------------------------------------------------------
    def fork(self, rid: int, index: int) -> int:
        """Copy-on-write: replace the request's ``index``-th block with a
        private copy iff it is shared (refcount > 1).  Returns the bid
        the request now owns at that position."""
        held = self._owned[rid]
        bid = held[index]
        if self._refcount[bid] <= 1:
            return bid
        new = self._take_fresh()
        self._refcount[new] = 1
        held[index] = new
        self._decref(bid)
        return new

    def register(self, bid: int) -> None:
        """Mark a block's content as cacheable: at refcount 0 it is
        retained (LRU) instead of freed."""
        if self._refcount.get(bid, 0) <= 0 and bid not in self._cached:
            raise KeyError(f"bid {bid} not live")
        self._registered.add(bid)

    def is_registered(self, bid: int) -> bool:
        return bid in self._registered

    def evict(self, bid: int) -> None:
        """Reclaim one cached block.  Never touches referenced blocks."""
        if self._refcount.get(bid, 0) > 0:
            raise ValueError(f"evicting referenced block {bid}")
        if bid not in self._cached:
            raise KeyError(bid)
        del self._cached[bid]
        self._registered.discard(bid)
        self._free.append(bid)
        self.eviction_count += 1
        if self.on_evict is not None:
            self.on_evict(bid)

    # ------------------------------------------------------------------
    # tier promotion / replication support
    # ------------------------------------------------------------------
    def adopt_cached(self) -> int:
        """Draw a block directly into the retained cache (refcount 0,
        registered) — the HBM landing spot for a block promoted from a
        lower tier or replicated in from another instance.  May evict
        other cached blocks to make room (which re-spills them when a
        spill tier is wired to ``on_evict``)."""
        bid = self._take_fresh()
        self._registered.add(bid)
        self._cached[bid] = None
        return bid

    def pin(self, bid: int) -> None:
        """Take a reference on a live or cached block.  Guards multi-step
        promotions: a pinned block can neither be evicted nor picked as
        a victim while tensor copies for its neighbours are in flight."""
        self._incref(bid)

    def unpin(self, bid: int) -> None:
        self._decref(bid)

    # ------------------------------------------------------------------
    def _incref(self, bid: int) -> None:
        n = self._refcount.get(bid, 0)
        if n == 0:
            if bid not in self._cached:
                raise KeyError(f"bid {bid} not shareable")
            del self._cached[bid]
        self._refcount[bid] = n + 1

    def _decref(self, bid: int) -> None:
        n = self._refcount[bid] - 1
        if n > 0:
            self._refcount[bid] = n
            return
        del self._refcount[bid]
        if bid in self._registered:
            self._cached[bid] = None          # newest LRU position
        else:
            self._free.append(bid)

    def _take_fresh(self) -> int:
        if self._free:
            return self._free.pop()
        if not self._cached:
            raise OutOfBlocks("no free or evictable blocks")
        victim = None
        if self.pick_eviction is not None:
            victim = self.pick_eviction()
        if victim not in self._cached or self._refcount.get(victim, 0) > 0:
            # the callback is advisory, never trusted: a referenced,
            # unknown, or already-evicted victim would corrupt the pool
            # (double-free / dropping live KV) — fall back to LRU order
            victim = next(iter(self._cached))     # oldest retained
        self.evict(victim)
        return self._free.pop()
