"""Shared-prefix KV-cache subsystem: ref-counted copy-on-write block
sharing, a radix tree over per-block token hashes, and the per-instance
facade that admission/routing consults (see prefix_cache.py)."""
from repro.cache.prefix_cache import PrefixCache
from repro.cache.prefix_tree import PrefixTree, chain_hashes
from repro.cache.shared_allocator import SharedBlockAllocator

__all__ = ["PrefixCache", "PrefixTree", "SharedBlockAllocator",
           "chain_hashes"]
