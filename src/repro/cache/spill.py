"""Host-RAM spill tier for evicted KV blocks.

When the HBM block pool evicts a refcount-0 registered block, its
content is copied to a host-side buffer instead of being dropped, so a
later radix match can *prefetch* it back instead of silently
recomputing the prefix.  The tier is deliberately flat: one entry per
block, keyed by the block's **chained prefix hash** (the same hash the
``PrefixTree`` edges use), so

  * blocks spill independently and in any order — HBM eviction is
    LRU-leaf-first (children before parents), and a child entry whose
    ancestors are still HBM-resident needs no placeholder chain here;
  * a match walks the prompt's chain hashes and extends an HBM-resident
    prefix with the longest *contiguous* run of spilled blocks — a hole
    (an entry LRU-dropped from the host tier) truncates the run, never
    corrupts it;
  * content is verified against the stored block tokens on every hit,
    mirroring the tree's collision-degrades-to-miss guarantee.

Payloads are opaque to this module: the executor stores per-block host
copies of the paged pool leaves (numpy), the simulator stores ``None``
(bookkeeping-only tier — capacity/goodput modeling without tensors).
"""
from __future__ import annotations

from collections import OrderedDict
from typing import List, Optional, Sequence, Tuple

from repro.cache.prefix_tree import chain_hashes


class HostSpillPool:
    def __init__(self, capacity_blocks: int, block_size: int = 16):
        self.capacity = capacity_blocks
        self.block_size = block_size
        # chain hash -> (block tokens, payload); insertion order == LRU
        self._entries: "OrderedDict[int, Tuple[tuple, object]]" = \
            OrderedDict()
        self.spilled = 0            # blocks ever accepted from HBM
        self.dropped = 0            # blocks LRU-dropped from the host tier
        self.promoted = 0           # blocks prefetched back to HBM

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, chain: int) -> bool:
        return chain in self._entries

    def clear(self) -> int:
        """Drop every spilled block (host memory of a crashed node is as
        gone as its HBM).  Returns entries dropped."""
        n = len(self._entries)
        self._entries.clear()
        return n

    # ------------------------------------------------------------------
    def put(self, chain: int, blk_tokens: Sequence[int],
            payload) -> bool:
        """Accept one evicted block.  Re-spilling the same content
        refreshes recency; overflow drops the oldest entries."""
        if self.capacity <= 0:
            return False
        self.spilled += chain not in self._entries
        self._entries[chain] = (tuple(blk_tokens), payload)
        self._entries.move_to_end(chain)
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
            self.dropped += 1
        return True

    def match_from(self, tokens: Sequence[int], start_block: int,
                   max_blocks: Optional[int] = None,
                   touch: bool = True) -> List[Tuple[int, object]]:
        """Contiguous run of spilled blocks extending an HBM-resident
        prefix of ``start_block`` full blocks: ``[(chain, payload)]``.
        ``touch=False`` keeps routing peeks side-effect free."""
        run: List[Tuple[int, object]] = []
        for i, (h, blk) in enumerate(chain_hashes(tokens, self.block_size)):
            if max_blocks is not None and i >= max_blocks:
                break
            if i < start_block:
                continue
            entry = self._entries.get(h)
            if entry is None or entry[0] != blk:
                break
            if touch:
                self._entries.move_to_end(h)
            run.append((h, entry[1]))
        return run

    def touch(self, chain: int) -> bool:
        """Refresh an entry's LRU recency without re-copying its
        payload (incremental checkpoint capture: present blocks are
        touched, only absent ones are exported again)."""
        if chain not in self._entries:
            return False
        self._entries.move_to_end(chain)
        return True

    def take(self, chain: int):
        """Remove an entry and return its payload (block promoted back
        to HBM — if it is evicted again it simply re-spills)."""
        _, payload = self._entries.pop(chain)
        self.promoted += 1
        return payload

    def stats(self) -> dict:
        return {"resident": len(self._entries), "capacity": self.capacity,
                "spilled": self.spilled, "dropped": self.dropped,
                "promoted": self.promoted}
