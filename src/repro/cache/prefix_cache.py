"""Per-instance shared-prefix KV cache: prefix tree + shared allocator.

The facade an ``Instance`` owns when prefix caching is enabled.  It is
pure bookkeeping (block ids, token hashes) — tensor reuse on the real
engine is the executor's ``claim_prefix``, which this layer caps.

Lifecycle per request:

  match_tokens(prompt)          # pure — proxy routing peeks at all instances
  acquire(rid, prompt, hit, n)  # admission: ref matched blocks + fresh rest
  commit(rid, prompt)           # prefill done: full prompt blocks -> tree
  release(rid)                  # decref; refcount-0 registered blocks are
                                # RETAINED (LRU) for future prefix hits

Eviction is demand-driven inside the allocator; the tree supplies the
LRU-*leaf* victim so interior prefixes stay matchable, and is notified
on every eviction so it never maps a reclaimed block.

With ``spill_blocks > 0`` a host-RAM tier catches evicted blocks: the
eviction notifier copies the block out (through the executor-installed
``fetch_block`` callback; bookkeeping-only on the simulator) before the
tree forgets it, and ``prefetch`` promotes contiguous spilled extensions
of a prompt's HBM prefix back into the pool ahead of admission — a radix
match that once hit never silently degrades to recompute while the host
tier still holds the blocks.
"""
from __future__ import annotations

from typing import Callable, List, Optional, Sequence, Tuple

from repro.cache.prefix_tree import PrefixTree
from repro.cache.shared_allocator import SharedBlockAllocator
from repro.cache.spill import HostSpillPool
from repro.engine.kvcache import OutOfBlocks


class PrefixCache:
    def __init__(self, num_blocks: int, block_size: int = 16,
                 spill_blocks: int = 0):
        self.block_size = block_size
        self.tree = PrefixTree(block_size)
        self.spill = (HostSpillPool(spill_blocks, block_size)
                      if spill_blocks > 0 else None)
        self._fetch_block: Optional[Callable] = None
        self._load_block: Optional[Callable] = None
        self.allocator = SharedBlockAllocator(
            num_blocks, block_size,
            on_evict=self._on_evict,
            pick_eviction=self._pick_lru_leaf)

    def _pick_lru_leaf(self) -> Optional[int]:
        node = self.tree.lru_evictable(
            lambda bid: self.allocator.refcount(bid) == 0)
        return None if node is None else node.bid

    # ------------------------------------------------------------------
    # host spill tier
    # ------------------------------------------------------------------
    def bind_tiers(self, fetch_block: Optional[Callable] = None,
                   load_block: Optional[Callable] = None):
        """Executor hook: ``fetch_block(bid) -> payload`` copies a pool
        block to host memory, ``load_block(bid, payload)`` writes one
        back.  Unbound (the simulator) the spill tier is bookkeeping
        only — capacity and hit modeling without tensor traffic."""
        self._fetch_block = fetch_block
        self._load_block = load_block

    def _on_evict(self, bid: int):
        if self.spill is not None:
            nodes = self.tree._by_bid.get(bid, ())
            payload = (self._fetch_block(bid)
                       if nodes and self._fetch_block is not None else None)
            for node in nodes:
                self.spill.put(node.chain, node.tokens, payload)
        self.tree.remove_bid(bid)

    def prefetch(self, prompt_tokens: Sequence[int]) -> int:
        """Promote host-spilled blocks that contiguously extend the
        prompt's HBM-resident prefix back into the pool.  Returns tokens
        promoted.  The path is pinned for the duration so the evictions
        that make room can never reclaim what is being promoted."""
        if self.spill is None or not len(self.spill):
            return 0
        bs = self.block_size
        cap = self.max_match_tokens(prompt_tokens) // bs
        if cap <= 0:
            return 0
        path = self.tree.match(prompt_tokens, cap)
        depth = len(path)
        if depth >= cap:
            return 0
        run = self.spill.match_from(prompt_tokens, depth, cap, touch=False)
        if not run:
            return 0
        alloc = self.allocator
        bids = [n.bid for n in path]
        pinned: List[int] = []
        promoted = 0
        try:
            for bid in bids:
                alloc.pin(bid)
                pinned.append(bid)
            for chain, payload in run:
                if self._load_block is not None and payload is None:
                    break       # bookkeeping-only entry on a tensor engine
                try:
                    # may cascade-evict (re-spilling victims) to make room
                    bid = alloc.adopt_cached()
                except OutOfBlocks:
                    break
                if chain not in self.spill:
                    # the eviction cascade above LRU-dropped this very
                    # entry from the host tier: undo the adoption
                    alloc.evict(bid)
                    break
                alloc.pin(bid)
                pinned.append(bid)
                if self._load_block is not None:
                    self._load_block(bid, payload)
                self.spill.take(chain)
                bids.append(bid)
                promoted += 1
                self.tree.insert(
                    prompt_tokens[:(depth + promoted) * bs], bids)
        finally:
            for bid in reversed(pinned):
                alloc.unpin(bid)
        return promoted * bs

    @property
    def spilled_blocks(self) -> int:
        return 0 if self.spill is None else len(self.spill)

    def clear(self) -> int:
        """Total cache loss (instance crash): evict every cached
        refcount-0 block and drop the host spill tier with it — nothing
        survives the node.  Blocks still held by requests are untouched;
        evacuate those requests first.  Returns HBM blocks dropped."""
        spill, self.spill = self.spill, None   # no re-spilling mid-wipe
        dropped = 0
        try:
            for bid in list(self.allocator._cached):
                self.allocator.evict(bid)
                dropped += 1
        finally:
            self.spill = spill
        if self.spill is not None:
            self.spill.clear()
        return dropped

    # ------------------------------------------------------------------
    # cross-instance replication
    # ------------------------------------------------------------------
    def hot_prefixes(self, max_paths: int = 2,
                     min_hits: int = 3) -> List[tuple]:
        """Hottest matchable token prefixes, for the controller's
        epoch-boundary replication pass: ``[(token_prefix, hits)]``."""
        return self.tree.hot_paths(max_paths, min_hits)

    def admit_replica(self, tokens: Sequence[int],
                      n_blocks: int) -> Optional[Tuple[int, List[int]]]:
        """Adopt HBM blocks for a prefix replicated in from another
        instance.  Returns ``(skip, bids)`` — the full block list for
        the admitted prefix, of which the first ``skip`` were already
        resident (no tensor load needed) — or None when nothing new fit.
        Replicas never evict local content: adoption stops at the free
        watermark."""
        bs = self.block_size
        n_blocks = min(n_blocks, len(tokens) // bs)
        path = self.tree.match(tokens, n_blocks)
        skip = len(path)
        if skip >= n_blocks:
            return None
        alloc = self.allocator
        bids = [n.bid for n in path]
        pinned: List[int] = []
        try:
            for bid in bids:
                alloc.pin(bid)
                pinned.append(bid)
            for i in range(skip, n_blocks):
                if alloc.free_blocks <= 0:
                    break
                bid = alloc.adopt_cached()
                alloc.pin(bid)
                pinned.append(bid)
                bids.append(bid)
                self.tree.insert(tokens[:(i + 1) * bs], bids)
        finally:
            for bid in reversed(pinned):
                alloc.unpin(bid)
        if len(bids) <= skip:
            return None
        return skip, bids

    # ------------------------------------------------------------------
    def max_match_tokens(self, prompt_tokens: Sequence[int]) -> int:
        """Hit cap: full blocks only, and at least one token must remain
        to prefill (prefill emits the first output token)."""
        return ((len(prompt_tokens) - 1)
                // self.block_size * self.block_size)

    def match_tokens(self, prompt_tokens: Sequence[int]) -> int:
        """Longest reusable prefix in tokens.  Pure (no refcounts taken,
        no LRU recency touched) — this is what cache-aware routing peeks
        at on every instance per arrival."""
        cap = self.max_match_tokens(prompt_tokens) // self.block_size
        if cap <= 0:
            return 0
        return (len(self.tree.match(prompt_tokens, cap, touch=False))
                * self.block_size)

    def match_tokens_tiered(self, prompt_tokens: Sequence[int]) -> int:
        """HBM hit plus its contiguous host-spilled extension — what
        admission can reuse after a ``prefetch``.  Pure, for routing."""
        hbm = self.match_tokens(prompt_tokens)
        if self.spill is None or not len(self.spill):
            return hbm
        cap = self.max_match_tokens(prompt_tokens) // self.block_size
        depth = hbm // self.block_size
        if depth >= cap:
            return hbm
        run = self.spill.match_from(prompt_tokens, depth, cap, touch=False)
        return hbm + len(run) * self.block_size

    def matched_bids(self, prompt_tokens: Sequence[int], hit_tokens: int,
                     touch: bool = True) -> List[int]:
        n = hit_tokens // self.block_size
        return [nd.bid
                for nd in self.tree.match(prompt_tokens, n, touch=touch)][:n]

    # ------------------------------------------------------------------
    def can_acquire(self, prompt_tokens: Sequence[int], hit_tokens: int,
                    total_tokens: int) -> bool:
        """Pure admission check — run BEFORE the executor claims its
        slot/rows, so a memory-blocked request has no side effects to
        unwind."""
        shared = (self.matched_bids(prompt_tokens, hit_tokens, touch=False)
                  if hit_tokens else [])
        if len(shared) * self.block_size < hit_tokens:
            return False
        return self.allocator.can_allocate(total_tokens, shared)

    def acquire(self, rid: int, prompt_tokens: Sequence[int],
                hit_tokens: int, total_tokens: int) -> bool:
        """Admission: reference ``hit_tokens`` worth of cached prefix
        blocks and draw fresh blocks to cover ``total_tokens``.  False
        (nothing held) when even eviction can't make room."""
        shared = (self.matched_bids(prompt_tokens, hit_tokens)
                  if hit_tokens else [])
        if len(shared) * self.block_size < hit_tokens:
            return False                      # evicted between peek/claim
        if not self.allocator.can_allocate(total_tokens, shared):
            return False
        self.allocator.allocate(rid, total_tokens, shared=shared)
        return True

    def commit(self, rid: int, prompt_tokens: Sequence[int]) -> int:
        """Prefill complete: publish the request's full prompt blocks to
        the tree (first writer wins per position) and mark them retained.
        Returns how many blocks this request newly published."""
        bids = self.allocator.owned(rid)
        n_full = len(prompt_tokens) // self.block_size
        newly = self.tree.insert(prompt_tokens[:n_full * self.block_size],
                                 bids[:n_full])
        for bid in newly:
            self.allocator.register(bid)
        return len(newly)

    def release(self, rid: int) -> int:
        return self.allocator.free(rid)
