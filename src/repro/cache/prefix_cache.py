"""Per-instance shared-prefix KV cache: prefix tree + shared allocator.

The facade an ``Instance`` owns when prefix caching is enabled.  It is
pure bookkeeping (block ids, token hashes) — tensor reuse on the real
engine is the executor's ``claim_prefix``, which this layer caps.

Lifecycle per request:

  match_tokens(prompt)          # pure — proxy routing peeks at all instances
  acquire(rid, prompt, hit, n)  # admission: ref matched blocks + fresh rest
  commit(rid, prompt)           # prefill done: full prompt blocks -> tree
  release(rid)                  # decref; refcount-0 registered blocks are
                                # RETAINED (LRU) for future prefix hits

Eviction is demand-driven inside the allocator; the tree supplies the
LRU-*leaf* victim so interior prefixes stay matchable, and is notified
on every eviction so it never maps a reclaimed block.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

from repro.cache.prefix_tree import PrefixTree
from repro.cache.shared_allocator import SharedBlockAllocator


class PrefixCache:
    def __init__(self, num_blocks: int, block_size: int = 16):
        self.block_size = block_size
        self.tree = PrefixTree(block_size)
        self.allocator = SharedBlockAllocator(
            num_blocks, block_size,
            on_evict=self.tree.remove_bid,
            pick_eviction=self._pick_lru_leaf)

    def _pick_lru_leaf(self) -> Optional[int]:
        node = self.tree.lru_evictable(
            lambda bid: self.allocator.refcount(bid) == 0)
        return None if node is None else node.bid

    # ------------------------------------------------------------------
    def max_match_tokens(self, prompt_tokens: Sequence[int]) -> int:
        """Hit cap: full blocks only, and at least one token must remain
        to prefill (prefill emits the first output token)."""
        return ((len(prompt_tokens) - 1)
                // self.block_size * self.block_size)

    def match_tokens(self, prompt_tokens: Sequence[int]) -> int:
        """Longest reusable prefix in tokens.  Pure (no refcounts taken,
        no LRU recency touched) — this is what cache-aware routing peeks
        at on every instance per arrival."""
        cap = self.max_match_tokens(prompt_tokens) // self.block_size
        if cap <= 0:
            return 0
        return (len(self.tree.match(prompt_tokens, cap, touch=False))
                * self.block_size)

    def matched_bids(self, prompt_tokens: Sequence[int], hit_tokens: int,
                     touch: bool = True) -> List[int]:
        n = hit_tokens // self.block_size
        return [nd.bid
                for nd in self.tree.match(prompt_tokens, n, touch=touch)][:n]

    # ------------------------------------------------------------------
    def can_acquire(self, prompt_tokens: Sequence[int], hit_tokens: int,
                    total_tokens: int) -> bool:
        """Pure admission check — run BEFORE the executor claims its
        slot/rows, so a memory-blocked request has no side effects to
        unwind."""
        shared = (self.matched_bids(prompt_tokens, hit_tokens, touch=False)
                  if hit_tokens else [])
        if len(shared) * self.block_size < hit_tokens:
            return False
        return self.allocator.can_allocate(total_tokens, shared)

    def acquire(self, rid: int, prompt_tokens: Sequence[int],
                hit_tokens: int, total_tokens: int) -> bool:
        """Admission: reference ``hit_tokens`` worth of cached prefix
        blocks and draw fresh blocks to cover ``total_tokens``.  False
        (nothing held) when even eviction can't make room."""
        shared = (self.matched_bids(prompt_tokens, hit_tokens)
                  if hit_tokens else [])
        if len(shared) * self.block_size < hit_tokens:
            return False                      # evicted between peek/claim
        if not self.allocator.can_allocate(total_tokens, shared):
            return False
        self.allocator.allocate(rid, total_tokens, shared=shared)
        return True

    def commit(self, rid: int, prompt_tokens: Sequence[int]) -> int:
        """Prefill complete: publish the request's full prompt blocks to
        the tree (first writer wins per position) and mark them retained.
        Returns how many blocks this request newly published."""
        bids = self.allocator.owned(rid)
        n_full = len(prompt_tokens) // self.block_size
        newly = self.tree.insert(prompt_tokens[:n_full * self.block_size],
                                 bids[:n_full])
        for bid in newly:
            self.allocator.register(bid)
        return len(newly)

    def release(self, rid: int) -> int:
        return self.allocator.free(rid)
