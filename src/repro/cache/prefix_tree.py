"""Radix/prefix tree over per-block token hashes.

One node per KV block: the edge into a node is the *chained* hash of its
token block (hash of (parent_chain_hash, block tokens)), so a node at
depth ``d`` identifies a unique d-block token prefix.  Each node carries
an opaque ``bid`` — the allocator block id holding that block's KV
(instance-level sharing), or the cache slot whose row holds the whole
prefix up to this depth (engine-level donor index).

Matching walks the chain from the root and returns the node path; the
two users interpret it differently:

  * the block-level ``PrefixCache`` takes ``[n.bid for n in path]`` —
    every block along the path is individually reusable;
  * the engine donor index takes ``(len(path), path[-1].bid)`` — a node
    registered at depth d implies its slot row holds the *entire*
    d-block prefix (chains can only be extended by rows that contain
    their parents).

Token blocks are stored in the node and verified on match, so a 64-bit
hash collision degrades to a miss, never to wrong-token reuse.
"""
from __future__ import annotations

import itertools
from typing import Dict, List, Optional, Sequence

_ROOT_HASH = 0


class Node:
    __slots__ = ("chain", "tokens", "bid", "parent", "children",
                 "last_used", "hit_count")

    def __init__(self, chain: int, tokens: tuple, bid, parent: "Node"):
        self.chain = chain
        self.tokens = tokens          # this block's token ids (verification)
        self.bid = bid
        self.parent = parent
        self.children: Dict[int, "Node"] = {}
        self.last_used = 0
        self.hit_count = 0            # touching matches through this node


def chain_hashes(tokens: Sequence[int], block_size: int):
    """Yields (chain_hash, block_tokens) per *full* block — lazily, so a
    walk that misses at depth k hashes only k+1 blocks, not the whole
    prompt (peeks run per instance per arrival)."""
    h = _ROOT_HASH
    for i in range(0, (len(tokens) // block_size) * block_size, block_size):
        blk = tuple(int(t) for t in tokens[i:i + block_size])
        h = hash((h, blk))
        yield h, blk


class PrefixTree:
    def __init__(self, block_size: int):
        self.block_size = block_size
        self.root = Node(_ROOT_HASH, (), None, None)
        self._by_bid: Dict[object, List[Node]] = {}
        self._clock = itertools.count(1)
        self.node_count = 0

    # ------------------------------------------------------------------
    def match(self, tokens: Sequence[int],
              max_blocks: Optional[int] = None,
              touch: bool = True) -> List[Node]:
        """Longest cached prefix: the node path (root excluded).

        ``touch=False`` keeps the walk side-effect free (LRU recency
        unchanged) — routing peeks probe EVERY instance, and must not
        refresh blocks on instances that never receive the request."""
        path: List[Node] = []
        node = self.root
        for h, blk in chain_hashes(tokens, self.block_size):
            if max_blocks is not None and len(path) >= max_blocks:
                break
            child = node.children.get(h)
            if child is None or child.tokens != blk:
                break
            if touch:
                child.last_used = next(self._clock)
                child.hit_count += 1
            path.append(child)
            node = child
        return path

    def insert(self, tokens: Sequence[int], bids: Sequence) -> List:
        """Register blocks for a full-block token prefix.  Existing nodes
        keep their original bid (first writer wins — duplicate-content
        blocks stay unregistered).  Returns the bids newly registered."""
        node = self.root
        newly = []
        for (h, blk), bid in zip(chain_hashes(tokens, self.block_size), bids):
            child = node.children.get(h)
            if child is None or child.tokens != blk:
                child = Node(h, blk, bid, node)
                node.children[h] = child
                self._by_bid.setdefault(bid, []).append(child)
                self.node_count += 1
                newly.append(bid)
            child.last_used = next(self._clock)
            node = child
        return newly

    # ------------------------------------------------------------------
    def holds(self, bid) -> bool:
        return bid in self._by_bid

    def bids(self):
        return self._by_bid.keys()

    def remove_bid(self, bid) -> None:
        """Drop every node registered under ``bid`` (block evicted, or
        slot row reused).  Detached subtrees become unmatchable; their
        nodes are pruned so they cannot resurface under a stale chain."""
        for node in self._by_bid.pop(bid, []):
            self._detach(node)

    def _detach(self, node: Node) -> None:
        # iterative (explicit stack): chains reach prompt_len/block_size
        # deep — 1024 for 16k contexts at block 16 — past the default
        # Python recursion limit
        if node.parent is None:
            return                            # already pruned
        node.parent.children.pop(node.chain, None)
        node.parent = None
        stack = [node]
        while stack:
            n = stack.pop()
            self.node_count -= 1
            # prune the (now unreachable) subtree from the bid index
            for child in n.children.values():
                bucket = self._by_bid.get(child.bid)
                if bucket is not None:
                    try:
                        bucket.remove(child)
                    except ValueError:
                        pass
                    if not bucket:
                        del self._by_bid[child.bid]
                child.parent = None
                stack.append(child)
            n.children.clear()

    # ------------------------------------------------------------------
    def hot_paths(self, max_paths: int = 2,
                  min_hits: int = 3) -> List[tuple]:
        """Hottest matchable prefixes for cross-instance replication:
        ``[(token_prefix, hits)]``, hottest first.  For every chain whose
        nodes each matched at least ``min_hits`` times, only the deepest
        such node is reported (a parent's hit count is always >= its
        children's, so the frontier is well defined)."""
        out = []
        stack = [(self.root, ())]
        while stack:
            node, toks = stack.pop()
            for child in node.children.values():
                ctoks = toks + child.tokens
                if child.hit_count >= min_hits:
                    if not any(c.hit_count >= min_hits
                               for c in child.children.values()):
                        out.append((ctoks, child.hit_count))
                    stack.append((child, ctoks))
        out.sort(key=lambda e: -e[1])
        return out[:max_paths]

    # ------------------------------------------------------------------
    def lru_evictable(self, evictable) -> Optional[Node]:
        """Least-recently-used *leaf* whose bid satisfies ``evictable``
        (leaf-first keeps interior prefixes matchable, sglang-style).
        Iterative — see _detach."""
        best: Optional[Node] = None
        stack = [self.root]
        while stack:
            node = stack.pop()
            for child in node.children.values():
                if child.children:
                    stack.append(child)
                elif evictable(child.bid) and (
                        best is None or child.last_used < best.last_used):
                    best = child
        return best
