"""Production mesh builders.

Functions, not module constants — importing this module never touches
JAX device state, so smoke tests see 1 CPU device while the dry-run
(which sets XLA_FLAGS before any import) sees 512.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; the multi-pod mesh adds a leading
    'pod' axis (2 pods = 512 chips).  v5e pod slice topology."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_local_mesh(model: int = 1):
    """Whatever devices exist locally (tests / CPU examples)."""
    n = len(jax.devices())
    data = n // model
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(AxisType.Auto, AxisType.Auto))
