"""Training launcher.

Local mode (default) trains a reduced config on CPU for smoke/demo; the
production path lowers the real config's train_step onto the production
mesh (same code the dry-run compiles) — on actual v5e pods the only
change is real devices behind the same mesh axes.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --steps 50
"""
from __future__ import annotations

import argparse
import json

from repro.configs import get_config, list_archs, reduced_config
from repro.training.checkpoint import save_checkpoint
from repro.training.train import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m", choices=list_archs())
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full-config", action="store_true",
                    help="use the full (assigned) config instead of the "
                         "reduced smoke variant — CPU-feasible only for "
                         "the smallest archs")
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args()

    cfg = (get_config(args.arch) if args.full_config
           else reduced_config(args.arch))
    params, history = train_loop(cfg, args.steps, args.batch, args.seq)
    print(json.dumps(history, indent=2))
    if args.ckpt:
        save_checkpoint(args.ckpt, params, meta={"arch": cfg.name,
                                                 "steps": args.steps})
        print("checkpoint saved to", args.ckpt)


if __name__ == "__main__":
    main()
