"""Serving launcher: run a TaiChi (or baseline) cluster.

Two modes:
  --engine sim   event-driven simulator with estimator timing (default;
                 any registered arch, production scale)
  --engine jax   real JAX engine on local devices with reduced configs
                 (CPU demo; tokens are really computed)

Examples:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-14b \
      --policy taichi --np 2 --nd 2 --sp 1024 --sd 256 --qps 80
  PYTHONPATH=src python -m repro.launch.serve --engine jax \
      --arch smollm-135m --qps 2 --n 16
"""
from __future__ import annotations

import argparse
import json

import jax

from repro.configs import get_config, list_archs, reduced_config
from repro.core.estimator import CostModel
from repro.core.hw import InstanceSpec
from repro.core.latency import SLO
from repro.core.policies import Sliders
from repro.sim.simulator import ServingConfig, build_cluster, run_sim
from repro.sim.workload import WORKLOADS


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-14b")
    ap.add_argument("--engine", choices=["sim", "jax"], default="sim")
    ap.add_argument("--policy", default="taichi",
                    choices=["taichi", "aggregation", "disaggregation"])
    ap.add_argument("--np", type=int, default=2)
    ap.add_argument("--nd", type=int, default=2)
    ap.add_argument("--sp", type=int, default=1024)
    ap.add_argument("--sd", type=int, default=256)
    ap.add_argument("--tp", type=int, default=4)
    ap.add_argument("--qps", type=float, default=40.0)
    ap.add_argument("--n", type=int, default=300)
    ap.add_argument("--workload", default="sharegpt",
                    choices=sorted(WORKLOADS))
    ap.add_argument("--ttft-slo", type=float, default=1.5)
    ap.add_argument("--tpot-slo", type=float, default=0.030)
    args = ap.parse_args()

    slo = SLO(ttft=args.ttft_slo, tpot=args.tpot_slo)
    sliders = Sliders(n_p=args.np, n_d=args.nd, s_p=args.sp, s_d=args.sd)

    if args.engine == "sim":
        sc = ServingConfig(model=args.arch, tp=args.tp, policy=args.policy,
                           sliders=sliders)
        st = run_sim(sc, slo, WORKLOADS[args.workload], args.qps, args.n)
        c = st.cluster
        print(json.dumps({**st.summary(),
                          "policy": args.policy,
                          "transfers": c.transfer_count,
                          "backflows": c.backflow_count,
                          "degrades": c.degrade_count}, indent=2))
        return

    # real-engine demo on CPU: reduced config, shared random params
    from repro.engine.engine import JaxExecutor
    from repro.models import transformer as tf
    cfg = reduced_config(args.arch)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    sc = ServingConfig(model=args.arch, tp=1, policy=args.policy,
                       sliders=Sliders(n_p=args.np, n_d=args.nd,
                                       s_p=min(args.sp, 64),
                                       s_d=min(args.sd, 32)),
                       hbm_blocks=512)
    factory = lambda: JaxExecutor(cfg, params, n_slots=8, max_seq=512)
    cluster = build_cluster(sc, slo, executor_factory=factory)
    # CostModel must describe the small model for timing coherence
    from repro.sim.workload import LengthDist, WorkloadSpec
    wl = WorkloadSpec("tiny",
                      LengthDist(mu=3.4, sigma=0.4, lo=16, hi=128),
                      LengthDist(mu=2.5, sigma=0.4, lo=4, hi=32))
    reqs = wl.sample_requests(args.n, args.qps, seed=0)
    cluster.run(reqs)
    st = cluster.stats(reqs, slo, args.qps)
    print(json.dumps({**st.summary(),
                      "policy": args.policy,
                      "real_tokens": sum(len(r.output_tokens)
                                         for r in reqs),
                      "transfers": cluster.transfer_count}, indent=2))


if __name__ == "__main__":
    main()
