"""Serving launcher: run a TaiChi (or baseline) cluster.

Three modes:
  --engine sim   event-driven simulator with estimator timing (default;
                 any registered arch, production scale)
  --engine jax   real JAX engine on local devices with reduced configs
                 (CPU demo; tokens are really computed), batch replay
  --engine live  the ONLINE serving runtime on the real JAX engine:
                 open-loop ingestion, per-token streaming, windowed
                 telemetry snapshots, and (with --controller) live
                 slider adaptation incl. drain-and-flip role changes

Examples:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-14b \
      --policy taichi --np 2 --nd 2 --sp 1024 --sd 256 --qps 80
  PYTHONPATH=src python -m repro.launch.serve --engine jax \
      --arch smollm-135m --qps 2 --n 16
  PYTHONPATH=src python -m repro.launch.serve --engine live \
      --arch smollm-135m --qps 3 --n 24 --controller --stream
"""
from __future__ import annotations

import argparse
import json

import jax

from repro.configs import get_config, list_archs, reduced_config
from repro.core.estimator import CostModel
from repro.core.hw import InstanceSpec
from repro.core.latency import SLO
from repro.core.policies import Sliders
from repro.sim.simulator import ServingConfig, build_cluster, run_sim
from repro.sim.workload import WORKLOADS, LengthDist, WorkloadSpec

#: reduced-config live/jax demo traffic (tokenized: the engine sees real
#: token ids, so runs are reproducible across loops)
TINY = WorkloadSpec("tiny",
                    LengthDist(mu=3.4, sigma=0.4, lo=16, hi=128),
                    LengthDist(mu=2.5, sigma=0.4, lo=4, hi=32),
                    tokenized=True, vocab_size=4096)


def _trace_config(args):
    """--trace (or either output path) turns on lifecycle tracing."""
    if not (args.trace or args.trace_out or args.trace_jsonl):
        return None
    from repro.serving import TraceConfig
    return TraceConfig()


def _dump_trace(loop, args, slo: SLO):
    tr = getattr(loop, "tracer", None)
    if tr is None:
        return
    if args.trace_out:
        tr.dump_chrome(args.trace_out)
        print(f"chrome trace -> {args.trace_out} "
              "(open in ui.perfetto.dev)", flush=True)
    if args.trace_jsonl:
        tr.dump_jsonl(args.trace_jsonl)
        print(f"trace jsonl -> {args.trace_jsonl}", flush=True)
    print(json.dumps(
        {"slo_violation_report": tr.violation_report(slo)},
        indent=2, default=str))


def _live_mode(args, slo: SLO):
    """Online runtime on the real engine (reduced config, CPU-runnable):
    tokens stream as they are computed, telemetry snapshots print as
    JSON lines, and the controller may retune sliders mid-run."""
    from repro.engine.engine import JaxExecutor
    from repro.kernels import kernels_native_default
    from repro.models import attention
    from repro.models import transformer as tf
    from repro.serving import (ControllerConfig, ServingLoop,
                               SliderController, WallClock)
    if kernels_native_default():
        # serving default on a real TPU backend: paged Pallas kernels
        # dereference block tables at DMA time (CPU keeps the jnp
        # reference read, where the kernels would only interpret)
        attention.use_kernels(True)
    cfg = reduced_config(args.arch)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    sc = ServingConfig(model=args.arch, tp=1, policy=args.policy,
                       sliders=Sliders(n_p=args.np, n_d=args.nd,
                                       s_p=min(args.sp, 64),
                                       s_d=min(args.sd, 32)),
                       hbm_blocks=512)
    factory = lambda: JaxExecutor(cfg, params, n_slots=8, max_seq=512)
    cluster = build_cluster(sc, slo, executor_factory=factory,
                            async_exec=not args.no_async)
    if args.horizon > 1:
        cluster.set_horizon(args.horizon)
    ctl = None
    if args.controller:
        ctl = SliderController(ControllerConfig(
            epoch=args.epoch, cooldown=1,
            sd_steps=(16, 32, 64)))        # reduced-config ladder
    streamed = {"tokens": 0}

    def on_token(req, t, tok):
        streamed["tokens"] += 1
        if args.stream:
            print(f"[{t:8.3f}s] req{req.rid} token#{req.output_len} "
                  f"id={tok}")

    loop = ServingLoop(
        cluster, slo,
        arrivals=TINY.iter_requests(args.qps, seed=0,
                                    max_new_tokens=32, limit=args.n),
        controller=ctl, window=args.window, on_token=on_token,
        snapshot_every=args.snapshot_every,
        clock=WallClock() if args.pace else None, pace=args.pace,
        tracing=_trace_config(args))
    loop.run()
    _dump_trace(loop, args, slo)
    for snap in loop.log.snapshots:
        print(json.dumps({k: v for k, v in snap.items()
                          if k != "instances"}))
    st = loop.stats(args.qps)
    print(json.dumps({**st.summary(),
                      "policy": args.policy,
                      "streamed_tokens": streamed["tokens"],
                      "real_tokens": sum(len(r.output_tokens)
                                         for r in loop.requests),
                      "transfers": cluster.transfer_count,
                      "controller_moves": (ctl.moves if ctl else [])},
                     indent=2, default=str))


def _serve_mode(args, slo: SLO):
    """Deployable network front-end: the live JAX engine behind the
    OpenAI-compatible HTTP/SSE server (``repro.frontend``), with the
    multi-process tokenize/detokenize pipeline and the router-side
    admission queue.  Blocks until SIGINT/SIGTERM, then drains."""
    from repro.engine.engine import JaxExecutor
    from repro.frontend import AdmissionConfig, FrontendConfig, \
        FrontendServer
    from repro.models import transformer as tf
    from repro.serving import (ControllerConfig, ServingLoop,
                               SliderController, WallClock)
    host, _, port = args.serve.rpartition(":")
    cfg = reduced_config(args.arch)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    sc = ServingConfig(model=args.arch, tp=1, policy=args.policy,
                       sliders=Sliders(n_p=args.np, n_d=args.nd,
                                       s_p=min(args.sp, 64),
                                       s_d=min(args.sd, 32)),
                       hbm_blocks=512)
    factory = lambda: JaxExecutor(cfg, params, n_slots=8, max_seq=512)
    cluster = build_cluster(sc, slo, executor_factory=factory,
                            async_exec=not args.no_async)
    if args.horizon > 1:
        cluster.set_horizon(args.horizon)
    ctl = None
    if args.controller:
        ctl = SliderController(ControllerConfig(
            epoch=args.epoch, cooldown=1, sd_steps=(16, 32, 64)))
    loop = ServingLoop(
        cluster, slo, clock=WallClock(), pace=True, controller=ctl,
        window=args.window,
        admission=AdmissionConfig(max_depth=args.adm_depth,
                                  max_inflight=args.adm_inflight),
        tracing=_trace_config(args))
    srv = FrontendServer(loop, FrontendConfig(
        host=host or "127.0.0.1", port=int(port), model=args.arch,
        tok_workers=args.tok_workers))
    print(f"serving {args.arch} ({args.policy}) on "
          f"http://{host or '127.0.0.1'}:{port} — POST /v1/completions, "
          "/v1/chat/completions; GET /healthz, /metrics", flush=True)
    srv.run(install_signals=True)
    print(json.dumps(loop.snapshot(), default=str))
    _dump_trace(loop, args, slo)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-14b")
    ap.add_argument("--engine", choices=["sim", "jax", "live"],
                    default="sim")
    ap.add_argument("--policy", default="taichi",
                    choices=["taichi", "aggregation", "disaggregation"])
    ap.add_argument("--np", type=int, default=2)
    ap.add_argument("--nd", type=int, default=2)
    ap.add_argument("--sp", type=int, default=1024)
    ap.add_argument("--sd", type=int, default=256)
    ap.add_argument("--tp", type=int, default=4)
    ap.add_argument("--qps", type=float, default=40.0)
    ap.add_argument("--n", type=int, default=300)
    ap.add_argument("--workload", default="sharegpt",
                    choices=sorted(WORKLOADS))
    ap.add_argument("--ttft-slo", type=float, default=1.5)
    ap.add_argument("--tpot-slo", type=float, default=0.030)
    # live-mode knobs
    ap.add_argument("--controller", action="store_true",
                    help="live: adapt sliders online (epoch-based)")
    ap.add_argument("--epoch", type=float, default=2.0,
                    help="live: controller epoch seconds")
    ap.add_argument("--window", type=float, default=5.0,
                    help="live: telemetry window seconds")
    ap.add_argument("--snapshot-every", type=float, default=5.0,
                    help="live: telemetry snapshot cadence")
    ap.add_argument("--stream", action="store_true",
                    help="live: print every streamed token")
    ap.add_argument("--pace", action="store_true",
                    help="live: pace events to wall-clock time")
    ap.add_argument("--horizon", type=int, default=8,
                    help="live: max fused decode steps per iteration "
                         "(adaptive; 1 = classic single-step)")
    ap.add_argument("--no-async", action="store_true",
                    help="live: disable the non-blocking dispatch/"
                         "commit executor pipeline")
    # tracing knobs (live + serve modes)
    ap.add_argument("--trace", action="store_true",
                    help="record per-request lifecycle traces and print "
                         "an SLO violation attribution report")
    ap.add_argument("--trace-out", metavar="PATH", default=None,
                    help="write a Chrome/Perfetto trace JSON after the "
                         "run (implies --trace)")
    ap.add_argument("--trace-jsonl", metavar="PATH", default=None,
                    help="write the trace event log as JSON lines "
                         "(implies --trace)")
    # network front-end knobs
    ap.add_argument("--serve", metavar="HOST:PORT", default=None,
                    help="run the OpenAI-compatible HTTP/SSE server on "
                         "the live engine (e.g. --serve 0.0.0.0:8000)")
    ap.add_argument("--tok-workers", type=int, default=2,
                    help="serve: tokenizer/detokenizer worker processes "
                         "(0 = inline, single-process)")
    ap.add_argument("--adm-depth", type=int, default=256,
                    help="serve: admission queue depth bound")
    ap.add_argument("--adm-inflight", type=int, default=64,
                    help="serve: released-but-unfinished request cap")
    args = ap.parse_args()

    slo = SLO(ttft=args.ttft_slo, tpot=args.tpot_slo)
    sliders = Sliders(n_p=args.np, n_d=args.nd, s_p=args.sp, s_d=args.sd)

    if args.serve:
        return _serve_mode(args, slo)

    if args.engine == "live":
        return _live_mode(args, slo)

    if args.engine == "sim":
        sc = ServingConfig(model=args.arch, tp=args.tp, policy=args.policy,
                           sliders=sliders)
        st = run_sim(sc, slo, WORKLOADS[args.workload], args.qps, args.n)
        c = st.cluster
        print(json.dumps({**st.summary(),
                          "policy": args.policy,
                          "transfers": c.transfer_count,
                          "backflows": c.backflow_count,
                          "degrades": c.degrade_count}, indent=2))
        return

    # real-engine demo on CPU: reduced config, shared random params
    from repro.engine.engine import JaxExecutor
    from repro.models import transformer as tf
    cfg = reduced_config(args.arch)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    sc = ServingConfig(model=args.arch, tp=1, policy=args.policy,
                       sliders=Sliders(n_p=args.np, n_d=args.nd,
                                       s_p=min(args.sp, 64),
                                       s_d=min(args.sd, 32)),
                       hbm_blocks=512)
    factory = lambda: JaxExecutor(cfg, params, n_slots=8, max_seq=512)
    cluster = build_cluster(sc, slo, executor_factory=factory)
    # CostModel must describe the small model for timing coherence
    from repro.sim.workload import LengthDist, WorkloadSpec
    wl = WorkloadSpec("tiny",
                      LengthDist(mu=3.4, sigma=0.4, lo=16, hi=128),
                      LengthDist(mu=2.5, sigma=0.4, lo=4, hi=32))
    reqs = wl.sample_requests(args.n, args.qps, seed=0)
    cluster.run(reqs)
    st = cluster.stats(reqs, slo, args.qps)
    print(json.dumps({**st.summary(),
                      "policy": args.policy,
                      "real_tokens": sum(len(r.output_tokens)
                                         for r in reqs),
                      "transfers": cluster.transfer_count}, indent=2))


if __name__ == "__main__":
    main()
