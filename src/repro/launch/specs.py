"""Dry-run case construction: (architecture x input-shape) -> a jittable
step function + abstract inputs (ShapeDtypeStruct, zero allocation) +
shardings for the production mesh.

Input shapes (assigned):
  train_4k     seq=4096    global_batch=256   train_step
  prefill_32k  seq=32768   global_batch=32    full chunked prefill (4k chunks)
  decode_32k   seq=32768   global_batch=128   serve_step (1 token, 32k KV)
  long_500k    seq=524288  global_batch=1     serve_step (1 token, 512k KV)

Applicability (DESIGN.md §Shape skips): long_500k runs only for archs
with bounded attention state (zamba2 hybrid, mamba2 SSM, gemma3 sliding-
window); pure full-attention archs skip it.  Modality notes: VLM prompts
are [patch-embeds ; text] with the assigned seq as the combined length;
audio backbones prefill/decode the text decoder against a fixed 1500-
frame encoder context (frontends stubbed per the assignment carve-out).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.distributed import sharding as shd
from repro.models import transformer as tf
from repro.models.config import ModelConfig
from repro.training.optimizer import AdamWConfig, init_opt_state
from repro.training.train import make_train_step

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32, chunk=4096),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}

LONG_CTX_ARCHS = {"zamba2-7b", "mamba2-1.3b", "gemma3-1b"}
AUDIO_FRAMES = 1500


def applicable(arch: str, shape: str) -> Tuple[bool, str]:
    if shape == "long_500k" and arch not in LONG_CTX_ARCHS:
        return False, ("pure full-attention architecture: 512k dense KV has "
                       "no sub-quadratic variant in the source model "
                       "(DESIGN.md §Shape skips)")
    return True, ""


def _vlm_split(cfg: ModelConfig, seq: int) -> Tuple[int, int]:
    """(image_tokens, text_tokens) with text a multiple of 512."""
    img = min(4096, max(512, seq // 8))
    return img, seq - img


def _fsdp_needed(cfg: ModelConfig) -> bool:
    """Weights-per-model-shard > 8 GiB -> shard weights over data too."""
    per_shard = cfg.param_count() * 2 / 16
    return per_shard > 8 * 1024 ** 3


@dataclasses.dataclass
class DryRunCase:
    arch: str
    shape: str
    kind: str
    fn: Callable
    args: tuple                 # ShapeDtypeStructs
    in_specs: tuple             # PartitionSpec trees
    out_specs: object           # or None to let GSPMD propagate
    donate: tuple = ()
    note: str = ""


def _mesh_sizes(mesh) -> Tuple[int, int]:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    model = sizes["model"]
    return model, mesh.devices.size // model


def _fsdp_param_specs(cfg: ModelConfig, mesh):
    """Augment TP specs with data-axis sharding on the largest divisible
    free axis of every big weight (>= 32 MiB per model shard)."""
    model_size, dp_size = _mesh_sizes(mesh)
    base = shd.param_specs(cfg, model_size)
    shapes = tf.abstract_params(cfg)
    dp = shd.data_axes(mesh)

    def aug(spec: P, leaf):
        entries = list(spec) + [None] * (leaf.ndim - len(spec))
        div = model_size if "model" in entries else 1
        if leaf.size * 2 / div < 32 * 1024 ** 2:
            return P(*entries)
        free = [i for i, e in enumerate(entries)
                if e is None and leaf.shape[i] % dp_size == 0
                and leaf.shape[i] > 1]
        if not free:
            return P(*entries)
        ax = max(free, key=lambda i: leaf.shape[i])
        entries[ax] = dp
        return P(*entries)

    return jax.tree.map(aug, base, shapes)


def period_len(cfg: ModelConfig) -> int:
    return len(cfg.segments()[0].pattern)


def true_periods(cfg: ModelConfig) -> float:
    """Number of scan periods in the full config (fractional when a
    trailing partial segment exists — gemma3 26/6, zamba2 81/6)."""
    return cfg.num_layers / period_len(cfg)


def probe_cfg(cfg: ModelConfig, d: int) -> ModelConfig:
    """Shallow fully-unrolled variant with exactly ``d`` periods — used
    by the dry-run's loop-aware cost probes (cost_analysis counts a scan
    body once; probes at d=1,2 recover the per-period cost exactly)."""
    kw = dict(num_layers=period_len(cfg) * d, scan_unroll=True)
    if cfg.family == "audio":
        kw["num_encoder_layers"] = d
    return dataclasses.replace(cfg, **kw)


def build_case(arch: str, shape: str, mesh,
               fsdp: Optional[bool] = None,
               cfg: Optional[ModelConfig] = None,
               prefill_chunks: Optional[int] = None,
               kv_mode: str = "auto",
               chunk_override: Optional[int] = None,
               accum_steps: int = 1) -> DryRunCase:
    cfg = cfg or get_config(arch)
    info = SHAPES[shape]
    seq, batch, kind = info["seq"], info["batch"], info["kind"]
    dp = shd.data_axes(mesh)
    bdim = dp if batch > 1 else None
    dt = cfg.param_dtype

    model_size, dp_size = _mesh_sizes(mesh)
    params_abs = tf.abstract_params(cfg)
    use_fsdp = _fsdp_needed(cfg) if fsdp is None else fsdp
    pspecs = (_fsdp_param_specs(cfg, mesh) if use_fsdp
              else shd.param_specs(cfg, model_size))
    note = "fsdp" if use_fsdp else ""

    if kind == "train":
        opt_abs = jax.eval_shape(init_opt_state, params_abs)
        ospecs = jax.tree.map(lambda _: None, opt_abs)  # placeholder
        from repro.training.optimizer import OptState
        ospecs = OptState(step=P(), m=pspecs, v=pspecs)
        batch_abs = {"tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
                     "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32)}
        bspecs = {"tokens": P(bdim, None), "labels": P(bdim, None)}
        if cfg.family == "vlm":
            img, txt = _vlm_split(cfg, seq)
            batch_abs = {"tokens": jax.ShapeDtypeStruct((batch, txt),
                                                        jnp.int32),
                         "labels": jax.ShapeDtypeStruct((batch, txt),
                                                        jnp.int32),
                         "image_embeds": jax.ShapeDtypeStruct(
                             (batch, img, cfg.vision_dim), dt)}
            bspecs = dict(bspecs, image_embeds=P(bdim, None, None))
        if cfg.family == "audio":
            batch_abs["audio_embeds"] = jax.ShapeDtypeStruct(
                (batch, AUDIO_FRAMES, cfg.d_model), dt)
            bspecs = dict(bspecs, audio_embeds=P(bdim, None, None))
        fn = make_train_step(cfg, AdamWConfig(), accum_steps=accum_steps)
        return DryRunCase(arch, shape, kind, fn,
                          (params_abs, opt_abs, batch_abs),
                          (pspecs, ospecs, bspecs), None,
                          donate=(0, 1), note=note)

    cross = AUDIO_FRAMES if cfg.family == "audio" else 0
    cspecs = shd.cache_specs(cfg, mesh, batch, seq, cross_len=cross,
                             kv_mode=kv_mode)
    if kind == "prefill":
        chunk = chunk_override or info["chunk"]
        kw = {}
        img = 0
        if cfg.family == "vlm":
            img, txt = _vlm_split(cfg, seq)
            txt = (txt // chunk) * chunk
            kw["image_embeds"] = jax.ShapeDtypeStruct(
                (batch, img, cfg.vision_dim), dt)
        else:
            txt = seq
        if prefill_chunks is not None:
            txt = chunk * prefill_chunks
        if cfg.family == "audio":
            kw["audio_embeds"] = jax.ShapeDtypeStruct(
                (batch, AUDIO_FRAMES, cfg.d_model), dt)
        cache_abs = tf.abstract_cache(cfg, batch, seq, dt, cross_len=cross)
        tokens_abs = jax.ShapeDtypeStruct((batch, txt), jnp.int32)

        kw_names = tuple(kw)

        def fn(params, cache, tokens, *extras):
            kwargs = dict(zip(kw_names, extras))
            logits, cache = tf.full_prefill(params, cfg, tokens, cache,
                                            chunk, **kwargs)
            return jnp.argmax(logits, -1), cache

        kwspecs = tuple(P(bdim, None, None) for _ in kw_names)
        return DryRunCase(
            arch, shape, kind, fn,
            (params_abs, cache_abs, tokens_abs) + tuple(kw.values()),
            (pspecs, cspecs, P(bdim, None)) + kwspecs,
            None, donate=(1,), note=note)

    # decode
    cache_abs = tf.abstract_cache(cfg, batch, seq, dt, cross_len=cross)
    tokens_abs = jax.ShapeDtypeStruct((batch, 1), jnp.int32)
    pos_abs = jax.ShapeDtypeStruct((batch,), jnp.int32)

    def dfn(params, cache, tokens, pos):
        logits, cache = tf.decode_step(params, cfg, tokens, cache, pos)
        return jnp.argmax(logits, -1), cache

    return DryRunCase(
        arch, shape, kind, dfn,
        (params_abs, cache_abs, tokens_abs, pos_abs),
        (pspecs, cspecs, P(bdim, None), P(bdim)),
        None, donate=(1,), note=note)
