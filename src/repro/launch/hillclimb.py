import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
"""Perf-iteration driver: compile one (arch x shape) case with sharding/
config overrides and report the three roofline terms — the measurement
loop of EXPERIMENTS.md §Perf.

  PYTHONPATH=src python -m repro.launch.hillclimb --arch qwen3-14b \
      --shape prefill_32k --kv-mode batch
"""
import argparse
import json
import time

import jax

from repro.analysis.roofline import collective_bytes_from_hlo, roofline_report
from repro.configs import get_config
from repro.launch.dryrun import _compile_case, _probe_costs
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import SHAPES, build_case, probe_cfg


def measure(arch: str, shape: str, *, kv_mode: str = "auto",
            fsdp=None, cfg_override=None, probes: bool = True,
            use_hints: bool = False, chunk: int = None,
            seq_parallel: bool = False, accum_steps: int = 1,
            label: str = "") -> dict:
    mesh = make_production_mesh()
    base_cfg = cfg_override or get_config(arch)
    case = build_case(arch, shape, mesh, fsdp=fsdp, cfg=base_cfg,
                      kv_mode=kv_mode, chunk_override=chunk,
                      accum_steps=accum_steps)
    t0 = time.time()
    compiled = _compile_case(case, mesh, use_hints=use_hints, seq_parallel=seq_parallel)
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    coll = collective_bytes_from_hlo(compiled.as_text())
    rec = {
        "arch": arch, "shape": shape, "mesh": "16x16", "n_devices": 256,
        "kind": case.kind, "note": f"{label or kv_mode}",
        "compile_s": round(time.time() - t0, 1),
        "arg_bytes": int(mem.argument_size_in_bytes),
        "out_bytes": int(mem.output_size_in_bytes),
        "temp_bytes": int(mem.temp_size_in_bytes),
        "alias_bytes": int(mem.alias_size_in_bytes),
        "peak_bytes_per_device": int(mem.argument_size_in_bytes
                                     + mem.output_size_in_bytes
                                     + mem.temp_size_in_bytes
                                     - mem.alias_size_in_bytes),
        "raw_flops_per_device": float(cost.get("flops", 0.0)),
        "raw_bytes_per_device": float(cost.get("bytes accessed", 0.0)),
        "raw_collective_bytes_per_device": float(coll),
    }
    if probes:
        kind = SHAPES[shape]["kind"]

        def builder(d, k):
            return build_case(
                arch, shape, mesh, fsdp=fsdp,
                cfg=probe_cfg(base_cfg, d), kv_mode=kv_mode,
                chunk_override=chunk, accum_steps=accum_steps,
                prefill_chunks=(k if kind == "prefill" else None))

        corr = _probe_costs(builder, mesh, use_hints=use_hints, seq_parallel=seq_parallel)
        rec["flops_per_device"] = corr["flops"]
        rec["hlo_bytes_accessed_per_device"] = corr["bytes"]
        rec["collective_bytes_per_device"] = corr["coll"]
        rec["cost_method"] = "probe-corrected"
    else:
        rec["flops_per_device"] = rec["raw_flops_per_device"]
        rec["hlo_bytes_accessed_per_device"] = rec["raw_bytes_per_device"]
        rec["collective_bytes_per_device"] = float(coll)
        rec["cost_method"] = "raw"
    rec["roofline"] = roofline_report(rec)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--kv-mode", default="auto")
    ap.add_argument("--hints", action="store_true")
    ap.add_argument("--chunk", type=int, default=None)
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--label", default="")
    ap.add_argument("--no-probes", action="store_true")
    args = ap.parse_args()
    rec = measure(args.arch, args.shape, kv_mode=args.kv_mode,
                  probes=not args.no_probes, use_hints=args.hints,
                  chunk=args.chunk, seq_parallel=args.seq_parallel,
                  accum_steps=args.accum, label=args.label)
    print(json.dumps(rec, indent=2))


if __name__ == "__main__":
    main()
