import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run driver.

Lowers + compiles every (architecture x input shape) case on the
production meshes (single-pod 16x16 and multi-pod 2x16x16), records
memory_analysis / cost_analysis / collective bytes, and writes one JSON
artifact per case under runs/dryrun/.

The two XLA_FLAGS lines above MUST stay the first statements: jax locks
the device count at first initialization.  Nothing else in the repo sets
this flag — smoke tests and benchmarks see the real single CPU device.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-14b --shape decode_32k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh single|multi|both]
"""

import argparse
import json
import time
import traceback

import jax

from repro.analysis.roofline import (collective_bytes_from_hlo,
                                     roofline_report)
from repro.configs import ASSIGNED, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (SHAPES, applicable, build_case, probe_cfg,
                                true_periods)

RUNS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                        "runs", "dryrun")


def _compile_case(case, mesh, use_hints: bool = False,
                  seq_parallel: bool = False):
    from repro.distributed import hints as hints_mod
    from repro.distributed.sharding import data_axes
    with mesh:
        shardings = jax.tree.map(
            lambda s: jax.NamedSharding(mesh, s), case.in_specs,
            is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
        jf = jax.jit(case.fn, in_shardings=shardings,
                     donate_argnums=case.donate)
        if use_hints:
            with hints_mod.hints(data_axes(mesh), "model",
                                 seq_parallel=seq_parallel):
                lowered = jf.lower(*case.args)
        else:
            lowered = jf.lower(*case.args)
        return lowered.compile()


def _probe_costs(case_builder, mesh, use_hints: bool = False,
                 seq_parallel: bool = False) -> dict:
    """Loop-aware cost reconstruction.  cost_analysis counts each scan
    body ONCE; shallow fully-unrolled probes recover per-period (and, for
    prefill, per-chunk) costs exactly:

      train/decode:  f(d)   = A + d*E              probes d=1,2
      prefill:       f(d,k) = A + d*E + (k-1)*B + (k-1)*d*C
                                                   probes (1,1)(2,1)(1,2)(2,2)

    Returns corrected {flops, bytes, collective} per device."""

    def measure(d, k):
        case = case_builder(d, k)
        comp = _compile_case(case, mesh, use_hints=use_hints,
                             seq_parallel=seq_parallel)
        cost = comp.cost_analysis()
        return {
            "flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": float(collective_bytes_from_hlo(comp.as_text())),
        }

    probe_case = case_builder(1, 1)   # built (not compiled) for metadata
    cfg = get_config(probe_case.arch)
    D = true_periods(cfg)
    info = SHAPES[probe_case.shape]
    if probe_case.kind == "prefill":
        K = (info["seq"] if cfg.family != "vlm"
             else (info["seq"] - 4096)) // info["chunk"]
        f11, f21 = measure(1, 1), measure(2, 1)
        f12, f22 = measure(1, 2), measure(2, 2)
        out = {}
        for key in ("flops", "bytes", "coll"):
            # clamp increments at 0: XLA occasionally optimizes the d=2
            # probe below d=1 (CSE across unrolled periods), which would
            # extrapolate negative
            E = max(f21[key] - f11[key], 0.0)
            C = max(f22[key] - f12[key] - E, 0.0)
            B = max(f12[key] - f11[key] - C, 0.0)
            A = max(f11[key] - E, 0.0)
            out[key] = max(A + D * E + (K - 1) * B + (K - 1) * D * C,
                           f11[key])
        return out
    f1, f2 = measure(1, 1), measure(2, 1)
    out = {}
    for key in ("flops", "bytes", "coll"):
        E = max(f2[key] - f1[key], 0.0)
        A = max(f1[key] - E, 0.0)
        out[key] = max(A + D * E, f1[key])
    return out


def run_case(arch: str, shape: str, multi_pod: bool,
             verbose: bool = True, probes: bool = True,
             use_hints: bool = True) -> dict:
    """Head-axis sharding constraints (hints) are applied where the
    §Perf measurements showed them to win: prefill (removes partial-sum
    score all-reduces, up to 10x collective reduction) and FSDP training
    (stops XLA hoisting expert-weight gathers).  They are OFF for decode
    and non-FSDP training, where padding small head counts regressed
    collectives/memory (EXPERIMENTS.md §Perf, promoted-optimizations
    note).  Un-hinted baselines: runs/dryrun_baseline."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    case = build_case(arch, shape, mesh)
    if use_hints:
        use_hints = (case.kind == "prefill"
                     or (case.kind == "train" and "fsdp" in case.note))
    t0 = time.time()
    compiled = _compile_case(case, mesh, use_hints=use_hints)
    t_compile = time.time() - t0
    t_lower = 0.0
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes_from_hlo(hlo)
    n_dev = mesh.devices.size
    corrected = None
    if probes:
        kind = SHAPES[shape]["kind"]

        def builder(d, k):
            return build_case(
                arch, shape, mesh, fsdp=("fsdp" in case.note),
                cfg=probe_cfg(get_config(arch), d),
                prefill_chunks=(k if kind == "prefill" else None))

        corrected = _probe_costs(builder, mesh, use_hints=use_hints)
    rec = {
        "arch": arch, "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": int(n_dev),
        "kind": case.kind, "note": case.note,
        "compile_s": round(t_compile, 1),
        # memory_analysis is per-device on the SPMD module
        "arg_bytes": int(mem.argument_size_in_bytes),
        "out_bytes": int(mem.output_size_in_bytes),
        "temp_bytes": int(mem.temp_size_in_bytes),
        "alias_bytes": int(mem.alias_size_in_bytes),
        "peak_bytes_per_device": int(mem.argument_size_in_bytes
                                     + mem.output_size_in_bytes
                                     + mem.temp_size_in_bytes
                                     - mem.alias_size_in_bytes),
        # raw cost_analysis (scan bodies counted ONCE — see probes)
        "raw_flops_per_device": float(cost.get("flops", 0.0)),
        "raw_bytes_per_device": float(cost.get("bytes accessed", 0.0)),
        "raw_collective_bytes_per_device": coll,
    }
    if corrected is not None:
        # loop-aware reconstruction (per-device)
        rec["flops_per_device"] = corrected["flops"]
        rec["hlo_bytes_accessed_per_device"] = corrected["bytes"]
        rec["collective_bytes_per_device"] = corrected["coll"]
        rec["cost_method"] = "probe-corrected"
    else:
        rec["flops_per_device"] = rec["raw_flops_per_device"]
        rec["hlo_bytes_accessed_per_device"] = rec["raw_bytes_per_device"]
        rec["collective_bytes_per_device"] = coll
        rec["cost_method"] = "raw"
    rec["roofline"] = roofline_report(rec)
    if verbose:
        print(json.dumps(rec, indent=2))
    return rec


def save(rec: dict):
    os.makedirs(RUNS_DIR, exist_ok=True)
    name = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.json"
    with open(os.path.join(RUNS_DIR, name), "w") as f:
        json.dump(rec, f, indent=2)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ASSIGNED)
    ap.add_argument("--shape", choices=sorted(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    cases = []
    archs = ASSIGNED if (args.all or not args.arch) else [args.arch]
    shapes = sorted(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    ok = fail = skip = 0
    for arch in archs:
        for shape in shapes:
            app, why = applicable(arch, shape)
            if not app:
                print(f"SKIP {arch} x {shape}: {why}")
                skip += 1
                continue
            for mp in meshes:
                mname = "2x16x16" if mp else "16x16"
                path = os.path.join(
                    RUNS_DIR, f"{arch}__{shape}__{mname}.json")
                if args.skip_existing and os.path.exists(path):
                    ok += 1
                    continue
                tag = f"{arch} x {shape} x {mname}"
                try:
                    t0 = time.time()
                    # roofline probes on the single-pod mesh only (the
                    # multi-pod pass proves the pod axis lowers/compiles)
                    rec = run_case(arch, shape, mp, verbose=False,
                                   probes=not mp)
                    save(rec)
                    dom = rec["roofline"]["dominant"]
                    print(f"OK   {tag}: peak/dev="
                          f"{rec['peak_bytes_per_device']/2**30:.2f}GiB "
                          f"dominant={dom} ({time.time()-t0:.0f}s)")
                    ok += 1
                except Exception as e:
                    print(f"FAIL {tag}: {type(e).__name__}: {e}")
                    traceback.print_exc()
                    fail += 1
    print(f"\ndry-run complete: {ok} ok, {fail} failed, {skip} skipped")
    if fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
