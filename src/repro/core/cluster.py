"""Cluster orchestration: a discrete-event loop shared by the simulator
(SimExecutor + estimator time) and the real engine (JaxExecutor + the
same estimator time base, so scheduling behaves identically while tokens
are computed for real).

Events: ARRIVAL (proxy routes prefill), ITER (an instance executes one
mixed batch), TRANSFER (a KV/state migration lands).  Migration latency
is charged via CostModel.transfer_time — asynchronous, off the critical
path, as in the paper's vLLM implementation (§3.5).

The loop is INCREMENTAL: ``submit`` enqueues an arrival, ``step``
processes exactly one event, and ``peek_time`` exposes the next event
time — the online serving runtime (``repro.serving``) drives these
directly, ingesting open-loop arrivals as they occur instead of a
pre-materialized list.  ``run`` is the batch convenience wrapper the
simulator and benchmarks use.

Role reconfiguration (drain-and-flip): ``request_role_flip`` stages a
P-heavy<->D-heavy flip on an instance; its decode population is migrated
away through the ordinary TRANSFER machinery (no in-flight request
dropped) and the flip lands once the decode side is empty.

Async execution (``async_exec=True``): each ITER splits into a DISPATCH
(the instance hands the plan to its executor's non-blocking
``step_async`` and the cluster schedules a COMMIT at the modeled end
time) and a COMMIT (the single host readback, bookkeeping, then —
before the host spends time streaming the tokens — the NEXT iteration
is dispatched inline, so the device computes horizon N+1 while the host
consumes horizon N).  Migrations, drains, and flips all run in an
instance's commit phase, i.e. with its pipeline flushed — an eject can
never observe a half-applied horizon.
"""
from __future__ import annotations

import heapq
import itertools
from typing import Callable, Dict, List, Optional, Sequence

from repro.core.estimator import CostModel
from repro.core.instance import D_HEAVY, Instance
from repro.core.latency import SLO, RunStats
from repro.core.policies import BasePolicy
from repro.engine.request import Request, State

ARRIVAL, ITER, TRANSFER, COMMIT = 0, 1, 2, 3


class Cluster:
    def __init__(self, policy: BasePolicy, cost: CostModel,
                 async_exec: bool = False):
        self.async_exec = async_exec
        self.policy = policy
        self.cost = cost
        self.instances = policy.instances
        self._heap: list = []
        self._seq = itertools.count()
        self._inst_by_id = {i.iid: i for i in self.instances}
        self._iter_scheduled: Dict[int, bool] = {
            i.iid: False for i in self.instances}
        self.now = 0.0
        self.transfer_count = 0
        self.transfer_bytes = 0
        self.replication_count = 0
        self.replication_bytes = 0
        self.backflow_count = 0
        self.degrade_count = 0
        self.drain_count = 0
        # observer hooks for the online serving loop (None in batch mode)
        self.on_finish: Optional[Callable[[Request, float], None]] = None
        self.on_reject: Optional[Callable[[Request, float], None]] = None

    # ------------------------------------------------------------------
    def _push(self, t: float, kind: int, data):
        heapq.heappush(self._heap, (t, next(self._seq), kind, data))

    def _schedule_iter(self, inst: Instance, t: float):
        if not self._iter_scheduled[inst.iid]:
            self._iter_scheduled[inst.iid] = True
            self._push(max(t, inst.busy_until), ITER, inst.iid)

    def _start_transfer(self, req: Request, src: Instance, dst: Instance,
                        now: float, kind: str):
        """kind: 'place' (prefill->decode), 'degrade', 'backflow', or
        'drain' (decode evacuation ahead of a role flip)."""
        # prefix-aware migration: when the destination already caches a
        # prefix of the request's prompt, only the non-shared suffix
        # ships (the landed state aliases the cached blocks)
        shared = dst.peek_migration_prefix(req)
        state = src.eject(req)
        req.state = State.MIGRATING
        req.n_migrations += 1
        moved = max(req.context_len - shared, 0)
        t = self.cost.transfer_time(moved)
        self.transfer_count += 1
        self.transfer_bytes += self.cost.state_bytes(moved)
        self._push(now + t, TRANSFER, (req, dst, state, kind))

    def replicate_prefix(self, src: Instance, dst: Instance,
                         tokens, now: Optional[float] = None) -> bool:
        """Ship a hot cached prefix from ``src`` to ``dst`` through the
        ordinary TRANSFER machinery — block-granular, no request
        attached, charged at migration bandwidth but entirely off the
        critical path (the destination keeps serving while it lands)."""
        state = src.export_prefix(tokens)
        if state is None:
            return False
        now = self.now if now is None else now
        moved = state["n_blocks"] * src.prefix_cache.block_size
        t = self.cost.transfer_time(moved)
        self.replication_count += 1
        self.replication_bytes += self.cost.state_bytes(moved)
        self._push(now + t, TRANSFER, (None, dst, state, "replicate"))
        return True

    # ------------------------------------------------------------------
    # incremental interface (driven by repro.serving.server)
    # ------------------------------------------------------------------
    def submit(self, req: Request, t: Optional[float] = None):
        """Enqueue one arrival.  Online ingestion: the serving loop calls
        this as requests show up; the batch ``run`` calls it up front."""
        self._push(req.arrival if t is None else t, ARRIVAL, req)

    def reroute(self, req: Request):
        """Route a queued-but-unadmitted request again NOW, with full
        ARRIVAL semantics (including early rejection and its observer
        hook) — used when its original placement loses the ability to
        serve it (e.g. the controller zeroes an instance's chunk)."""
        self._handle(self.now, ARRIVAL, req)

    def peek_time(self) -> Optional[float]:
        return self._heap[0][0] if self._heap else None

    def step(self) -> Optional[tuple]:
        """Pop and process exactly one event.  Returns ``(time, kind,
        data)`` for observability, or None when the heap is empty."""
        if not self._heap:
            return None
        now, _, kind, data = heapq.heappop(self._heap)
        self.now = max(self.now, now)
        self._handle(now, kind, data)
        return now, kind, data

    def _handle(self, now: float, kind: int, data):
        if kind == ARRIVAL:
            inst = self.policy.on_arrival(data, now)
            if inst is None:               # early rejection
                data.state = State.REJECTED
                data.finish_time = now
                if self.on_reject is not None:
                    self.on_reject(data, now)
                return
            self._schedule_iter(inst, now)
        elif kind == TRANSFER:
            req, dst, state, move_kind = data
            if move_kind == "replicate":
                # no request rides along: the payload lands straight
                # into the destination's cache tiers (best effort —
                # a full pool admits nothing rather than evicting)
                dst.replicate_in(state)
                return
            dst.inject(req, state)
            if move_kind == "backflow":
                req.reset_tpot_window()
                self.backflow_count += 1
            elif move_kind == "degrade":
                self.degrade_count += 1
            elif move_kind == "drain":
                self.drain_count += 1
            self._schedule_iter(dst, now)
        elif kind == COMMIT:
            self._commit(self._inst_by_id[data], now)
        else:  # ITER
            inst = self._inst_by_id[data]
            self._iter_scheduled[inst.iid] = False
            if self.async_exec \
                    and getattr(inst.executor, "step_async", None):
                self._dispatch(inst, now)
                return
            dur, prefill_done, finished = inst.run_iteration(now)
            end = now + dur
            if self.on_finish is not None:
                for req in finished:
                    # a request EOSing mid-horizon finished at its last
                    # token's per-step time, not the horizon end — same
                    # timestamping as the async commit path
                    self.on_finish(req, req.finish_time
                                   if req.finish_time is not None else end)
            self._post_iteration(inst, end, dur, prefill_done)

    def _post_iteration(self, inst: Instance, end: float, dur: float,
                        prefill_done, reschedule: bool = True):
        """Scheduling phase shared by the synchronous ITER and the async
        COMMIT: route finished prefills, run Algorithm 1's migration
        selection, advance a staged drain, and (optionally) reschedule
        the instance."""
        for req in prefill_done:
            target, needs_transfer = self.policy.on_prefill_done(
                req, inst, end)
            if needs_transfer:
                self._start_transfer(req, inst, target, end, "place")
            else:
                target.admit_decode(req)
                self._schedule_iter(target, end)
        for (req, src, dst, is_backflow) in (
                self.policy.select_migrations(end, inst)):
            self._start_transfer(req, src, dst, end,
                                 "backflow" if is_backflow
                                 else "degrade")
            self._schedule_iter(dst, end)
        if inst.pending_flip is not None:
            self._drain_step(inst, end)
        if reschedule and inst.has_work():
            if dur == 0.0:
                # nothing schedulable this tick (e.g. oversized
                # head-of-line request): back off instead of
                # spinning at the same timestamp
                self._schedule_iter(inst, end + 0.01)
            else:
                self._schedule_iter(inst, end)

    # ------------------------------------------------------------------
    # async pipeline: dispatch / commit event halves
    # ------------------------------------------------------------------
    def _dispatch(self, inst: Instance, now: float):
        dur = inst.dispatch_iteration(now)
        if dur is None:
            if inst.has_work():
                # nothing schedulable (oversized head-of-line): back off
                self._schedule_iter(inst, now + 0.01)
            return
        # hold the scheduled flag through the flight so arrivals and
        # transfers cannot double-dispatch; the commit rearms it
        self._iter_scheduled[inst.iid] = True
        self._push(now + dur, COMMIT, inst.iid)

    def _commit(self, inst: Instance, now: float):
        res = inst.commit_iteration(defer_emit=True)
        self._iter_scheduled[inst.iid] = False
        # scheduling first (migrations/drains run against a flushed
        # pipeline), then dispatch the NEXT iteration inline so the
        # device starts horizon N+1 before the host streams horizon N
        self._post_iteration(inst, now, res.duration, res.prefill_done,
                             reschedule=False)
        if inst.has_work() and not self._iter_scheduled[inst.iid]:
            if res.duration == 0.0:
                self._schedule_iter(inst, now + 0.01)
            else:
                self._handle(now, ITER, inst.iid)
        for req, t in res.token_events:
            inst.token_sink(req, t)
        if self.on_finish is not None:
            for req in res.finished:
                self.on_finish(req, req.finish_time
                               if req.finish_time is not None else now)

    # ------------------------------------------------------------------
    def set_horizon(self, max_horizon: int):
        """Set every instance's decode-horizon cap (1 = classic
        single-step iterations).  Instances still shrink K adaptively —
        this is the ceiling, not the operating point."""
        for inst in self.instances:
            inst.max_horizon = max_horizon

    # ------------------------------------------------------------------
    # drain-and-flip role reconfiguration
    # ------------------------------------------------------------------
    def request_role_flip(self, inst: Instance, itype: str,
                          chunk_size: int) -> bool:
        """Stage a role flip; decode residents are evacuated through the
        migration machinery over the following iterations and the flip
        lands once the instance's decode side is empty.  Returns True if
        the flip was staged (or applied immediately)."""
        if inst.pending_flip is not None:
            return False
        inst.begin_flip(itype, chunk_size)
        if not inst.apply_flip():          # something to drain
            self._schedule_iter(inst, self.now)
        return True

    def _drain_step(self, inst: Instance, now: float):
        """Migrate a draining instance's decode residents to the least
        decode-loaded non-draining instance, then land the flip."""
        for req in inst.drain_candidates():
            if req.state == State.MIGRATING:
                continue
            dst = self._drain_destination(inst)
            if dst is None:
                break                      # nowhere to go: retry next iter
            self._start_transfer(req, inst, dst, now, "drain")
            self._schedule_iter(dst, now)
        inst.apply_flip()

    def _drain_destination(self, inst: Instance) -> Optional[Instance]:
        cands = [i for i in self.instances
                 if i is not inst and not i.draining]
        if not cands:
            return None
        # decodes prefer a D-heavy home; fall back to any peer
        d = [i for i in cands if i.itype == D_HEAVY]
        return min(d or cands, key=lambda i: i.decode_load())

    # ------------------------------------------------------------------
    def run(self, requests: Sequence[Request], until: Optional[float] = None
            ) -> List[Request]:
        for r in requests:
            self.submit(r)
        while self._heap:
            if until is not None and self.peek_time() > until:
                break
            self.step()
        return list(requests)

    # ------------------------------------------------------------------
    def stats(self, requests, slo: SLO, qps: float) -> RunStats:
        wall = max(((r.finish_time or 0.0) for r in requests), default=0.0)
        return RunStats(
            list(requests), slo, qps, wall,
            cache_lookups=sum(i.cache_lookups for i in self.instances),
            cache_hits=sum(i.cache_hits for i in self.instances),
            saved_prefill_tokens=sum(i.cached_prefill_tokens
                                     for i in self.instances),
            early_rejections=getattr(self.policy.proxy, "rejected_count", 0),
            role_flips=self.role_flip_count)

    @property
    def role_flip_count(self) -> int:
        """Landed flips, from the per-instance ground truth."""
        return sum(i.role_flips for i in self.instances)
