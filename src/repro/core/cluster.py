"""Cluster orchestration: a discrete-event loop shared by the simulator
(SimExecutor + estimator time) and the real engine (JaxExecutor + the
same estimator time base, so scheduling behaves identically while tokens
are computed for real).

Events: ARRIVAL (proxy routes prefill), ITER (an instance executes one
mixed batch), TRANSFER (a KV/state migration lands).  Migration latency
is charged via CostModel.transfer_time — asynchronous, off the critical
path, as in the paper's vLLM implementation (§3.5).

The loop is INCREMENTAL: ``submit`` enqueues an arrival, ``step``
processes exactly one event, and ``peek_time`` exposes the next event
time — the online serving runtime (``repro.serving``) drives these
directly, ingesting open-loop arrivals as they occur instead of a
pre-materialized list.  ``run`` is the batch convenience wrapper the
simulator and benchmarks use.

Role reconfiguration (drain-and-flip): ``request_role_flip`` stages a
P-heavy<->D-heavy flip on an instance; its decode population is migrated
away through the ordinary TRANSFER machinery (no in-flight request
dropped) and the flip lands once the decode side is empty.

Async execution (``async_exec=True``): each ITER splits into a DISPATCH
(the instance hands the plan to its executor's non-blocking
``step_async`` and the cluster schedules a COMMIT at the modeled end
time) and a COMMIT (the single host readback, bookkeeping, then —
before the host spends time streaming the tokens — the NEXT iteration
is dispatched inline, so the device computes horizon N+1 while the host
consumes horizon N).  Migrations, drains, and flips all run in an
instance's commit phase, i.e. with its pipeline flushed — an eject can
never observe a half-applied horizon.

Fault tolerance: ``fail_instance`` (crash, total HBM/KV loss) and
``quarantine_instance`` (suspected-bad, memory kept) evacuate every
resident request through the preemption-by-recompute path and re-route
it via the proxy; dead/quarantined instances are excluded from
placement and migration destinations exactly like draining ones.
TRANSFER landings verify a content hash and retry with capped
exponential backoff, falling back to recompute when retries exhaust.
An attached ``FaultInjector`` (``attach_faults``) fires scheduled
crash/stall/exec-error faults as first-class FAULT events.  With no
injector attached and no faults raised, every path below is inert —
behavior is bit-identical to the fault-free cluster.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Callable, Dict, List, Optional, Sequence

from repro.core.estimator import CostModel
from repro.core.instance import (D_HEAVY, HEALTH_DEAD, HEALTH_OK,
                                 HEALTH_QUARANTINED, Instance)
from repro.core.latency import SLO, RunStats
from repro.core.policies import BasePolicy
from repro.engine.request import Request, State, TERMINAL_STATES
from repro.serving import faults as flt
from repro.serving.recovery import RecoveryConfig, RecoveryManager
from repro.serving.tracing import (PH_DECODE_WAIT, PH_QUEUE, PH_TRANSFER,
                                   Tracer)

ARRIVAL, ITER, TRANSFER, COMMIT, FAULT = 0, 1, 2, 3, 4


@dataclasses.dataclass
class FaultToleranceConfig:
    """Recovery behavior knobs.  The defaults recover; ``fail_stop()``
    is the ablation baseline where faults terminally fail their
    victims (what the chaos bench compares against)."""
    evacuate: bool = True            # crash/quarantine victims re-route
    transfer_max_retries: int = 3    # re-sends before giving up
    transfer_backoff: float = 0.05   # base delay, doubles per attempt
    transfer_backoff_cap: float = 0.8
    recompute_fallback: bool = True  # exhausted transfer -> re-prefill
    verify_transfers: bool = True    # content-hash check at landing
    max_recoveries: int = 5          # per-request bound -> FAILED

    @classmethod
    def fail_stop(cls) -> "FaultToleranceConfig":
        return cls(evacuate=False, transfer_max_retries=0,
                   recompute_fallback=False)


class Cluster:
    #: class-level fallback so partially-constructed clusters (tests
    #: stubbing via ``__new__``) still see default recovery knobs
    ft: FaultToleranceConfig = FaultToleranceConfig()
    faults: Optional[flt.FaultInjector] = None
    #: request-lifecycle tracer (wired by ``ServingLoop(tracing=...)``;
    #: None = every tracing site below is inert)
    tracer: Optional[Tracer] = None
    #: warm-recovery manager (checkpoints + post-crash re-replication);
    #: None = every recovery site below is inert
    recovery: Optional[RecoveryManager] = None

    def __init__(self, policy: BasePolicy, cost: CostModel,
                 async_exec: bool = False,
                 ft: Optional[FaultToleranceConfig] = None,
                 recovery=None):
        self.async_exec = async_exec
        self.policy = policy
        self.cost = cost
        self.instances = policy.instances
        self._heap: list = []
        self._seq = itertools.count()
        self._inst_by_id = {i.iid: i for i in self.instances}
        self._iter_scheduled: Dict[int, bool] = {
            i.iid: False for i in self.instances}
        self.now = 0.0
        self.transfer_count = 0
        self.transfer_bytes = 0
        self.replication_count = 0
        self.replication_bytes = 0
        self.backflow_count = 0
        self.degrade_count = 0
        self.drain_count = 0
        # observer hooks for the online serving loop (None in batch mode)
        self.on_finish: Optional[Callable[[Request, float], None]] = None
        self.on_reject: Optional[Callable[[Request, float], None]] = None
        self.on_failed: Optional[Callable[[Request, float], None]] = None
        self.on_abort: Optional[Callable[[Request, float], None]] = None
        # fault tolerance
        self.ft = ft or FaultToleranceConfig()
        self.faults: Optional[flt.FaultInjector] = None
        # warm recovery: accept a RecoveryConfig or a prebuilt manager;
        # a disabled config leaves the attribute None so every hook
        # below short-circuits (bit-identical to recovery-less runs)
        if isinstance(recovery, RecoveryConfig):
            recovery = RecoveryManager(recovery) if recovery.enable \
                else None
        self.recovery: Optional[RecoveryManager] = recovery
        self._aborting: Dict[int, Request] = {}
        self.instance_failures = 0
        self.instance_recoveries = 0
        self.quarantines = 0
        self.evacuated_requests = 0
        self.transfer_retries = 0
        self.transfer_corruptions = 0
        self.transfer_recomputes = 0
        self.exec_errors = 0
        self.failed_count = 0
        self.aborted_count = 0
        self.last_exec_error: Optional[str] = None

    # ------------------------------------------------------------------
    def _push(self, t: float, kind: int, data):
        heapq.heappush(self._heap, (t, next(self._seq), kind, data))

    def _schedule_iter(self, inst: Instance, t: float):
        if inst.health != HEALTH_OK:
            return
        if not self._iter_scheduled[inst.iid]:
            self._iter_scheduled[inst.iid] = True
            self._push(max(t, inst.busy_until), ITER, inst.iid)

    def _start_transfer(self, req: Request, src: Instance, dst: Instance,
                        now: float, kind: str):
        """kind: 'place' (prefill->decode), 'degrade', 'backflow', or
        'drain' (decode evacuation ahead of a role flip)."""
        # prefix-aware migration: when the destination already caches a
        # prefix of the request's prompt, only the non-shared suffix
        # ships (the landed state aliases the cached blocks)
        shared = dst.peek_migration_prefix(req)
        state = src.eject(req)
        req.state = State.MIGRATING
        req.n_migrations += 1
        moved = max(req.context_len - shared, 0)
        t = self.cost.transfer_time(moved)
        if self.tracer is not None:
            self.tracer.phase(req.rid, now, PH_TRANSFER, kind=kind,
                              src=src.iid, dst=dst.iid, tokens=moved)
        self.transfer_count += 1
        self.transfer_bytes += self.cost.state_bytes(moved)
        checksum = (flt.payload_checksum(state)
                    if self.ft.verify_transfers else None)
        self._push(now + t, TRANSFER,
                   (req, dst, state, kind,
                    {"attempt": 0, "checksum": checksum, "delay": t}))

    def replicate_prefix(self, src: Instance, dst: Instance,
                         tokens, now: Optional[float] = None) -> bool:
        """Ship a hot cached prefix from ``src`` to ``dst`` through the
        ordinary TRANSFER machinery — block-granular, no request
        attached, charged at migration bandwidth but entirely off the
        critical path (the destination keeps serving while it lands)."""
        state = src.export_prefix(tokens)
        if state is None:
            return False
        now = self.now if now is None else now
        moved = state["n_blocks"] * src.prefix_cache.block_size
        t = self.cost.transfer_time(moved)
        self.replication_count += 1
        self.replication_bytes += self.cost.state_bytes(moved)
        self._push(now + t, TRANSFER,
                   (None, dst, state, "replicate",
                    {"attempt": 0, "checksum": None, "delay": t,
                     "src": src.iid}))
        return True

    # ------------------------------------------------------------------
    # incremental interface (driven by repro.serving.server)
    # ------------------------------------------------------------------
    def submit(self, req: Request, t: Optional[float] = None):
        """Enqueue one arrival.  Online ingestion: the serving loop calls
        this as requests show up; the batch ``run`` calls it up front."""
        self._push(req.arrival if t is None else t, ARRIVAL, req)

    def reroute(self, req: Request):
        """Route a queued-but-unadmitted request again NOW, with full
        ARRIVAL semantics (including early rejection and its observer
        hook) — used when its original placement loses the ability to
        serve it (e.g. the controller zeroes an instance's chunk)."""
        self._handle(self.now, ARRIVAL, req)

    def peek_time(self) -> Optional[float]:
        return self._heap[0][0] if self._heap else None

    def peek_event(self) -> Optional[tuple]:
        """Next event as ``(time, kind, data)`` without popping — the
        serving loop's wall-clock watchdog inspects an imminent COMMIT
        to decide whether its ``PendingStep`` is overdue."""
        if not self._heap:
            return None
        t, _, kind, data = self._heap[0]
        return t, kind, data

    def step(self) -> Optional[tuple]:
        """Pop and process exactly one event.  Returns ``(time, kind,
        data)`` for observability, or None when the heap is empty."""
        if not self._heap:
            return None
        now, _, kind, data = heapq.heappop(self._heap)
        self.now = max(self.now, now)
        self._handle(now, kind, data)
        if self._aborting:
            self._sweep_aborts(self.now)
        return now, kind, data

    def _handle(self, now: float, kind: int, data):
        if kind == ARRIVAL:
            self._handle_arrival(data, now)
        elif kind == TRANSFER:
            self._handle_transfer(data, now)
        elif kind == COMMIT:
            inst = self._inst_by_id[data]
            if not inst.has_inflight():
                # the in-flight iteration was discarded by a failure or
                # quarantine between dispatch and commit: stale event
                self._iter_scheduled[inst.iid] = False
                if inst.has_work():
                    self._schedule_iter(inst, now)
                return
            try:
                self._commit(inst, now)
            except Exception as e:         # device/readback failure
                self._on_exec_error(inst, now, e)
        elif kind == FAULT:
            self._handle_fault(data, now)
        else:  # ITER
            inst = self._inst_by_id[data]
            self._iter_scheduled[inst.iid] = False
            if inst.health != HEALTH_OK:
                return                     # stale event for a downed peer
            try:
                self._run_iter(inst, now)
            except Exception as e:         # executor-step failure
                self._on_exec_error(inst, now, e)

    def _handle_arrival(self, req: Request, now: float):
        if req.rid in self._aborting:      # client hung up before routing
            self._finish_abort(req, now)
            return
        inst = self.policy.on_arrival(req, now)
        if inst is not None:
            if self.tracer is not None:
                self.tracer.event(req.rid, now, "route", iid=inst.iid)
            self._schedule_iter(inst, now)
            return
        recovered = req.n_recoveries > 0 or req.first_token_time is not None
        capacity = any(i.schedulable and i.chunk_size > 0
                       for i in self.instances)
        if recovered and capacity:
            # a recovered request must not be early-rejected — the
            # client may already have streamed its tokens.  Force-place
            # on the least prefill-queued healthy instance.
            inst = min((i for i in self.instances
                        if i.schedulable and i.chunk_size > 0),
                       key=lambda i: i.queued_prefill_tokens())
            inst.enqueue_prefill(req)
            if self.tracer is not None:
                self.tracer.event(req.rid, now, "route", iid=inst.iid,
                                  forced=True)
            self._schedule_iter(inst, now)
            return
        if not capacity:
            self._fail_request(req, now, "no_capacity")
            return
        req.state = State.REJECTED         # early rejection
        req.finish_time = now
        if self.on_reject is not None:
            self.on_reject(req, now)

    def _handle_transfer(self, data, now: float):
        req, dst, state, move_kind, meta = data
        if move_kind == "replicate":
            # no request rides along: the payload lands straight into
            # the destination's cache tiers (best effort — a full pool
            # admits nothing rather than evicting, and a dropped or
            # corrupted replica simply never lands)
            if self._transfer_outcome() == flt.DELIVER \
                    and dst.health == HEALTH_OK:
                dst.replicate_in(state)
                if self.recovery is not None:
                    # replica-placement registry: a crashed holder's
                    # paths re-replicate immediately instead of waiting
                    # for the controller's next epoch
                    self.recovery.on_replica_landed(
                        state["tokens"], meta.get("src"), dst.iid)
            return
        if req.rid in self._aborting:      # client hung up mid-flight
            self._finish_abort(req, now)
            return
        if dst.health != HEALTH_OK:
            # destination died while the payload was on the wire: the
            # KV exists nowhere anymore — recompute elsewhere
            self._recover_by_recompute(req, now, "transfer_dst_down")
            return
        outcome = self._transfer_outcome()
        if outcome == flt.CORRUPT:
            self.transfer_corruptions += 1
            if self.ft.verify_transfers:
                self._retry_transfer(data, now)
                return
            # unverified corruption would decode garbage — model it as
            # a delivery (tokens diverge on a real wire; the sim has no
            # payload bits to flip) and let the counter tell the story
        elif outcome == flt.DROP:
            self._retry_transfer(data, now)
            return
        elif meta.get("checksum") is not None and self.ft.verify_transfers \
                and flt.payload_checksum(state) != meta["checksum"]:
            # real corruption (bit-flip in the payload itself)
            self.transfer_corruptions += 1
            self._retry_transfer(data, now)
            return
        dst.inject(req, state)
        if self.tracer is not None:
            self.tracer.phase(req.rid, now, PH_DECODE_WAIT,
                              iid=dst.iid, via=move_kind)
        if move_kind == "backflow":
            req.reset_tpot_window()
            self.backflow_count += 1
        elif move_kind == "degrade":
            self.degrade_count += 1
        elif move_kind == "drain":
            self.drain_count += 1
        self._schedule_iter(dst, now)

    def _run_iter(self, inst: Instance, now: float):
        if self.async_exec \
                and getattr(inst.executor, "step_async", None):
            self._dispatch(inst, now)
            return
        dur, prefill_done, finished = inst.run_iteration(now)
        end = now + dur
        if self.recovery is not None:
            for req in finished:
                self.recovery.drop(req.rid)
        if self.on_finish is not None:
            for req in finished:
                # a request EOSing mid-horizon finished at its last
                # token's per-step time, not the horizon end — same
                # timestamping as the async commit path
                self.on_finish(req, req.finish_time
                               if req.finish_time is not None else end)
        self._post_iteration(inst, end, dur, prefill_done)

    # ------------------------------------------------------------------
    # fault tolerance: injection, failure, recovery, abort
    # ------------------------------------------------------------------
    def attach_faults(self, injector: flt.FaultInjector):
        """Bind a fault injector: every scheduled fault becomes a FAULT
        event at its exact time; transfer landings consult the
        injector's drop/corrupt probabilities."""
        self.faults = injector
        for f in injector.schedule:
            self._push(f.t, FAULT, f)

    def _transfer_outcome(self) -> str:
        if self.faults is None:
            return flt.DELIVER
        return self.faults.transfer_outcome()

    def _handle_fault(self, fault: flt.Fault, now: float):
        inst = self._inst_by_id.get(fault.iid)
        if inst is None:
            return
        if self.faults is not None:
            self.faults.record(fault)
        if fault.kind == flt.CRASH:
            self.fail_instance(inst, now, reason="injected_crash")
        elif fault.kind == flt.STALL:
            inst.stall_until = max(inst.stall_until, now + fault.duration)
        elif fault.kind == flt.EXEC_ERROR:
            injector = self.faults or flt.FaultInjector()
            injector.arm_exec_error(inst)
        elif fault.kind == flt.RECOVER:
            self.recover_instance(inst, now)

    def fail_instance(self, inst: Instance, now: Optional[float] = None,
                      reason: str = "crash") -> List[Request]:
        """Instance crash: total HBM/KV loss (prefix cache and host
        spill tier included).  Every resident request is evacuated and
        re-routed through preemption-by-recompute (``ft.evacuate``) or
        terminally FAILED (fail-stop).  Returns the victims."""
        now = self.now if now is None else now
        if inst.health == HEALTH_DEAD:
            return []
        inst.health = HEALTH_DEAD
        inst.fail_count += 1
        self.instance_failures += 1
        victims = inst.evacuate()
        inst.wipe_cache()
        self.evacuated_requests += len(victims)
        if self.tracer is not None:
            self.tracer.global_event(now, "instance_crash", iid=inst.iid,
                                     reason=reason, victims=len(victims))
        self._reroute_victims(victims, now, reason)
        if self.recovery is not None:
            self.recovery.on_instance_failed(self, inst, now)
        return victims

    def quarantine_instance(self, inst: Instance,
                            now: Optional[float] = None,
                            reason: str = "stall") -> List[Request]:
        """Suspected-bad instance (watchdog / exec error): excluded from
        placement like a dead one, but its memory survives — the
        watchdog's probation timer (or an explicit ``recover_instance``)
        re-admits it.  Residents are still evacuated: a quarantined
        instance runs no iterations, so keeping them would stall them
        for the whole probation."""
        now = self.now if now is None else now
        if inst.health != HEALTH_OK:
            return []
        inst.health = HEALTH_QUARANTINED
        inst.quarantine_count += 1
        self.quarantines += 1
        victims = inst.evacuate()
        self.evacuated_requests += len(victims)
        if self.tracer is not None:
            self.tracer.global_event(now, "instance_quarantined",
                                     iid=inst.iid, reason=reason,
                                     victims=len(victims))
        self._reroute_victims(victims, now, reason)
        return victims

    def recover_instance(self, inst: Instance,
                         now: Optional[float] = None) -> bool:
        """Bring a dead/quarantined instance back into rotation."""
        now = self.now if now is None else now
        if inst.health == HEALTH_OK:
            return False
        inst.health = HEALTH_OK
        inst.stall_until = 0.0
        inst.overrun = 0.0
        inst.last_progress = now
        inst.step_deadline = float("inf")
        self.instance_recoveries += 1
        if inst.has_work():
            self._schedule_iter(inst, now)
        return True

    def _reroute_victims(self, victims: Sequence[Request], now: float,
                         reason: str):
        for req in victims:
            if req.state in TERMINAL_STATES:
                continue
            if req.rid in self._aborting:
                self._finish_abort(req, now)
                continue
            if self.ft.evacuate:
                self._recover_by_recompute(req, now, reason)
            else:
                self._fail_request(req, now, f"instance_{reason}")

    def _recover_by_recompute(self, req: Request, now: float, reason: str):
        """Preemption-by-recompute over the ARRIVAL path: the request
        re-prefills its whole context (prompt + generated so far) on a
        healthy instance, token-exact via ``recompute_offset``."""
        req.n_recoveries += 1
        if req.n_recoveries > self.ft.max_recoveries:
            self._fail_request(req, now, "too_many_recoveries")
            return
        if not self.ft.recompute_fallback and reason.startswith("transfer"):
            self._fail_request(req, now, "transfer_failed")
            return
        req.recompute_offset = req.output_len
        # warm recovery: resume from the latest checkpoint instead of
        # recomputing from token 0 (the admitting instance consumes the
        # plan and may still fall back cold if it cannot host it)
        rs = (self.recovery.plan_restore(req)
              if self.recovery is not None else None)
        if rs is not None:
            req.restore_state = rs
            req.prefill_pos = rs["pos"] - req.output_len
        else:
            req.prefill_pos = -req.output_len
        req.state = State.QUEUED
        if self.tracer is not None:
            ekw = {"reason": reason, "n": req.n_recoveries}
            pkw = {"reason": reason}
            if rs is not None:           # keys only appear when warm, so
                ekw.update(warm=True,    # recovery-off traces stay
                           resumed_from=rs["pos"])  # bit-identical
                pkw.update(recovery="warm")
            self.tracer.event(req.rid, now, "recovery", **ekw)
            self.tracer.phase(req.rid, now, PH_QUEUE, **pkw)
        self._handle(now, ARRIVAL, req)

    def _retry_transfer(self, data, now: float):
        """Dropped or corrupted TRANSFER: re-send with capped
        exponential backoff; on exhaustion fall back to recompute (the
        source already ejected the state — only the payload in the
        event survives, so a re-send re-pushes the same payload)."""
        req, dst, state, move_kind, meta = data
        attempt = meta.get("attempt", 0)
        if attempt < self.ft.transfer_max_retries:
            self.transfer_retries += 1
            if self.faults is not None:
                # seeded decorrelated jitter: concurrent transfers that
                # failed together must not retry in lockstep (a capped
                # pure exponential re-synchronizes the storm).  Only
                # reachable with an injector attached — faults-off runs
                # never retry, so they stay bit-identical.
                delay = self.faults.retry_jitter(
                    self.ft.transfer_backoff,
                    meta.get("backoff", self.ft.transfer_backoff),
                    self.ft.transfer_backoff_cap)
            else:
                delay = min(self.ft.transfer_backoff * (2 ** attempt),
                            self.ft.transfer_backoff_cap)
            if self.tracer is not None and req is not None:
                self.tracer.event(req.rid, now, "transfer_retry",
                                  attempt=attempt + 1,
                                  delay_s=round(delay, 6))
            self._push(now + delay, TRANSFER,
                       (req, dst, state, move_kind,
                        {**meta, "attempt": attempt + 1,
                         "backoff": delay}))
            return
        if req is None:
            return                          # replicas are best-effort
        self.transfer_recomputes += 1
        self._recover_by_recompute(req, now, "transfer_exhausted")

    def _fail_request(self, req: Request, now: float, reason: str):
        req.state = State.FAILED
        req.finish_reason = reason
        req.finish_time = now
        self.failed_count += 1
        self._aborting.pop(req.rid, None)
        if self.recovery is not None:
            self.recovery.drop(req.rid)
        if self.on_failed is not None:
            self.on_failed(req, now)

    def _on_exec_error(self, inst: Instance, now: float, exc: Exception):
        """An executor step raised (injected or real device failure):
        quarantine the instance — its pipeline state is suspect — and
        evacuate.  The watchdog's probation re-admits it later."""
        self.exec_errors += 1
        self.last_exec_error = repr(exc)
        self.quarantine_instance(inst, now, reason="exec_error")

    # ---- request abort (client disconnect) ----------------------------
    def abort_request(self, req: Request, now: Optional[float] = None
                      ) -> bool:
        """Terminally cancel ``req`` wherever it lives, freeing its
        blocks and executor rows.  Only safe boundaries are touched
        directly — a request inside an in-flight iteration or riding a
        TRANSFER is marked and collected at the next commit/landing.
        Returns True when the abort resolved immediately."""
        now = self.now if now is None else now
        if req.state in TERMINAL_STATES:
            return True
        self._aborting[req.rid] = req
        return self._try_abort(req, now)

    def _try_abort(self, req: Request, now: float) -> bool:
        if req.state == State.MIGRATING:
            return False                   # collected at TRANSFER landing
        for inst in self.instances:
            if inst.has_inflight():
                plan = inst._inflight[0]
                if req in plan.decode_reqs \
                        or any(r is req for r, _ in plan.prefill_items):
                    return False           # collected after the commit
        holder = None
        for inst in self.instances:
            if (req.rid in inst.decoding or req in inst.pending_decode
                    or req in inst.prefill_queue):
                holder = inst
                break
        if holder is not None:
            holder.abort_request(req)
        elif req.state == State.QUEUED:
            return False                   # still an ARRIVAL in the heap
        self._finish_abort(req, now)
        return True

    def _finish_abort(self, req: Request, now: float):
        self._aborting.pop(req.rid, None)
        if req.state in TERMINAL_STATES:
            return
        req.state = State.CANCELLED
        req.finish_reason = "abort"
        req.finish_time = now
        self.aborted_count += 1
        if self.recovery is not None:
            self.recovery.drop(req.rid)
        if self.on_abort is not None:
            self.on_abort(req, now)

    def _sweep_aborts(self, now: float):
        for rid, req in list(self._aborting.items()):
            if req.state in TERMINAL_STATES:
                self._aborting.pop(rid, None)
                continue
            self._try_abort(req, now)

    def fault_counters(self) -> Dict[str, int]:
        return {
            "instance_failures": self.instance_failures,
            "instance_recoveries": self.instance_recoveries,
            "quarantines": self.quarantines,
            "evacuated_requests": self.evacuated_requests,
            "transfer_retries": self.transfer_retries,
            "transfer_corruptions": self.transfer_corruptions,
            "transfer_recomputes": self.transfer_recomputes,
            "exec_errors": self.exec_errors,
            "failed": self.failed_count,
            "aborted": self.aborted_count,
        }

    def recovery_counters(self) -> dict:
        """Warm-recovery observability: manager counters plus the warm
        restore/fallback tallies summed over instances."""
        out = (self.recovery.counters()
               if self.recovery is not None else {})
        out["warm_restores"] = sum(
            i.warm_restores for i in self.instances)
        out["warm_restored_tokens"] = sum(
            i.warm_restored_tokens for i in self.instances)
        out["warm_fallbacks"] = sum(
            i.warm_fallbacks for i in self.instances)
        return out

    def _post_iteration(self, inst: Instance, end: float, dur: float,
                        prefill_done, reschedule: bool = True):
        """Scheduling phase shared by the synchronous ITER and the async
        COMMIT: route finished prefills, run Algorithm 1's migration
        selection, advance a staged drain, and (optionally) reschedule
        the instance."""
        if self.recovery is not None:
            # capture here: both ITER and COMMIT reach this point with
            # the executor pipeline flushed, so exported KV is coherent
            self.recovery.on_commit(self, inst, end)
        for req in prefill_done:
            target, needs_transfer = self.policy.on_prefill_done(
                req, inst, end)
            if needs_transfer:
                self._start_transfer(req, inst, target, end, "place")
            else:
                target.admit_decode(req)
                if self.tracer is not None:
                    self.tracer.phase(req.rid, end, PH_DECODE_WAIT,
                                      iid=target.iid, via="local")
                self._schedule_iter(target, end)
        for (req, src, dst, is_backflow) in (
                self.policy.select_migrations(end, inst)):
            self._start_transfer(req, src, dst, end,
                                 "backflow" if is_backflow
                                 else "degrade")
            self._schedule_iter(dst, end)
        if inst.pending_flip is not None:
            self._drain_step(inst, end)
        if reschedule and inst.has_work():
            if dur == 0.0:
                # nothing schedulable this tick (e.g. oversized
                # head-of-line request): back off instead of
                # spinning at the same timestamp
                self._schedule_iter(inst, end + 0.01)
            else:
                self._schedule_iter(inst, end)

    # ------------------------------------------------------------------
    # async pipeline: dispatch / commit event halves
    # ------------------------------------------------------------------
    def _dispatch(self, inst: Instance, now: float):
        dur = inst.dispatch_iteration(now)
        if dur is None:
            if inst.has_work():
                # nothing schedulable (oversized head-of-line): back off
                self._schedule_iter(inst, now + 0.01)
            return
        # hold the scheduled flag through the flight so arrivals and
        # transfers cannot double-dispatch; the commit rearms it
        self._iter_scheduled[inst.iid] = True
        self._push(now + dur, COMMIT, inst.iid)

    def _commit(self, inst: Instance, now: float):
        res = inst.commit_iteration(defer_emit=True)
        self._iter_scheduled[inst.iid] = False
        # scheduling first (migrations/drains run against a flushed
        # pipeline), then dispatch the NEXT iteration inline so the
        # device starts horizon N+1 before the host streams horizon N
        self._post_iteration(inst, now, res.duration, res.prefill_done,
                             reschedule=False)
        if inst.has_work() and not self._iter_scheduled[inst.iid]:
            if res.duration == 0.0:
                self._schedule_iter(inst, now + 0.01)
            else:
                self._handle(now, ITER, inst.iid)
        for req, t in res.token_events:
            inst.token_sink(req, t)
        if self.recovery is not None:
            for req in res.finished:
                self.recovery.drop(req.rid)
        if self.on_finish is not None:
            for req in res.finished:
                self.on_finish(req, req.finish_time
                               if req.finish_time is not None else now)

    # ------------------------------------------------------------------
    def set_horizon(self, max_horizon: int):
        """Set every instance's decode-horizon cap (1 = classic
        single-step iterations).  Instances still shrink K adaptively —
        this is the ceiling, not the operating point."""
        for inst in self.instances:
            inst.max_horizon = max_horizon

    # ------------------------------------------------------------------
    # drain-and-flip role reconfiguration
    # ------------------------------------------------------------------
    def request_role_flip(self, inst: Instance, itype: str,
                          chunk_size: int) -> bool:
        """Stage a role flip; decode residents are evacuated through the
        migration machinery over the following iterations and the flip
        lands once the instance's decode side is empty.  Returns True if
        the flip was staged (or applied immediately)."""
        if inst.pending_flip is not None:
            return False
        if inst.health != HEALTH_OK:
            return False                   # no role changes on downed peers
        inst.begin_flip(itype, chunk_size)
        if not inst.apply_flip():          # something to drain
            self._schedule_iter(inst, self.now)
        return True

    def _drain_step(self, inst: Instance, now: float):
        """Migrate a draining instance's decode residents to the least
        decode-loaded non-draining instance, then land the flip."""
        for req in inst.drain_candidates():
            if req.state == State.MIGRATING:
                continue
            dst = self._drain_destination(inst)
            if dst is None:
                break                      # nowhere to go: retry next iter
            self._start_transfer(req, inst, dst, now, "drain")
            self._schedule_iter(dst, now)
        inst.apply_flip()

    def _drain_destination(self, inst: Instance) -> Optional[Instance]:
        cands = [i for i in self.instances
                 if i is not inst and not i.draining and i.schedulable]
        if not cands:
            return None
        # decodes prefer a D-heavy home; fall back to any peer
        d = [i for i in cands if i.itype == D_HEAVY]
        return min(d or cands, key=lambda i: i.decode_load())

    # ------------------------------------------------------------------
    def run(self, requests: Sequence[Request], until: Optional[float] = None
            ) -> List[Request]:
        for r in requests:
            self.submit(r)
        while self._heap:
            if until is not None and self.peek_time() > until:
                break
            self.step()
        return list(requests)

    # ------------------------------------------------------------------
    def stats(self, requests, slo: SLO, qps: float) -> RunStats:
        wall = max(((r.finish_time or 0.0) for r in requests), default=0.0)
        return RunStats(
            list(requests), slo, qps, wall,
            cache_lookups=sum(i.cache_lookups for i in self.instances),
            cache_hits=sum(i.cache_hits for i in self.instances),
            saved_prefill_tokens=sum(i.cached_prefill_tokens
                                     for i in self.instances),
            early_rejections=getattr(self.policy.proxy, "rejected_count", 0),
            role_flips=self.role_flip_count)

    @property
    def role_flip_count(self) -> int:
        """Landed flips, from the per-instance ground truth."""
        return sum(i.role_flips for i in self.instances)
