"""Cluster orchestration: a discrete-event loop shared by the simulator
(SimExecutor + estimator time) and the real engine (JaxExecutor + the
same estimator time base, so scheduling behaves identically while tokens
are computed for real).

Events: ARRIVAL (proxy routes prefill), ITER (an instance executes one
mixed batch), TRANSFER (a KV/state migration lands).  Migration latency
is charged via CostModel.transfer_time — asynchronous, off the critical
path, as in the paper's vLLM implementation (§3.5).
"""
from __future__ import annotations

import heapq
import itertools
from typing import Dict, List, Optional, Sequence

from repro.core.estimator import CostModel
from repro.core.instance import Instance
from repro.core.latency import SLO, RunStats
from repro.core.policies import BasePolicy
from repro.engine.request import Request, State

ARRIVAL, ITER, TRANSFER = 0, 1, 2


class Cluster:
    def __init__(self, policy: BasePolicy, cost: CostModel):
        self.policy = policy
        self.cost = cost
        self.instances = policy.instances
        self._heap: list = []
        self._seq = itertools.count()
        self._iter_scheduled: Dict[int, bool] = {
            i.iid: False for i in self.instances}
        self.transfer_count = 0
        self.transfer_bytes = 0
        self.backflow_count = 0
        self.degrade_count = 0

    # ------------------------------------------------------------------
    def _push(self, t: float, kind: int, data):
        heapq.heappush(self._heap, (t, next(self._seq), kind, data))

    def _schedule_iter(self, inst: Instance, t: float):
        if not self._iter_scheduled[inst.iid]:
            self._iter_scheduled[inst.iid] = True
            self._push(max(t, inst.busy_until), ITER, inst.iid)

    def _start_transfer(self, req: Request, src: Instance, dst: Instance,
                        now: float, kind: str):
        """kind: 'place' (prefill->decode), 'degrade', or 'backflow'."""
        # prefix-aware migration: when the destination already caches a
        # prefix of the request's prompt, only the non-shared suffix
        # ships (the landed state aliases the cached blocks)
        shared = dst.peek_migration_prefix(req)
        state = src.eject(req)
        req.state = State.MIGRATING
        req.n_migrations += 1
        moved = max(req.context_len - shared, 0)
        t = self.cost.transfer_time(moved)
        self.transfer_count += 1
        self.transfer_bytes += self.cost.state_bytes(moved)
        self._push(now + t, TRANSFER, (req, dst, state, kind))

    # ------------------------------------------------------------------
    def run(self, requests: Sequence[Request], until: Optional[float] = None
            ) -> List[Request]:
        for r in requests:
            self._push(r.arrival, ARRIVAL, r)
        inst_by_id = {i.iid: i for i in self.instances}
        while self._heap:
            now, _, kind, data = heapq.heappop(self._heap)
            if until is not None and now > until:
                break
            if kind == ARRIVAL:
                inst = self.policy.on_arrival(data, now)
                if inst is None:               # early rejection
                    data.state = State.REJECTED
                    data.finish_time = now
                    continue
                self._schedule_iter(inst, now)
            elif kind == TRANSFER:
                req, dst, state, move_kind = data
                dst.inject(req, state)
                if move_kind == "backflow":
                    req.reset_tpot_window()
                    self.backflow_count += 1
                elif move_kind == "degrade":
                    self.degrade_count += 1
                self._schedule_iter(dst, now)
            else:  # ITER
                inst = inst_by_id[data]
                self._iter_scheduled[inst.iid] = False
                dur, prefill_done, _finished = inst.run_iteration(now)
                end = now + dur
                for req in prefill_done:
                    target, needs_transfer = self.policy.on_prefill_done(
                        req, inst, end)
                    if needs_transfer:
                        self._start_transfer(req, inst, target, end, "place")
                    else:
                        target.admit_decode(req)
                        self._schedule_iter(target, end)
                for (req, src, dst, is_backflow) in (
                        self.policy.select_migrations(end, inst)):
                    self._start_transfer(req, src, dst, end,
                                         "backflow" if is_backflow
                                         else "degrade")
                    self._schedule_iter(dst, end)
                if inst.has_work():
                    if dur == 0.0:
                        # nothing schedulable this tick (e.g. oversized
                        # head-of-line request): back off instead of
                        # spinning at the same timestamp
                        self._schedule_iter(inst, end + 0.01)
                    else:
                        self._schedule_iter(inst, end)
        return list(requests)

    # ------------------------------------------------------------------
    def stats(self, requests, slo: SLO, qps: float) -> RunStats:
        wall = max((r.finish_time or 0.0) for r in requests)
        return RunStats(
            list(requests), slo, qps, wall,
            cache_lookups=sum(i.cache_lookups for i in self.instances),
            cache_hits=sum(i.cache_hits for i in self.instances),
            saved_prefill_tokens=sum(i.cached_prefill_tokens
                                     for i in self.instances))
