"""Target-hardware constants (TPU v5e) used by the estimator, the
scheduler's TTFT projections (Algorithm 2), and the roofline analysis.

The paper's testbed is A100-80GB + NVLink; we adapt to TPU v5e per the
assignment.  All absolute latencies therefore differ from the paper —
the *relative* claims (C1–C7 in DESIGN.md) are what EXPERIMENTS.md
validates, with SLOs derived from profiled base latencies.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12          # bf16 FLOP/s per chip
    hbm_bw: float = 819e9               # bytes/s per chip
    hbm_bytes: int = 16 * 1024 ** 3     # 16 GiB per chip
    ici_bw: float = 50e9                # bytes/s per ICI link
    ici_links: int = 4                  # links per chip (2D torus)
    dcn_bw: float = 25e9                # bytes/s cross-pod per host

    # achievable-efficiency derates (MFU-style), calibrated once:
    prefill_mfu: float = 0.55           # large-matmul bound
    decode_membw_eff: float = 0.75      # streaming weight/KV reads
    iteration_overhead_s: float = 2.0e-3  # launch/schedule per iteration


V5E = HardwareSpec()


@dataclasses.dataclass(frozen=True)
class InstanceSpec:
    """One serving instance = a TP group of ``tp`` chips."""
    hw: HardwareSpec = V5E
    tp: int = 4

    @property
    def flops(self) -> float:
        return self.hw.peak_flops * self.tp

    @property
    def hbm_bw(self) -> float:
        return self.hw.hbm_bw * self.tp

    @property
    def hbm_bytes(self) -> int:
        return self.hw.hbm_bytes * self.tp

    @property
    def interconnect_bw(self) -> float:
        """Effective point-to-point bandwidth for KV migration between
        instances (ICI within a pod)."""
        return self.hw.ici_bw
