"""Length-aware prefill scheduling — Algorithm 2 of the paper (§3.4),
plus the decode-placement rule of §3.3 step ①.

Prefill routing: for each instance estimate
    TTFT_hat = Q (queued prefill exec time) + E (this request's exec time)
             + T (KV transfer, P-heavy only — its decode will move away;
                  charged against the best decode-placement candidate's
                  cached prefix, so destination hits shrink the estimate)
keep instances with TTFT_hat + elapsed-queue-age < tpft SLO (feasible set),
pick the feasible instance with the FEWEST queued prefill tokens (this
preferentially degrades short prefills onto D-heavy instances, while
falling back to P-heavy when D-heavy queues grow — load balancing).
If no instance is feasible the request is assigned randomly (the paper
does the same for fair comparison instead of early rejection [20]).

Cache-aware extension: when instances carry a shared-prefix KV cache, E
is computed on the EFFECTIVE prefill length (prompt minus that
instance's longest cached prefix) and queued-token ties break toward the
instance holding the longest prefix.  This interacts with latency
shifting: a big hit can make a D-heavy instance feasible for a long
prompt that would otherwise have to degrade a P-heavy one.  Q still uses
full queued lengths — queued requests' hits are only claimed at
admission, so the estimate stays conservative.

Decode placement (§3.3 ①): prefilled on D-heavy -> decode in place (zero
transfer); prefilled on P-heavy -> D-heavy instance with the lowest
decode load (HBM usage).
"""
from __future__ import annotations

import random
from typing import List, Optional, Sequence

from repro.core.estimator import CostModel
from repro.core.instance import D_HEAVY, Instance, P_HEAVY
from repro.engine.request import Request


class Proxy:
    def __init__(self, instances: Sequence[Instance], cost: CostModel,
                 ttft_slo: float, seed: int = 0,
                 early_rejection: bool = False, cache_aware: bool = True):
        """early_rejection: when no instance can meet the TTFT SLO,
        proactively drop the request (Mooncake-style [20], paper §3.4)
        instead of randomly assigning it.  The paper disables this for
        fair comparison with PD aggregation; we expose both behaviors.
        cache_aware: use effective (post-prefix-hit) lengths in TTFT_hat
        and prefer the prefix-holding instance on ties (no-op unless
        instances have a prefix cache)."""
        self.instances = list(instances)
        self.cost = cost
        self.ttft_slo = ttft_slo
        self._rng = random.Random(seed)
        self.infeasible_count = 0
        self.early_rejection = early_rejection
        self.rejected_count = 0
        self.cache_aware = cache_aware

    # ------------------------------------------------------------------
    def _queue_time(self, inst: Instance) -> float:
        """Q: total estimated execution time of queued prefill work."""
        q = 0.0
        for r in inst.prefill_queue:
            q += self.cost.prefill_time(r.prefill_remaining,
                                        inst.chunk_size,
                                        decode_batch=len(inst.decoding))
        return q

    def _peek_hit(self, inst: Instance, req: Request) -> int:
        return inst.peek_prefix(req) if self.cache_aware else 0

    def _exec_time(self, inst: Instance, req: Request,
                   cached: int = 0) -> float:
        return self.cost.prefill_time(req.prompt_len - cached,
                                      inst.chunk_size,
                                      decode_batch=len(inst.decoding))

    def _transfer_time(self, inst: Instance, req: Request) -> float:
        """T: KV transfer charge for a P-heavy placement (its decode will
        move to a D-heavy instance after prefill).

        Destination-aware: the transfer is charged against the BEST
        decode-placement candidate — the least decode-loaded D-heavy
        instance, the same rule ``place_decode`` applies — and only the
        suffix that candidate does not already cache ships (prefix-aware
        migration).  A big prefix hit on the destination therefore
        shrinks TTFT_hat, which can make a P-heavy placement feasible
        for a prompt the full-transfer charge would have excluded."""
        if inst.itype != P_HEAVY:
            return 0.0
        return self.cost.transfer_time(self._transfer_moved(req))

    def _transfer_moved(self, req: Request) -> int:
        """Tokens a P-heavy placement would actually ship — independent
        of the prefill candidate, so ``schedule_prefill`` computes it
        once per arrival (the prefix match walks the whole prompt)."""
        dcands = [i for i in self.instances
                  if i.itype == D_HEAVY and not i.draining
                  and i.schedulable]
        if not dcands:
            return req.prompt_len
        dst = min(dcands, key=lambda i: i.decode_load())
        return max(req.prompt_len - dst.peek_migration_prefix(req), 0)

    # ------------------------------------------------------------------
    def schedule_prefill(self, req: Request, now: float) -> Instance:
        """Algorithm 2 (+ cache-aware effective lengths)."""
        feasible: List[tuple] = []             # (instance, prefix hit)
        t_place = None                         # lazy: P-heavy cands only
        for inst in self.instances:
            if inst.chunk_size <= 0:
                continue                       # pure-decode instance
            if not inst.schedulable:
                continue                       # dead/quarantined
            cached = self._peek_hit(inst, req)
            Q = self._queue_time(inst)
            E = self._exec_time(inst, req, cached)
            if inst.itype == P_HEAVY:
                # T is destination-derived — identical for every P-heavy
                # candidate, so the prefix match runs once per arrival
                if t_place is None:
                    t_place = self._transfer_time(inst, req)
                T = t_place
            else:
                T = 0.0
            if Q + E + T < self.ttft_slo:
                feasible.append((inst, cached))
        if feasible:
            # fewest queued prefill tokens; ties favor the instance with
            # the longest cached prefix, then D-heavy (the paper
            # "typically favors a D-heavy instance" — degradation first)
            chosen = min(feasible,
                         key=lambda ic: (ic[0].queued_prefill_tokens(),
                                         -ic[1],
                                         0 if ic[0].itype == D_HEAVY
                                         else 1))[0]
        else:
            self.infeasible_count += 1
            if self.early_rejection:
                self.rejected_count += 1
                return None
            cands = [i for i in self.instances
                     if i.chunk_size > 0 and i.schedulable]
            if not cands:
                return None        # no healthy prefill capacity at all
            chosen = self._rng.choice(cands)
        chosen.enqueue_prefill(req)
        return chosen

    # ------------------------------------------------------------------
    def place_decode(self, req: Request, prefill_inst: Instance,
                     d_instances: Sequence[Instance]) -> Instance:
        """§3.3 step ①: in-place on D-heavy, else least-loaded D-heavy.
        Draining instances (staged role flip) accept no new decodes."""
        cands = [i for i in d_instances
                 if not i.draining and i.schedulable]
        if (prefill_inst.itype == D_HEAVY and not prefill_inst.draining) \
                or not cands:
            return prefill_inst
        return min(cands, key=lambda i: i.decode_load())

    def least_loaded(self, itype: str) -> Optional[Instance]:
        cands = [i for i in self.instances
                 if i.itype == itype and not i.draining and i.schedulable]
        if not cands:
            return None
        return min(cands, key=lambda i: i.decode_load())
