"""Analytical execution-time estimator (the paper's Vidur analogue).

The paper's scheduling (Algorithm 2) *requires* an execution-time
predictor: "The recent research Vidur models it and provides an accurate
and efficient execution time predictor, which we leverage" (§3.4).  Vidur
is GPU-profiled; here we derive times from first principles on the target
TPU (roofline: max(compute, memory) + overhead), which reproduces the
paper's two key phenomenological facts:

  * Obs 2 — decode-iteration time is *linear* in the number of prefill
    tokens piggybacked in the batch (compute-bound linear ops add time
    proportional to chunk tokens): TPOT = intercept + slope * interference.
  * Obs 3 — prefill processing capacity grows with chunk size (per-
    iteration overhead and decode piggyback amortize over more tokens).

Estimates are *per mixed batch iteration*: one instance executes
``prefill_tokens`` of chunked prefill (at a given context offset) plus a
batch of decodes in lock-step (aggregated batch handling).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from repro.core.hw import InstanceSpec, V5E
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class CostModel:
    cfg: ModelConfig
    inst: InstanceSpec = InstanceSpec()

    def __post_init__(self):
        # The estimator sits on the proxy's per-arrival hot path (every
        # routing decision sums prefill_time over whole queues), so the
        # config-derived constants are computed once here and the
        # context-dependent lookups are memoized.  Pure caching — the
        # formulas are unchanged.  (object.__setattr__: frozen dataclass.)
        object.__setattr__(self, "_itemsize", None)
        object.__setattr__(self, "_active_params", None)
        object.__setattr__(self, "_weight_bytes", None)
        object.__setattr__(self, "_kv_per_token", None)
        object.__setattr__(self, "_state_bytes_cache", {})
        object.__setattr__(self, "_prefill_time_cache", {})
        object.__setattr__(self, "_attn_const", None)

    # ------------------------------------------------------------------
    # static model quantities
    # ------------------------------------------------------------------
    @property
    def itemsize(self) -> int:
        if self._itemsize is None:
            import jax.numpy as jnp
            object.__setattr__(self, "_itemsize",
                               jnp.dtype(self.cfg.dtype).itemsize)
        return self._itemsize

    @property
    def active_params(self) -> int:
        # matmul-relevant weights: exclude the embedding gather
        if self._active_params is None:
            object.__setattr__(
                self, "_active_params",
                self.cfg.active_param_count()
                - self.cfg.vocab_size * self.cfg.d_model)
        return self._active_params

    @property
    def weight_bytes(self) -> int:
        if self._weight_bytes is None:
            object.__setattr__(self, "_weight_bytes",
                               self.cfg.active_param_count() * self.itemsize)
        return self._weight_bytes

    def kv_bytes_per_token(self) -> float:
        """KV/state bytes appended per context token (amortized; SSM state
        is O(1) so contributes ~0 per token)."""
        if self._kv_per_token is None:
            object.__setattr__(self, "_kv_per_token",
                               self.cfg.kv_cache_bytes(1, 4096) / 4096)
        return self._kv_per_token

    def state_bytes(self, context: int) -> int:
        """Total cache bytes for one request at a given context length —
        the migration payload of flowing decode scheduling."""
        context = max(context, 1)
        b = self._state_bytes_cache.get(context)
        if b is None:
            b = self.cfg.kv_cache_bytes(1, context)
            self._state_bytes_cache[context] = b
        return b

    # ------------------------------------------------------------------
    # per-phase primitives
    # ------------------------------------------------------------------
    def _matmul_flops(self, tokens: int) -> float:
        return 2.0 * self.active_params * tokens

    def _attn_flops(self, tokens: int, ctx_start: float) -> float:
        """Attention score+value FLOPs for ``tokens`` new tokens whose
        context grows from ctx_start."""
        cfg = self.cfg
        if self._attn_const is None:
            n_attn = cfg.attn_layer_count()
            object.__setattr__(
                self, "_attn_const",
                (n_attn, 4.0 * n_attn * cfg.num_heads * cfg.head_dim))
        n_attn, flop_coeff = self._attn_const
        if n_attn == 0 or cfg.num_heads == 0:
            # SSM: linear-in-T mixer; fold into a small constant per token
            return 0.0
        avg_ctx = ctx_start + tokens / 2.0
        if cfg.sliding_window and cfg.local_global_ratio:
            r = cfg.local_global_ratio
            n_local = n_attn * r / (r + 1)
            n_global = n_attn - n_local
            eff_ctx = (n_local * min(avg_ctx, cfg.sliding_window)
                       + n_global * avg_ctx) / n_attn
        else:
            eff_ctx = avg_ctx
        return flop_coeff * tokens * eff_ctx

    def _kv_read_bytes(self, context: int) -> float:
        return self.state_bytes(context)

    def _tp_collective_time(self, tokens: int) -> float:
        """Per-layer all-reduce of activations across the TP group."""
        if self.inst.tp <= 1:
            return 0.0
        cfg = self.cfg
        n_layers = cfg.num_layers + cfg.num_encoder_layers
        bytes_ = (2.0 * tokens * cfg.d_model * self.itemsize * n_layers
                  * 2 * (self.inst.tp - 1) / self.inst.tp)
        return bytes_ / (self.inst.hw.ici_bw * self.inst.hw.ici_links)

    # ------------------------------------------------------------------
    # public API
    # ------------------------------------------------------------------
    def iteration_time(self, prefill_items: Sequence[tuple] = (),
                       decode_contexts: Sequence[int] = ()) -> float:
        """Time of one mixed-batch iteration.

        prefill_items: [(chunk_tokens, ctx_start), ...]
        decode_contexts: context length of each decode request in batch.
        """
        hw = self.inst
        p_tokens = sum(t for t, _ in prefill_items)
        d_tokens = len(decode_contexts)
        flops = self._matmul_flops(p_tokens + d_tokens)
        for t, c in prefill_items:
            flops += self._attn_flops(t, c)
        for c in decode_contexts:
            flops += self._attn_flops(1, c)
        t_compute = flops / (hw.flops * hw.hw.prefill_mfu)

        bytes_ = float(self.weight_bytes)
        for c in decode_contexts:
            bytes_ += self._kv_read_bytes(c)
        for t, c in prefill_items:
            bytes_ += self._kv_read_bytes(c) + t * self.kv_bytes_per_token()
        t_mem = bytes_ / (hw.hbm_bw * hw.hw.decode_membw_eff)

        t_coll = self._tp_collective_time(p_tokens + d_tokens)
        return (max(t_compute, t_mem) + t_coll
                + hw.hw.iteration_overhead_s)

    def prefill_time(self, prompt_len: int, chunk_size: int,
                     decode_batch: int = 0) -> float:
        """Total execution time to prefill ``prompt_len`` tokens with a
        given chunk size, assuming ``decode_batch`` decodes piggybacked in
        every iteration (Algorithm 2's E term)."""
        if chunk_size <= 0:
            return float("inf")
        key = (prompt_len, chunk_size, decode_batch)
        cached = self._prefill_time_cache.get(key)
        if cached is not None:
            return cached
        total, pos = 0.0, 0
        while pos < prompt_len:
            c = min(chunk_size, prompt_len - pos)
            total += self.iteration_time(
                [(c, pos)], [512] * decode_batch)
            pos += c
        if len(self._prefill_time_cache) < 1 << 18:
            self._prefill_time_cache[key] = total
        return total

    def decode_iteration_time(self, batch: int, avg_context: int,
                              chunk_tokens: int = 0) -> float:
        """Decode-iteration latency with optional prefill interference —
        the linear-TPOT primitive (Obs 2)."""
        items = [(chunk_tokens, 1024)] if chunk_tokens else []
        return self.iteration_time(items, [avg_context] * max(batch, 1))

    def transfer_time(self, context: int) -> float:
        """KV/state migration time between instances (paper §3.5: async
        NCCL; here ICI point-to-point)."""
        return self.state_bytes(context) / self.inst.interconnect_bw

    def prefill_capacity(self, chunk_size: int, decode_batch: int = 0,
                         prompt_len: int = 3000) -> float:
        """Prefill tokens/second at steady state (paper Fig 8)."""
        t = self.prefill_time(prompt_len, chunk_size, decode_batch)
        return prompt_len / t
