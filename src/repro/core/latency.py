"""SLO definitions, attainment, and goodput metrics (paper §2.1, §4.1)."""
from __future__ import annotations

import dataclasses
from typing import Iterable, List, Optional, Sequence

import numpy as np

from repro.engine.request import Request


@dataclasses.dataclass(frozen=True)
class SLO:
    ttft: float      # seconds
    tpot: float      # seconds per output token

    def satisfied(self, req: Request) -> bool:
        t1 = req.ttft()
        if t1 is None or t1 > self.ttft:
            return False
        tp = req.tpot()
        if tp is None:           # single-token outputs: only TTFT applies
            return True
        return tp <= self.tpot


def attainment(reqs: Sequence[Request], slo: SLO) -> float:
    from repro.engine.request import State
    done = [r for r in reqs if r.first_token_time is not None
            or r.state in (State.REJECTED, State.FAILED)]
    if not done:
        return 0.0
    # early-rejected, fault-failed and client-aborted requests count as
    # SLO violations even when their emitted tokens met the deadlines —
    # work that never produced a complete answer is not goodput
    bad = (State.REJECTED, State.FAILED, State.CANCELLED)
    return sum(slo.satisfied(r) and r.state not in bad
               for r in done) / len(done)


def percentile(xs: Iterable[float], q: float) -> float:
    xs = [x for x in xs if x is not None]
    return float(np.percentile(xs, q)) if xs else float("nan")


def p90(xs: Iterable[float]) -> float:
    return percentile(xs, 90)


@dataclasses.dataclass
class RunStats:
    reqs: List[Request]
    slo: SLO
    qps: float
    wall: float
    # prefix-cache counters (aggregated over instances by Cluster.stats;
    # zero when caching is off)
    cache_lookups: int = 0
    cache_hits: int = 0
    saved_prefill_tokens: int = 0
    # online-serving counters: proxy early-rejection drops and adaptive
    # controller activity (slider moves = chunk retunes + role flips),
    # surfaced here so sweeps and benches report them without log
    # scraping
    early_rejections: int = 0
    slider_moves: int = 0
    role_flips: int = 0

    @property
    def slo_attainment(self) -> float:
        return attainment(self.reqs, self.slo)

    def ttft_percentile(self, q: float) -> float:
        return percentile([r.ttft() for r in self.reqs], q)

    @property
    def mean_ttft(self) -> float:
        xs = [r.ttft() for r in self.reqs if r.ttft() is not None]
        return float(np.mean(xs)) if xs else float("nan")

    @property
    def p90_ttft(self) -> float:
        return self.ttft_percentile(90)

    @property
    def p90_tpot(self) -> float:
        return p90([r.tpot() for r in self.reqs])

    @property
    def cache_hit_rate(self) -> float:
        """Fraction of prefill admissions that reused a cached prefix."""
        if self.cache_lookups == 0:
            return 0.0
        return self.cache_hits / self.cache_lookups

    def summary(self) -> dict:
        out = {
            "qps": self.qps,
            "n": len(self.reqs),
            "attainment": round(self.slo_attainment, 4),
            "p90_ttft_s": round(self.p90_ttft, 3),
            "p90_tpot_ms": round(self.p90_tpot * 1e3, 2),
        }
        if self.cache_lookups:
            out["cache_hit_rate"] = round(self.cache_hit_rate, 4)
            out["saved_prefill_tokens"] = self.saved_prefill_tokens
        if self.early_rejections:
            out["early_rejections"] = self.early_rejections
        if self.slider_moves or self.role_flips:
            out["slider_moves"] = self.slider_moves
            out["role_flips"] = self.role_flips
        return out


def max_goodput(run_at_qps, qps_grid: Sequence[float],
                target: float = 0.9) -> tuple:
    """Paper metric: max request rate sustaining >= 90% SLO attainment.

    run_at_qps: callable qps -> RunStats.  Returns (goodput_qps, [RunStats]).
    """
    stats = []
    best = 0.0
    for q in qps_grid:
        st = run_at_qps(q)
        stats.append(st)
        if st.slo_attainment >= target:
            best = q
    return best, stats
