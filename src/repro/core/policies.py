"""Scheduling policies: PD aggregation, PD disaggregation, and TaiChi's
hybrid mode — the three rows of the paper's Table 1.

                      batch handling     request handling
  PD aggregation      aggregated         aggregated (decode in place)
  PD disaggregation   disaggregated      disaggregated (prefill->decode move)
  TaiChi hybrid       aggregated         disaggregated

TaiChi's three sliders (§3.1): R_PD (ratio of P-heavy to D-heavy
instances), S_P, S_D (their chunk sizes).  Setting S_D == S_P recovers
aggregation; S_D = 0 with S_P = max context recovers disaggregation —
both expressible as TaiChiPolicy corner cases, which the tests assert.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

from repro.cache.prefix_cache import PrefixCache
from repro.core import flowing
from repro.core.estimator import CostModel
from repro.core.instance import D_HEAVY, Instance, P_HEAVY
from repro.core.proxy import Proxy
from repro.engine.request import Request


@dataclasses.dataclass
class Sliders:
    """TaiChi's configuration surface (paper §3.1)."""
    n_p: int                 # P-heavy instance count   (R_PD = n_p : n_d)
    n_d: int                 # D-heavy instance count
    s_p: int                 # chunk size on P-heavy
    s_d: int                 # chunk size on D-heavy (0 = no prefill)
    watermark: float = 0.95  # M: D-heavy HBM watermark for degradation
    alpha: float = 0.96      # TPOT-approach factor for backflow


class BasePolicy:
    """Common wiring; subclasses override the three decision hooks."""

    name = "base"

    def __init__(self, instances: Sequence[Instance], cost: CostModel,
                 ttft_slo: float, tpot_slo: float, seed: int = 0):
        self.instances = list(instances)
        self.cost = cost
        self.ttft_slo = ttft_slo
        self.tpot_slo = tpot_slo
        self.proxy = Proxy(self.instances, cost, ttft_slo, seed=seed)
        # adaptive decode-horizon selection reads the flowing-decode
        # budget: give every instance the TPOT SLO it is serving against
        for inst in self.instances:
            if inst.tpot_slo is None:
                inst.tpot_slo = tpot_slo

    @property
    def p_instances(self) -> List[Instance]:
        return [i for i in self.instances if i.itype == P_HEAVY]

    @property
    def d_instances(self) -> List[Instance]:
        return [i for i in self.instances if i.itype == D_HEAVY]

    # hooks ------------------------------------------------------------
    def on_arrival(self, req: Request, now: float) -> Instance:
        raise NotImplementedError

    def on_prefill_done(self, req: Request, inst: Instance,
                        now: float) -> Tuple[Instance, bool]:
        """Returns (decode instance, needs_transfer)."""
        raise NotImplementedError

    def select_migrations(self, now: float, inst: Instance
                          ) -> List[Tuple[Request, Instance, Instance, bool]]:
        """Algorithm 1 is invoked in the scheduling phase of each
        iteration of ``inst``; returns [(req, src, dst, is_backflow)]."""
        return []


class PDAggregationPolicy(BasePolicy):
    """Chunked prefill everywhere (Sarathi-Serve-style); requests decode
    where they prefilled."""

    name = "pd_aggregation"

    def on_arrival(self, req: Request, now: float) -> Instance:
        cands = [i for i in self.instances if i.schedulable]
        if not cands:
            return None
        inst = min(cands, key=lambda i: i.queued_prefill_tokens())
        inst.enqueue_prefill(req)
        return inst

    def on_prefill_done(self, req, inst, now):
        return inst, False


class PDDisaggregationPolicy(BasePolicy):
    """DistServe/Splitwise-style: prefill instances never decode, decode
    instances never prefill, KV moves across after the first token."""

    name = "pd_disaggregation"

    def on_arrival(self, req: Request, now: float) -> Instance:
        cands = [i for i in self.p_instances if i.schedulable]
        if not cands:
            return None
        inst = min(cands, key=lambda i: i.queued_prefill_tokens())
        inst.enqueue_prefill(req)
        return inst

    def on_prefill_done(self, req, inst, now):
        live = [i for i in self.d_instances if i.schedulable]
        cands = [i for i in live if not i.draining] or live
        if not cands:
            return inst, False             # every D peer down: decode here
        target = min(cands, key=lambda i: i.decode_load())
        return target, True


class TaiChiPolicy(BasePolicy):
    """Hybrid mode: Algorithm 2 for prefill, §3.3① for decode placement,
    Algorithm 1 for flowing decode (degradation + backflow)."""

    name = "taichi"

    def __init__(self, instances, cost, ttft_slo, tpot_slo,
                 sliders: Sliders, seed: int = 0,
                 enable_flowing: bool = True, length_aware: bool = True,
                 early_rejection: bool = False, cache_aware: bool = True):
        """enable_flowing / length_aware: ablation switches for the
        paper's Fig-18 breakdown (Arch -> +Flowing -> +LengthAware).
        early_rejection: drop TTFT-infeasible requests at the proxy
        (paper §3.4 discussion; off by default for fair comparison).
        cache_aware: route on effective (post-prefix-hit) prefill
        lengths when instances carry a prefix cache — disable to ablate
        routing awareness while keeping KV reuse itself on."""
        super().__init__(instances, cost, ttft_slo, tpot_slo, seed=seed)
        self.sliders = sliders
        for inst in self.instances:
            inst.tpot_alpha = sliders.alpha
        self.enable_flowing = enable_flowing
        self.length_aware = length_aware
        self.proxy.early_rejection = early_rejection
        self.proxy.cache_aware = cache_aware

    def on_arrival(self, req: Request, now: float) -> Instance:
        if not self.length_aware:
            # naive least-queued routing (no TTFT feasibility estimate)
            cands = [i for i in self.instances
                     if i.chunk_size > 0 and i.schedulable]
            if not cands:
                return None
            inst = min(cands, key=lambda i: i.queued_prefill_tokens())
            inst.enqueue_prefill(req)
            return inst
        return self.proxy.schedule_prefill(req, now)

    def on_prefill_done(self, req, inst, now):
        target = self.proxy.place_decode(req, inst, self.d_instances)
        return target, target is not inst

    def select_migrations(self, now: float, inst: Instance):
        if not self.enable_flowing:
            return []
        if inst.draining:
            return []                      # drain machinery owns its moves
        moves = []
        s = self.sliders
        d_avail = [i for i in self.d_instances
                   if not i.draining and i.schedulable]
        p_avail = [i for i in self.p_instances
                   if not i.draining and i.schedulable]
        if inst.itype == P_HEAVY:
            for req in flowing.select_backflow(inst, self.tpot_slo,
                                               s.alpha, now):
                dst = min(d_avail, key=lambda i: i.decode_load(),
                          default=None)
                if dst is not None and dst is not inst:
                    moves.append((req, inst, dst, True))
        else:
            for req in flowing.select_degrade(inst, s.watermark):
                dst = min(p_avail, key=lambda i: i.decode_load(),
                          default=None)
                if dst is not None and dst is not inst:
                    moves.append((req, inst, dst, False))
        return moves


def build_instances(cost: CostModel, sliders: Sliders,
                    executor_factory, hbm_blocks: int = 4096,
                    block_size: int = 16,
                    prefix_cache: bool = False,
                    spill_blocks: int = 0) -> List[Instance]:
    """Instantiate the differentiated-capability pool.  With
    ``prefix_cache`` each instance owns a shared-prefix KV cache over
    its own HBM block pool; ``spill_blocks`` adds a host-RAM tier per
    instance that catches LRU-evicted prefix blocks (prefixes stay
    per-instance — the controller's replication pass copies hot ones
    across)."""
    def make(iid, itype, chunk):
        pc = (PrefixCache(hbm_blocks, block_size,
                          spill_blocks=spill_blocks) if prefix_cache
              else None)
        return Instance(iid, itype, chunk, cost, executor_factory(),
                        hbm_blocks, block_size, prefix_cache=pc)
    out = []
    iid = 0
    for _ in range(sliders.n_p):
        out.append(make(iid, P_HEAVY, sliders.s_p))
        iid += 1
    for _ in range(sliders.n_d):
        out.append(make(iid, D_HEAVY, sliders.s_d))
        iid += 1
    return out
