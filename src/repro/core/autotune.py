"""Offline slider search (paper §3.1): "The optimal configuration for a
given workload and SLO can be determined via offline search, following
approaches from prior work [3, 19, 36]."

Searches the (R_PD, S_P, S_D) grid with short simulator runs and returns
the slider setting with the highest goodput, mirroring DistServe's
on-demand search-and-reconfigure strategy (re-run on significant
workload change; completes in minutes of simulated serving, seconds of
wall time per candidate)."""
from __future__ import annotations

import dataclasses
import itertools
from typing import List, Optional, Sequence, Tuple

from repro.core.latency import SLO
from repro.core.policies import Sliders
from repro.sim.workload import WorkloadSpec

DEFAULT_RATIOS = [(1, 3), (2, 2), (3, 1)]
DEFAULT_SP = [1024, 2048, 4096]
DEFAULT_SD = [0, 64, 128, 256, 512]


@dataclasses.dataclass
class SearchResult:
    sliders: Sliders
    goodput: float
    attainment_at_goodput: float
    trials: List[Tuple[Sliders, float]]


def search_sliders(model: str, slo: SLO, workload: WorkloadSpec,
                   qps_grid: Sequence[float], *, tp: int = 4,
                   n_instances: int = 4, n_requests: int = 150,
                   ratios=None, sp_grid=None, sd_grid=None,
                   seed: int = 0) -> SearchResult:
    from repro.sim.simulator import ServingConfig, goodput_sweep
    ratios = ratios or DEFAULT_RATIOS
    sp_grid = sp_grid or DEFAULT_SP
    sd_grid = sd_grid or DEFAULT_SD

    trials: List[Tuple[Sliders, float]] = []
    best: Optional[Tuple[Sliders, float, float]] = None
    for (n_p, n_d), s_p, s_d in itertools.product(ratios, sp_grid, sd_grid):
        if n_p + n_d != n_instances or s_d > s_p:
            continue
        sliders = Sliders(n_p=n_p, n_d=n_d, s_p=s_p, s_d=s_d)
        sc = ServingConfig(model=model, tp=tp, policy="taichi",
                           sliders=sliders)
        g, stats = goodput_sweep(sc, slo, workload, qps_grid,
                                 n_requests=n_requests, seed=seed)
        att = max((s.slo_attainment for s in stats if s.qps <= g),
                  default=0.0)
        trials.append((sliders, g))
        if best is None or g > best[1]:
            best = (sliders, g, att)
    sliders, g, att = best
    return SearchResult(sliders=sliders, goodput=g,
                        attainment_at_goodput=att, trials=trials)
