"""Flowing decode scheduling — Algorithm 1 of the paper (§3.3).

Per inference iteration, each instance's scheduler selects:

  * P-heavy: the *optimizing set* O — decode requests whose current TPOT
    (since their last reset) exceeds alpha * tpot_slo; they flow BACK to a
    D-heavy instance before they violate the SLO (step ③).
  * D-heavy: the *degrading set* D — while HBM usage exceeds the
    watermark M, repeatedly pick the request with the current LONGEST
    output (it has banked the most low-interference iterations, hence the
    largest remaining TPOT budget; short-output requests — unknowable a
    priori — are never picked because they haven't grown long) (step ②).

Migration itself (KV/state transfer + re-admission) is orchestrated by
the cluster; this module is the pure selection logic so it can be
unit/property tested against the paper's pseudocode.
"""
from __future__ import annotations

from typing import List

from repro.core.instance import D_HEAVY, Instance, P_HEAVY
from repro.engine.request import Request


def select_backflow(inst: Instance, tpot_slo: float, alpha: float,
                    now: float) -> List[Request]:
    """Algorithm 1, lines 1-3 (P-heavy): requests approaching TPOT SLO."""
    assert inst.itype == P_HEAVY
    out = []
    for r in inst.decoding.values():
        cur = r.current_tpot(now)
        if cur is not None and cur > tpot_slo * alpha:
            out.append(r)
    return out


def select_degrade(inst: Instance, watermark: float) -> List[Request]:
    """Algorithm 1, lines 4-12 (D-heavy): longest-first until usage <= M.

    Memory-to-release loop over the allocator's actual block ownership."""
    assert inst.itype == D_HEAVY
    total = inst.allocator.num_blocks
    used = inst.allocator.used_blocks
    threshold = watermark * total
    degrade: List[Request] = []
    chosen = set()
    while used > threshold:
        candidates = [r for r in inst.decoding.values()
                      if r.rid not in chosen]
        if not candidates:
            break
        r_star = max(candidates, key=lambda r: r.effective_output_len)
        chosen.add(r_star.rid)
        degrade.append(r_star)
        used -= inst.allocator.blocks_for(r_star.context_len)
    return degrade
