"""Serving instance: a TP group running mixed chunked-prefill + decode
batches (aggregated batch handling).  P-heavy and D-heavy instances are
*the same class* with different chunk sizes — the paper's point is that
capability differentiation is purely a chunk-size configuration (§3.1).

The instance owns: a prefill queue (FIFO), the set of decoding requests,
HBM block accounting, and an executor that actually produces tokens
(real JAX engine, or the simulator's token oracle).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Dict, List, Optional, Protocol, Sequence, Tuple

from repro.cache.prefix_cache import PrefixCache
from repro.core.estimator import CostModel
from repro.engine.kvcache import BlockAllocator
from repro.engine.request import Request, State

P_HEAVY = "P"
D_HEAVY = "D"

# instance health (fault tolerance): OK serves; QUARANTINED is excluded
# from placement but keeps its KV (watchdog probation re-admits); DEAD
# lost its HBM entirely and needs an explicit recover
HEALTH_OK = "ok"
HEALTH_QUARANTINED = "quarantined"
HEALTH_DEAD = "dead"


@dataclasses.dataclass
class IterationPlan:
    prefill_items: List[Tuple[Request, int]]      # (request, chunk tokens)
    decode_reqs: List[Request]
    #: fused decode steps this iteration executes (1 = classic single
    #: step; > 1 only on decode-only plans — a co-scheduled chunked
    #: prefill always forces K back to 1)
    horizon: int = 1
    #: per-row token budgets for horizon > 1 (aligned with decode_reqs):
    #: min(horizon, remaining output, allocator-extendable growth)
    decode_budgets: Optional[List[int]] = None
    #: per-step modeled durations, filled by iteration_duration for
    #: horizon > 1 so token timestamps spread exactly like K=1 events
    step_durations: Optional[List[float]] = None

    @property
    def prefill_tokens(self) -> int:
        return sum(t for _, t in self.prefill_items)

    def prefill_rows(self) -> List[Tuple[Request, int, int, bool]]:
        """Batch-plan surface for executors: one row per prefill chunk as
        ``(request, start_position, take, completes_prefill)``."""
        return [(r, r.prefill_pos, t, t == r.prefill_remaining)
                for r, t in self.prefill_items]

    def empty(self) -> bool:
        return not self.prefill_items and not self.decode_reqs


@dataclasses.dataclass
class CommitResult:
    """Outcome of one committed iteration (second half of the
    dispatch/commit split).  ``token_events`` carries the deferred
    per-token sink calls ``(request, time)`` when the caller asked for
    deferred emission — so it can dispatch the next horizon before the
    host spends time streaming these."""
    duration: float
    prefill_done: List[Request]
    finished: List[Request]
    token_events: List[Tuple[Request, float]]


class Executor(Protocol):
    """Produces tokens for a planned iteration; returns per-request
    "finished decoding" flags (EOS) for decode requests."""

    def execute(self, plan: IterationPlan) -> Dict[int, bool]: ...

    def add_request(self, req: Request): ...

    def claim_prefix(self, req: Request, max_tokens: int) -> int: ...

    def extract_state(self, req: Request): ...

    def insert_state(self, req: Request, state): ...

    def release(self, req: Request): ...


#: HBM-utilization level above which the horizon collapses to 1 — near
#: the degradation watermark every iteration must be schedulable so
#: Algorithm 1 can start flowing requests without a K-step lag
HORIZON_HBM_GUARD = 0.90


class Instance:
    def __init__(self, iid: int, itype: str, chunk_size: int,
                 cost: CostModel, executor: Executor,
                 hbm_blocks: int = 4096, block_size: int = 16,
                 max_decode_batch: int = 256,
                 prefix_cache: Optional[PrefixCache] = None,
                 max_horizon: int = 1,
                 tpot_slo: Optional[float] = None,
                 tpot_alpha: float = 0.96):
        self.iid = iid
        self.itype = itype
        self.chunk_size = chunk_size
        self.cost = cost
        self.executor = executor
        if prefix_cache is None:
            # a paged executor with prefix caching enabled owns the
            # PrefixCache (its allocator's ids index the physical pool)
            prefix_cache = getattr(executor, "prefix_cache_obj", None)
        else:
            adopt = getattr(executor, "adopt_prefix_cache", None)
            if adopt is not None and not adopt(prefix_cache) \
                    and getattr(executor, "paged", False):
                # a paged executor that cannot bind the caller's
                # PrefixCache would run two divergent block-bookkeeping
                # systems (and a mismatched block size would round
                # prefill_pos into aliased shared blocks) — refuse
                raise ValueError(
                    "prefix_cache.block_size must match the paged "
                    "executor's cache_block_size")
        self.prefix_cache = prefix_cache
        if prefix_cache is not None:
            # watermark/degradation reads the SHARED allocator: cached
            # (refcount-0) blocks are evictable, so they don't pressure M
            self.allocator = prefix_cache.allocator
        elif getattr(executor, "allocator", None) is not None:
            # unified bookkeeping: admission draws from the allocator
            # whose block ids index the executor's physical pool, so HBM
            # capacity is bounded by actual context, not n_slots*max_seq
            self.allocator = executor.allocator
        else:
            self.allocator = BlockAllocator(hbm_blocks, block_size)
        if self.allocator is getattr(executor, "allocator", None):
            # this Instance now drives allocate/extend/free; the
            # executor must stop self-managing the same allocator
            executor.use_external_bookkeeping()
        self.max_decode_batch = max_decode_batch
        # multi-step decode horizon: upper bound on fused decode steps
        # per iteration (1 = classic).  tpot_slo/tpot_alpha describe the
        # flowing-decode budget the adaptive pick shrinks K against
        # (wired by the policy; optional for standalone instances).
        self.max_horizon = max_horizon
        self.tpot_slo = tpot_slo
        self.tpot_alpha = tpot_alpha
        self.last_horizon = 1
        self.horizon_peak = 1
        # horizon distribution: planned K -> iteration count (telemetry
        # gauge; shows where the adaptive pick actually operates)
        self.horizon_hist: Dict[int, int] = {}
        # in-flight iteration (dispatch/commit split): (plan, pending
        # executor step or None, start time, modeled duration)
        self._inflight: Optional[tuple] = None

        self.prefill_queue: deque[Request] = deque()
        self.decoding: Dict[int, Request] = {}
        self.pending_decode: deque[Request] = deque()
        # online-serving hooks: a per-token callback installed by the
        # serving loop (streaming), and drain-and-flip reconfiguration
        # state driven by the adaptive slider controller
        self.token_sink: Optional[Callable[[Request, float], None]] = None
        self.draining: bool = False
        self.pending_flip: Optional[Tuple[str, int]] = None
        self.role_flips: int = 0
        # fault tolerance: health gates placement exactly like draining;
        # stall_until models a transient slowdown (dispatch durations run
        # behind the cost model until then); last_progress/step_deadline
        # feed the serving loop's watchdog
        self.health: str = HEALTH_OK
        self.stall_until: float = 0.0
        self.last_progress: float = 0.0
        self.step_deadline: float = float("inf")
        #: worst dispatch-time stall overrun (actual - modeled duration)
        #: since the watchdog last looked — the sync executor's
        #: heartbeat signal (dispatch+commit are one atomic event there,
        #: so a stale step_deadline is never observable mid-step)
        self.overrun: float = 0.0
        self.fail_count: int = 0
        self.quarantine_count: int = 0
        #: request-lifecycle tracer (wired by ServingLoop; None = off)
        self.tracer = None
        # accounting
        self.busy_until: float = 0.0
        self.iterations: int = 0
        self.prefill_token_count: int = 0
        self.decode_token_count: int = 0
        self.interference_log: List[Tuple[int, int]] = []  # (ptk, dtk)
        self.stalled_decodes: int = 0
        self.preemptions: int = 0
        self.cache_lookups: int = 0
        self.cache_hits: int = 0
        self.cached_prefill_tokens: int = 0    # prefill tokens NOT recomputed
        # multi-tier KV accounting
        self.spill_promoted_tokens: int = 0    # host tier -> HBM prefetches
        self.replicas_in: int = 0              # blocks landed by replication
        # warm recovery: victims resumed from a checkpoint here, stream
        # tokens they did NOT re-prefill, and planned-warm restores that
        # had to fall back to cold recompute on this executor
        self.warm_restores: int = 0
        self.warm_restored_tokens: int = 0
        self.warm_fallbacks: int = 0

    # ------------------------------------------------------------------
    # admission / queues
    # ------------------------------------------------------------------
    def enqueue_prefill(self, req: Request):
        self.prefill_queue.append(req)

    def queued_prefill_tokens(self) -> int:
        return sum(r.prefill_remaining for r in self.prefill_queue)

    def admit_decode(self, req: Request):
        """Called by the proxy when this instance is chosen for decode."""
        self.pending_decode.append(req)

    def hbm_utilization(self) -> float:
        return self.allocator.utilization()

    def peek_prefix(self, req: Request) -> int:
        """Longest cached prefix (tokens) this instance could reuse for
        ``req`` — pure, so the proxy can probe every instance when
        routing (cache-aware TTFT_hat).  Counts BOTH tiers: host-spilled
        blocks are promoted back to HBM at admission (``prefetch``), so
        for routing purposes they are as reusable as resident ones."""
        if req.prefill_pos != 0:
            return 0
        if self.prefix_cache is None or not req.prompt_tokens:
            return 0
        return self.prefix_cache.match_tokens_tiered(req.prompt_tokens)

    def _match_prefix(self, req: Request) -> int:
        if self.prefix_cache is None or not req.prompt_tokens:
            return 0
        return self.prefix_cache.match_tokens(req.prompt_tokens)

    def peek_migration_prefix(self, req: Request) -> int:
        """Longest cached prefix (tokens) of a MIGRATING request's prompt
        this instance already holds — a flowing-decode move only ships
        the non-shared suffix, so its transfer cost is charged on
        ``context_len - peek_migration_prefix`` (pure, like
        ``peek_prefix``, but valid mid-decode).  Zero unless this
        instance's executor actually lands migrations by aliasing cached
        blocks (paged engine / simulator) — a dense engine ships the
        full row and must be charged in full."""
        if not getattr(self.executor, "prefix_aware_transfer", False):
            return 0
        return self._match_prefix(req)

    def decode_load(self) -> int:
        """HBM usage proxy for proxy-side load balancing (paper §3.3 ①)."""
        return self.allocator.used_blocks

    @property
    def schedulable(self) -> bool:
        """Health gate for every placement/migration-destination choice
        (draining is a separate, role-flip-scoped gate)."""
        return self.health == HEALTH_OK

    # ------------------------------------------------------------------
    # role reconfiguration (drain-and-flip)
    # ------------------------------------------------------------------
    def begin_flip(self, itype: str, chunk_size: int):
        """Stage a role flip: the instance stops accepting decode
        placements (``draining``) while the cluster migrates its decode
        population away; ``apply_flip`` lands once drained."""
        self.pending_flip = (itype, chunk_size)
        self.draining = True

    def drain_candidates(self) -> List[Request]:
        """Decode-side residents that must migrate before a staged flip
        applies.  Prefill work is NOT drained — it keeps running through
        the flip (the chunk size just changes underneath it)."""
        return list(self.decoding.values()) + list(self.pending_decode)

    def apply_flip(self) -> bool:
        """Land a staged flip if the decode side is empty."""
        if self.pending_flip is None:
            return False
        if self.decoding or self.pending_decode:
            return False
        self.itype, self.chunk_size = self.pending_flip
        self.pending_flip = None
        self.draining = False
        self.role_flips += 1
        return True

    # ------------------------------------------------------------------
    # iteration
    # ------------------------------------------------------------------
    def _try_admit_pending(self, now: Optional[float] = None):
        while self.pending_decode and len(self.decoding) < self.max_decode_batch:
            req = self.pending_decode[0]
            need = req.context_len + 64           # headroom for growth
            if not self.allocator.holds(req.rid):
                if not self.allocator.can_allocate(need):
                    break
                self.allocator.allocate(req.rid, need)
                self.executor.add_request(req)
            self.pending_decode.popleft()
            self.decoding[req.rid] = req
            req.state = State.DECODE
            req.decode_instance = self.iid
            if self.tracer is not None and now is not None:
                self.tracer.phase(req.rid, now, "decode", iid=self.iid)

    def _pick_horizon(self, now: Optional[float] = None) -> int:
        """How many decode steps the next iteration may fuse.

        The horizon must stay *schedulable*: it collapses to 1 whenever
        the instance has prefill work (a co-scheduled chunk must not
        wait K steps — TTFT), is draining toward a role flip (the
        barrier needs per-step progress), or sits near the HBM
        watermark (degradation must be able to flow requests without a
        K-step lag).  Otherwise K is the largest power of two within
        ``max_horizon``, shrunk by the flowing-decode budget: as the
        worst in-flight TPOT approaches the backflow threshold
        ``tpot_alpha * tpot_slo``, Algorithm 1 needs finer scheduling
        grain, so the horizon steps down before requests must flow."""
        if self.max_horizon <= 1 or not self.decoding:
            return 1
        if self.prefill_queue or self.draining or self.pending_flip:
            return 1
        if not getattr(self.executor, "horizon_capable", True):
            return 1
        if self.allocator.utilization() > HORIZON_HBM_GUARD:
            return 1
        k = 1
        while k * 2 <= self.max_horizon:
            k *= 2
        if now is not None and self.tpot_slo:
            worst = max((r.current_tpot(now) or 0.0
                         for r in self.decoding.values()), default=0.0)
            frac = worst / (self.tpot_alpha * self.tpot_slo)
            if frac >= 0.9:
                return 1
            if frac >= 0.75:
                k = min(k, 2)
            elif frac >= 0.5:
                k = min(k, max(2, k // 2))
        return k

    def build_plan(self, now: Optional[float] = None) -> IterationPlan:
        self._try_admit_pending(now)
        k = self._pick_horizon(now)
        decode_reqs: List[Request] = []
        budgets: List[int] = []
        for req in list(self.decoding.values()):
            # per-row horizon budget: never generate past the request's
            # remaining output, never reserve more growth than the
            # allocator can grant — but always try at least one step
            want = max(1, min(k, req.remaining_output))
            grant = 0
            for b in range(want, 0, -1):
                if self.allocator.can_extend(req.rid, req.context_len + b):
                    self.allocator.extend(req.rid, req.context_len + b)
                    grant = b
                    break
            if grant:
                decode_reqs.append(req)
                budgets.append(grant)
            else:
                self.stalled_decodes += 1
        budget = max(0, self.chunk_size - len(decode_reqs))
        if self.chunk_size <= 0 and self.prefill_queue \
                and self.allocator.holds(self.prefill_queue[0].rid):
            # a zeroed chunk slider (set_chunks(0) / drain-and-flip)
            # must never strand an ADMITTED mid-chunk prefill: it holds
            # HBM blocks and budget can never recover on its own, so
            # grant a minimal budget to keep it flowing to completion.
            # (A decode batch merely as wide as a positive chunk is NOT
            # stranding — budget frees as decodes finish.)
            budget = min(64, self.prefill_queue[0].prefill_remaining)
        items: List[Tuple[Request, int]] = []
        while budget > 0 and self.prefill_queue:
            head = self.prefill_queue[0]
            if not self.allocator.holds(head.rid):
                if not self._admit_prefill(head, now):
                    break                          # head-of-line blocking
            take = min(head.prefill_remaining, budget)
            items.append((head, take))
            budget -= take
            if take == head.prefill_remaining:
                self.prefill_queue.popleft()
                head.state = State.PREFILL
            else:
                break
        plan = IterationPlan(items, decode_reqs)
        if k > 1 and decode_reqs and not items:
            # collapse to the largest power of two any row can actually
            # use (bounds jit compile variants to the pow2 ladder); rows
            # with smaller grants freeze early via their budget
            h = 1
            mb = max(budgets)
            while h * 2 <= mb:
                h *= 2
            if h > 1:
                plan.horizon = h
                plan.decode_budgets = [min(b, h) for b in budgets]
        self.last_horizon = plan.horizon
        self.horizon_peak = max(self.horizon_peak, plan.horizon)
        if plan.empty() and self.decoding:
            # memory deadlock: every decode stalled on a block boundary
            # with zero free blocks.  vLLM-style preemption-by-recompute:
            # evict the longest-context decode; it re-prefills its whole
            # context (prompt + generated so far) later.
            victim = max(self.decoding.values(), key=lambda r: r.context_len)
            self._preempt(victim, now)
            self.preemptions += 1
            return self.build_plan(now)
        if not plan.empty():
            self.horizon_hist[plan.horizon] = \
                self.horizon_hist.get(plan.horizon, 0) + 1
        return plan

    def _admit_prefill(self, req: Request,
                       now: Optional[float] = None) -> bool:
        """Reserve HBM blocks for a queued prefill and hand the request
        to the executor.  With a prefix cache, the matched prefix is
        claimed (executor may shrink it to what its rows still hold) and
        the request's prefill starts at the matched position — the cost
        model then charges only the uncached tokens."""
        if req.restore_state is not None:
            return self._admit_restore(req, now)
        need = req.prefill_remaining + 64          # headroom for growth
        if self.prefix_cache is None:
            if not self.allocator.can_allocate(need):
                return False
            self.allocator.allocate(req.rid, need)
            self.executor.add_request(req)
            return True
        if self.prefix_cache.spill is not None and req.prompt_tokens \
                and req.prefill_pos == 0:
            # promote host-spilled continuation blocks back to HBM now,
            # so the match below (and the claim) sees them as resident —
            # a prefix the routing peek counted never silently recomputes
            self.spill_promoted_tokens += self.prefix_cache.prefetch(
                req.prompt_tokens)
        hit = 0 if req.prefill_pos != 0 else self._match_prefix(req)
        if not self.prefix_cache.can_acquire(req.prompt_tokens or (),
                                             hit, need):
            return False       # memory-blocked: no executor side effects
        if hit:
            claim = getattr(self.executor, "claim_prefix", None)
            if claim is not None:
                hit = claim(req, hit)
            hit -= hit % self.prefix_cache.block_size
        if not self.prefix_cache.acquire(req.rid, req.prompt_tokens or (),
                                         hit, need):
            # only reachable when the executor SHRANK the hit (more fresh
            # blocks needed than pre-checked): unwind the slot claim —
            # the executor re-registers the claimed row as a donor
            self.executor.release(req)
            return False
        self.cache_lookups += 1                    # one per admission
        if hit:
            self.cache_hits += 1
            self.cached_prefill_tokens += hit
            req.prefill_pos = hit
            req.cached_prefix_len = hit
        self.executor.add_request(req)
        return True

    def _admit_restore(self, req: Request,
                       now: Optional[float] = None) -> bool:
        """Land a warm-recovery restore: resume the victim from its
        checkpointed stream position instead of re-prefilling its whole
        context from token 0.  A bookkeeping-only executor (the sim's
        token oracle) restores from the progress record alone; a live
        executor adopts the materialized engine state via the ordinary
        migration landing (``insert_state``) — without one it MUST fall
        back to cold recompute, since resuming bookkeeping past KV that
        does not exist would decode garbage.  Returns False only on
        memory pressure (head-of-line retry, nothing consumed)."""
        rs = req.restore_state
        engine = rs.get("engine")
        bookkeeping = getattr(self.executor, "bookkeeping_only", False)
        if not bookkeeping and (
                engine is None or engine.get("block_size")
                != getattr(self.executor, "cache_block_size", None)):
            return self._restore_cold(req, now)
        ctx = rs["pos"] if bookkeeping else engine["pos"]
        req.recompute_offset = req.output_len
        req.prefill_pos = ctx - req.output_len
        # final footprint matches the cold path exactly: the full
        # recompute stream (prompt + emitted output) plus growth headroom
        total = req.context_len + req.prefill_remaining + 64
        if not self.allocator.can_allocate(total):
            return False
        if bookkeeping:
            self.allocator.allocate(req.rid, total)
        else:
            from repro.engine.engine import MigrationFormatError
            try:
                # the can_allocate(total) pre-check above guarantees the
                # landing never defers (total >= the state's pos+headroom)
                self.executor.insert_state(req, engine)
            except MigrationFormatError:
                return self._restore_cold(req, now)
            self.allocator.extend(req.rid, total)
        self.executor.add_request(req)
        req.restore_state = None
        self.warm_restores += 1
        self.warm_restored_tokens += ctx
        if self.tracer is not None and now is not None:
            self.tracer.event(req.rid, now, "warm_restore", iid=self.iid,
                              pos=ctx, materialized=engine is not None)
        return True

    def _restore_cold(self, req: Request,
                      now: Optional[float] = None) -> bool:
        """This executor cannot host the restore plan: drop it and take
        the ordinary cold recompute-from-0 admission path."""
        req.restore_state = None
        req.recompute_offset = req.output_len
        req.prefill_pos = -req.output_len
        self.warm_fallbacks += 1
        if self.tracer is not None and now is not None:
            self.tracer.event(req.rid, now, "warm_fallback", iid=self.iid)
        return self._admit_prefill(req, now)

    def _preempt(self, req: Request, now: Optional[float] = None):
        self.decoding.pop(req.rid, None)
        if self.allocator.holds(req.rid):
            self.allocator.free(req.rid)
        self.executor.release(req)
        # recompute: remaining prefill = full context (prompt + generated);
        # the engine recovers true cache positions (and the regenerated
        # token stream) via recompute_offset
        req.recompute_offset = req.output_len
        req.prefill_pos = -req.output_len
        req.state = State.QUEUED
        if self.tracer is not None and now is not None:
            self.tracer.event(req.rid, now, "preempt", iid=self.iid,
                              ctx=req.context_len)
            self.tracer.phase(req.rid, now, "queue", reason="preempt")
        self.prefill_queue.appendleft(req)

    def iteration_duration(self, plan: IterationPlan) -> float:
        if plan.horizon <= 1:
            return self.cost.iteration_time(
                [(t, r.prefill_pos) for r, t in plan.prefill_items],
                [r.context_len for r in plan.decode_reqs])
        # a K-horizon models exactly the K single decode iterations it
        # fuses: contexts grow one token per step, so the per-step
        # durations (kept for token-timestamp spreading) sum to what a
        # K=1 schedule would have charged over the same tokens
        ctxs = [r.context_len for r in plan.decode_reqs]
        plan.step_durations = [
            self.cost.iteration_time([], [c + s for c in ctxs])
            for s in range(plan.horizon)]
        return sum(plan.step_durations)

    # ------------------------------------------------------------------
    # iteration execution: dispatch / commit (run_iteration = both)
    # ------------------------------------------------------------------
    def dispatch_iteration(self, now: float) -> Optional[float]:
        """Build a plan and hand it to the executor WITHOUT waiting for
        device results (``step_async`` when the executor has one).
        Returns the modeled duration, or None when nothing is
        schedulable; ``commit_iteration`` finishes the iteration."""
        plan = self.build_plan(now)
        if plan.empty():
            return None
        dur = self.iteration_duration(plan)
        # the watchdog's step deadline is the COST MODEL's expectation —
        # an injected/real stall extends the actual duration past it
        self.last_progress = now
        self.step_deadline = now + dur
        if now < self.stall_until:
            extra = self.stall_until - now
            dur += extra
            # the sync path commits in the same event, so the watchdog
            # can never catch step_deadline mid-flight — record the
            # overrun for its next sweep instead
            self.overrun = max(self.overrun, extra)
            if self.tracer is not None:
                self.tracer.global_event(now, "stall", iid=self.iid,
                                         extra_s=round(extra, 6))
        step_fn = getattr(self.executor, "step_async", None)
        # stage the plan BEFORE the executor call: if the step raises
        # (device fault), the fault handler's evacuation can still find
        # every request riding the plan (a fully-taken prefill is
        # already popped off the queue by build_plan)
        self._inflight = (plan, None, now, dur)
        pending = step_fn(plan) if step_fn is not None else None
        self._inflight = (plan, pending, now, dur)
        self.busy_until = now + dur
        return dur

    def has_inflight(self) -> bool:
        return self._inflight is not None

    def pending_step(self):
        """The in-flight iteration's unresolved executor step, if any —
        the serving loop polls this to prefetch device results during
        idle pacing gaps (keeps the inflight tuple's layout private)."""
        if self._inflight is None:
            return None
        pending = self._inflight[1]
        if pending is None or pending.resolved:
            return None
        return pending

    def commit_iteration(self, defer_emit: bool = False) -> CommitResult:
        """Resolve the in-flight step (the one blocking readback) and
        apply request/latency bookkeeping.  With ``defer_emit`` the
        per-token sink callbacks are returned instead of fired, so the
        caller can dispatch the next horizon first and stream these
        while the device computes (one-horizon-lagged consumption)."""
        plan, pending, t0, dur = self._inflight
        # resolve BEFORE discarding the in-flight record: if the
        # readback raises (device fault), the fault handler's
        # evacuation still sees the plan's requests
        if pending is not None:
            eos = pending.resolve()
            emitted = pending.emitted
        else:
            eos = self.executor.execute(plan)
            emitted = {}
        self._inflight = None
        end = t0 + dur
        events: List[Tuple[Request, float]] = []

        def emit(req, t):
            if self.token_sink is None:
                return
            if defer_emit:
                events.append((req, t))
            else:
                self.token_sink(req, t)

        prefill_done: List[Request] = []
        finished: List[Request] = []
        tr = self.tracer
        for req, take in plan.prefill_items:
            if tr is not None:
                # phase opens at the chunk's dispatch time (same-phase
                # transitions merge, so later chunks keep the start)
                tr.phase(req.rid, t0, "prefill", iid=self.iid)
                tr.event(req.rid, t0, "prefill_chunk", take=take,
                         pos=req.prefill_pos,
                         cached=req.cached_prefix_len)
            req.prefill_pos += take
            req.prefill_instance = (self.iid if req.prefill_instance is None
                                    else req.prefill_instance)
            self.prefill_token_count += take
            if req.prefill_remaining == 0:
                if self.prefix_cache is not None and req.prompt_tokens:
                    # publish the prompt's blocks for future prefix hits
                    self.prefix_cache.commit(req.rid, req.prompt_tokens)
                # prefill emits the first token — which may already be EOS
                # (or already exhaust the request's output budget:
                # single-token scoring/classification traffic never
                # reaches decode)
                req.record_token(end)
                emit(req, end)
                if eos.get(req.rid, False) or req.done():
                    req.state = State.FINISHED
                    req.finish_reason = self._finish_reason(req)
                    req.finish_time = end
                    self.remove_request(req)
                    finished.append(req)
                else:
                    prefill_done.append(req)

        K = plan.horizon
        budgets = plan.decode_budgets or [1] * len(plan.decode_reqs)
        counts = [emitted.get(r.rid, b)
                  for r, b in zip(plan.decode_reqs, budgets)]
        # spread horizon token timestamps over the modeled per-step
        # durations, exactly where a K=1 schedule would have put them
        # (in-flight TPOT telemetry reads per-step latency, not dur/1)
        last_t = [end] * len(plan.decode_reqs)
        t = t0
        for s in range(K):
            t = end if K == 1 else t + plan.step_durations[s]
            for i, (req, c) in enumerate(zip(plan.decode_reqs, counts)):
                if s >= c:
                    continue
                if s == 0:
                    req.interference_tokens += plan.prefill_tokens
                req.record_token(t)
                emit(req, t)
                self.decode_token_count += 1
                last_t[i] = t
        if tr is not None:
            for i, (req, c) in enumerate(zip(plan.decode_reqs, counts)):
                # per-commit decode record: fused horizon K, tokens this
                # commit actually produced, and the co-batched prefill
                # tokens that slowed every step (interference)
                tr.event(req.rid, last_t[i], "decode_commit", k=K,
                         tokens=c, interference=plan.prefill_tokens)
        for i, req in enumerate(plan.decode_reqs):
            if eos.get(req.rid, False) or req.done():
                req.state = State.FINISHED
                req.finish_reason = self._finish_reason(req)
                req.finish_time = last_t[i]
                self.remove_request(req)
                finished.append(req)
        self.interference_log.append(
            (plan.prefill_tokens, len(plan.decode_reqs)))
        self.iterations += 1
        self.busy_until = end
        self.last_progress = end
        self.step_deadline = float("inf")
        return CommitResult(dur, prefill_done, finished, events)

    @staticmethod
    def _finish_reason(req: Request) -> str:
        """OpenAI semantics: "length" when generation hit the token cap,
        "stop" when the model stopped itself (EOS / hidden output
        length) before the cap."""
        return "length" if req.output_len >= req.max_new_tokens else "stop"

    def run_iteration(self, now: float) -> Tuple[float, List[Request], List[Request]]:
        """Execute one iteration starting at ``now`` (synchronous:
        dispatch + commit back-to-back).

        Returns (duration, prefill_completed, decode_finished)."""
        dur = self.dispatch_iteration(now)
        if dur is None:
            return 0.0, [], []
        res = self.commit_iteration()
        return res.duration, res.prefill_done, res.finished

    # ------------------------------------------------------------------
    # migration support (flowing decode)
    # ------------------------------------------------------------------
    def remove_request(self, req: Request):
        self.decoding.pop(req.rid, None)
        if self.allocator.holds(req.rid):
            self.allocator.free(req.rid)
        self.executor.release(req)

    def eject(self, req: Request):
        """Remove for migration; returns opaque engine state."""
        state = self.executor.extract_state(req)
        self.decoding.pop(req.rid, None)
        if req in self.pending_decode:
            self.pending_decode.remove(req)
        if self.allocator.holds(req.rid):
            self.allocator.free(req.rid)
        self.executor.release(req)
        return state

    def inject(self, req: Request, state):
        """Receive a migrated decode request (allocation happens at
        admission time via pending queue)."""
        self.executor.insert_state(req, state)
        self.pending_decode.append(req)

    def has_work(self) -> bool:
        return bool(self.prefill_queue or self.decoding or
                    self.pending_decode)

    # ------------------------------------------------------------------
    # fault tolerance: abort / evacuation / crash wipe
    # ------------------------------------------------------------------
    def abort_request(self, req: Request) -> bool:
        """Remove ``req`` from this instance and free everything it
        holds (client abort).  The caller guarantees the request is not
        inside an in-flight iteration — those are collected at commit.
        Returns True when the request was resident here."""
        found = False
        if req in self.prefill_queue:
            self.prefill_queue.remove(req)
            found = True
        if req in self.pending_decode:
            self.pending_decode.remove(req)
            found = True
        if self.decoding.pop(req.rid, None) is not None:
            found = True
        if found:
            if self.allocator.holds(req.rid):
                self.allocator.free(req.rid)
            self.executor.release(req)
        return found

    def _abort_inflight(self) -> Optional[IterationPlan]:
        """Discard the in-flight iteration (the instance is being failed
        or quarantined): the device result is abandoned, no tokens are
        applied.  Returns the abandoned plan so the caller can evacuate
        requests that live only in it (a fully-taken prefill is popped
        off the queue at dispatch)."""
        if self._inflight is None:
            return None
        plan, pending, _, _ = self._inflight
        self._inflight = None
        if pending is not None and not pending.resolved:
            abort = getattr(self.executor, "abort_step", None)
            if abort is not None:
                abort(pending)
            else:
                pending.resolved = True
        self.step_deadline = float("inf")
        return plan

    def evacuate(self) -> List[Request]:
        """Pull every resident request off this instance — queued
        prefills, pending and active decodes, and anything riding the
        abandoned in-flight plan — freeing their blocks and executor
        rows.  Returns the victims for the cluster to re-route through
        preemption-by-recompute (or fail, under fail-stop)."""
        plan = self._abort_inflight()
        victims: List[Request] = []
        seen = set()

        def take(r: Request):
            if r.rid not in seen:
                seen.add(r.rid)
                victims.append(r)

        for r in self.prefill_queue:
            take(r)
        for r in self.pending_decode:
            take(r)
        for r in list(self.decoding.values()):
            take(r)
        if plan is not None:
            for r, _ in plan.prefill_items:
                take(r)
            for r in plan.decode_reqs:
                take(r)
        self.prefill_queue.clear()
        self.pending_decode.clear()
        self.decoding.clear()
        for r in victims:
            if self.allocator.holds(r.rid):
                self.allocator.free(r.rid)
            self.executor.release(r)
        return victims

    def wipe_cache(self):
        """Total HBM/KV loss (crash): drop the prefix cache — host spill
        tier included, the whole node is gone — and let the executor
        forget device-side residue that outlives requests (donor rows,
        deferred migration payloads)."""
        if self.prefix_cache is not None:
            self.prefix_cache.clear()
        hook = getattr(self.executor, "on_crash", None)
        if hook is not None:
            hook()

    # ------------------------------------------------------------------
    # hot-prefix replication (cross-instance, block-granular)
    # ------------------------------------------------------------------
    def hot_prefixes(self, max_paths: int = 2,
                     min_hits: int = 3) -> List[Tuple[tuple, int]]:
        """This instance's hottest matchable token prefixes (by touching
        match count) — the controller's replication candidates."""
        if self.prefix_cache is None:
            return []
        return self.prefix_cache.hot_prefixes(max_paths, min_hits)

    def export_prefix(self, tokens: Sequence[int]):
        """Opaque replication payload for the resident full-block prefix
        of ``tokens`` (None when nothing is cached).  Side-effect free.
        On a real engine the payload carries gathered pool tensors; the
        simulator ships bookkeeping only."""
        exp = getattr(self.executor, "export_prefix_blocks", None)
        if exp is not None:
            return exp(tokens)
        pc = self.prefix_cache
        if pc is None:
            return None
        n = len(tokens) // pc.block_size
        path = pc.tree.match(tokens, n, touch=False)
        if not path:
            return None
        return {"paged_blocks": None, "n_blocks": len(path),
                "tokens": list(tokens[:len(path) * pc.block_size]),
                "kv_format": "sim"}

    def replicate_in(self, state) -> int:
        """Land a replicated prefix payload into the local cache.
        Returns blocks newly admitted (0 when already resident or no
        free room — replicas never evict local content)."""
        imp = getattr(self.executor, "import_prefix_blocks", None)
        if imp is not None:
            landed = imp(state)
        else:
            pc = self.prefix_cache
            if pc is None:
                return 0
            res = pc.admit_replica(state["tokens"], state["n_blocks"])
            landed = 0 if res is None else len(res[1]) - res[0]
        self.replicas_in += landed
        return landed
