"""Per-architecture-family GSPMD sharding rules.

Mesh axes: ``("data", "model")`` single-pod (16x16 = 256 chips) or
``("pod", "data", "model")`` multi-pod (2x16x16 = 512).  Batch shards
over ("pod","data"); tensor/expert parallelism over "model".

Rules are name-based over the params pytree (paths end in a leaf name
that identifies the op):

  last-axis 'model'      : wq wk wv w_gate w_up in_proj bq bk bv conv_w
                           conv_b dt_bias A_log D norm_w embed-d lm_head-V
  second-to-last 'model' : wo w_down out_proj
  expert axis 'model'    : moe w_gate/w_up/w_down ([E, d, f] etc.)
  replicated             : ln* q_norm k_norm final_norm router

GSPMD pads non-divisible dims (40 heads over 16, 40 experts over 16),
which the dry-run memory analysis accounts for honestly.

Activations / caches:
  tokens  [B, T]            -> (dp, None)
  KV      [n, B, S, H, D]   -> (None, dp, None, 'model', None)
  ssm     [n, B, H, P, N]   -> (None, dp, 'model', None, None)
  batch=1 (long_500k)       -> dp dropped (replicated batch)
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models import transformer as tf
from repro.models.config import ModelConfig


def data_axes(mesh) -> Tuple:
    """The composite data-parallel axis: ('pod','data') when a pod axis
    exists, else 'data'."""
    names = mesh.axis_names
    return ("pod", "data") if "pod" in names else ("data",)


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

_LAST_MODEL = {"wq", "wk", "wv", "w_gate", "w_up", "in_proj", "bq", "bk",
               "bv", "conv_w", "conv_b", "dt_bias", "A_log", "D", "norm_w",
               "projector"}
_PENULT_MODEL = {"wo", "w_down", "out_proj"}
_REPLICATED = {"ln1", "ln2", "ln_x", "ln_attn", "final_norm", "q_norm",
               "k_norm", "router"}


def _param_rule(path, leaf, model_size: int) -> P:
    """jit input shardings demand exact divisibility (GSPMD pads only
    intermediates), so every rule falls back along a preference chain and
    ends replicated if nothing divides."""
    names = [p.key for p in path if hasattr(p, "key")]
    name = names[-1] if names else ""
    in_moe = "moe" in names
    nd = leaf.ndim

    def at(*axes: int) -> P:
        for axis in axes:
            if leaf.shape[axis] % model_size == 0 and leaf.shape[axis] > 1:
                spec = [None] * nd
                spec[axis] = "model"
                return P(*spec)
        return P()

    if name == "embed":
        return at(-1)                      # [V, d] shard d (cheap gather)
    if name == "lm_head":
        return at(-1, -2)                  # [d, V] vocab, else d
    if name in _REPLICATED:
        return P()
    if in_moe and name in ("w_gate", "w_up", "w_down"):
        return at(-3, -1, -2)              # expert parallel, else TP
    if name in _LAST_MODEL:
        return at(-1)
    if name in _PENULT_MODEL:
        return at(-2)
    return P()


def param_specs(cfg: ModelConfig, model_size: int = 16):
    shapes = tf.abstract_params(cfg)
    return jax.tree_util.tree_map_with_path(
        lambda p, l: _param_rule(p, l, model_size), shapes)


def opt_state_specs(cfg: ModelConfig, zero1: bool = False,
                    mesh=None):
    """AdamW moments shard like params.  zero1=True additionally shards
    every moment's largest divisible axis over the data axis (optimizer
    state sharding — beyond-paper §Perf optimization)."""
    from repro.training.optimizer import OptState
    ps = param_specs(cfg)
    if not zero1:
        return OptState(step=P(), m=ps, v=ps)

    shapes = tf.abstract_params(cfg)
    dp = data_axes(mesh) if mesh is not None else ("data",)

    def zspec(spec: P, leaf) -> P:
        entries = list(spec) + [None] * (leaf.ndim - len(spec))
        # put dp on the first free axis (moments are only touched in the
        # optimizer, so extra resharding cost is confined to the update)
        for i, e in enumerate(entries):
            if e is None and leaf.shape[i] > 1:
                entries[i] = dp
                break
        return P(*entries)

    zs = jax.tree.map(zspec, ps, shapes)
    return OptState(step=P(), m=zs, v=zs)


# ---------------------------------------------------------------------------
# Activation / batch / cache specs
# ---------------------------------------------------------------------------

def batch_specs(cfg: ModelConfig, mesh, batch: int, family_inputs: bool
                = True):
    dp = data_axes(mesh)
    bdim = dp if batch > 1 else None
    spec = {"tokens": P(bdim, None), "labels": P(bdim, None)}
    if cfg.family == "vlm":
        spec["image_embeds"] = P(bdim, None, None)
    if cfg.family == "audio":
        spec["audio_embeds"] = P(bdim, None, None)
    return spec


def cache_specs(cfg: ModelConfig, mesh, batch: int, max_seq: int,
                cross_len: int = 8, kv_mode: str = "auto"):
    """Spec tree congruent with tf.init_cache output.

    kv_mode:
      'auto'  — KV heads shard over 'model' when divisible, else the
                sequence axis (baseline; S-sharded writes reshard in-scan
                and can trigger GSPMD full rematerialization).
      'batch' — KV shards over the data axis only, replicated across
                'model': every cache write is device-local (§Perf
                optimization for collective-bound prefill), at the cost
                of model_size x more KV memory per device.
      'seq'   — force sequence-axis sharding.
    """
    dp = data_axes(mesh)
    model_size = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
    bdim = dp if batch > 1 else None
    dp_size = mesh.devices.size // model_size

    def bspec(dim: int):
        return bdim if (bdim and dim % dp_size == 0) else None

    def rule(path, leaf):
        names = [p.key for p in path if hasattr(p, "key")]
        name = names[-1] if names else ""
        if name in ("k", "v", "ck", "cv"):
            b, s, h = leaf.shape[1], leaf.shape[2], leaf.shape[3]
            if kv_mode == "batch":
                return P(None, bspec(b), None, None, None)
            if kv_mode == "seq" and s % model_size == 0:
                return P(None, bspec(b), "model", None, None)
            if kv_mode == "auto":
                if h % model_size == 0:
                    return P(None, bspec(b), None, "model", None)
                if s % model_size == 0:
                    return P(None, bspec(b), "model", None, None)
            return P(None, bspec(b), None, None, None)
        if name == "ssm":
            h = leaf.shape[2]
            m = "model" if h % model_size == 0 else None
            return P(None, bspec(leaf.shape[1]), m, None, None)
        if name == "conv":
            c = leaf.shape[3]
            m = "model" if c % model_size == 0 else None
            return P(None, bspec(leaf.shape[1]), None, m)
        return P(*([None] * leaf.ndim))

    shapes = tf.abstract_cache(cfg, batch, max_seq, cross_len=cross_len)
    return jax.tree_util.tree_map_with_path(rule, shapes)


def shard(mesh, spec_tree):
    """Spec tree -> NamedSharding tree."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree, is_leaf=lambda x: isinstance(x, P))
