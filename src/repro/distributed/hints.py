"""Optional in-model sharding hints.

The model code is mesh-agnostic; under the production meshes GSPMD
occasionally picks catastrophic layouts (e.g. sharding the head_dim
*contraction* of attention scores because the head count doesn't divide
the model axis, turning the [B,H,T,S] scores into a partial-sum
all-reduce — observed at 10 GiB per layer-chunk on qwen3 prefill_32k).

``set_hints`` installs axis names; ``constrain`` then pins intermediate
layouts with lax.with_sharding_constraint (intermediates may pad, unlike
jit inputs).  With no hints installed (CPU tests, single device) every
call is a no-op.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

_state = threading.local()


def set_hints(batch_axes: Optional[Tuple], model_axis: Optional[str],
              seq_parallel: bool = False):
    _state.batch = batch_axes
    _state.model = model_axis
    _state.seq_parallel = seq_parallel


def clear_hints():
    _state.batch = None
    _state.model = None
    _state.seq_parallel = False


@contextlib.contextmanager
def hints(batch_axes, model_axis, seq_parallel: bool = False):
    set_hints(batch_axes, model_axis, seq_parallel)
    try:
        yield
    finally:
        clear_hints()


def active() -> bool:
    return getattr(_state, "model", None) is not None


def constrain_heads(x, *, batch_first: bool = True):
    """x: [B, T, H, D] (or [B, S, H, D] KV) -> pin H to the model axis,
    B to the data axes."""
    if not active():
        return x
    b = _state.batch if batch_first and x.shape[0] > 1 else None
    return jax.lax.with_sharding_constraint(
        x, P(b, None, _state.model, None))


def constrain_tokens(x):
    """x: [B, T, d] residual-stream activations.  With seq_parallel the
    token axis shards over 'model' between blocks (sequence parallelism:
    norms/residuals run on T/16 tokens; GSPMD inserts the all-gather at
    the next matmul and a reduce-scatter after — replacing the larger
    all-reduce + full-activation all-gathers of plain TP)."""
    if not active() or not getattr(_state, "seq_parallel", False):
        # only constrain the residual stream under explicit sequence
        # parallelism: the unconditional P(b, None, None) pin can trigger
        # an XLA SPMD gather-partitioning bug for d-sharded embeddings
        # inside accumulation scans (observed on arctic train_4k)
        return x
    b = _state.batch if x.shape[0] > 1 else None
    t = _state.model if x.shape[1] % 16 == 0 else None
    return jax.lax.with_sharding_constraint(x, P(b, t, None))
