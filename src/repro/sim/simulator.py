"""High-level simulation entry points: build a cluster for a policy name
and run a workload at a given QPS — the harness behind every goodput
experiment (paper Figs 15/16, Table 2)."""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence

from repro.configs import get_config
from repro.core.cluster import Cluster, FaultToleranceConfig
from repro.core.estimator import CostModel
from repro.core.hw import InstanceSpec
from repro.core.latency import SLO, RunStats, max_goodput
from repro.core.policies import (PDAggregationPolicy, PDDisaggregationPolicy,
                                 Sliders, TaiChiPolicy, build_instances)
from repro.engine.engine import SimExecutor
from repro.sim.workload import WORKLOADS, WorkloadSpec


@dataclasses.dataclass
class ServingConfig:
    model: str = "qwen2.5-14b"
    tp: int = 4
    policy: str = "taichi"            # taichi | aggregation | disaggregation
    sliders: Sliders = dataclasses.field(
        default_factory=lambda: Sliders(n_p=2, n_d=2, s_p=1024, s_d=512))
    hbm_blocks: int = 8192            # KV blocks per instance
    block_size: int = 16
    max_ctx: int = 16384
    prefix_cache: bool = False        # shared-prefix KV cache per instance
    spill_blocks: int = 0             # host-RAM spill tier per instance


def build_cluster(sc: ServingConfig, slo: SLO, seed: int = 0,
                  executor_factory: Optional[Callable] = None,
                  taichi_flags: Optional[dict] = None,
                  async_exec: bool = False,
                  ft: Optional[FaultToleranceConfig] = None,
                  recovery=None) -> Cluster:
    cfg = get_config(sc.model)
    cost = CostModel(cfg, InstanceSpec(tp=sc.tp))
    factory = executor_factory or (lambda: SimExecutor())
    s = sc.sliders
    if sc.policy == "aggregation":
        # all instances identical: chunk = s_p everywhere, no D-heavy split
        s = Sliders(n_p=s.n_p + s.n_d, n_d=0, s_p=s.s_p, s_d=s.s_p)
        instances = build_instances(cost, s, factory, sc.hbm_blocks,
                                    sc.block_size, sc.prefix_cache,
                                    sc.spill_blocks)
        policy = PDAggregationPolicy(instances, cost, slo.ttft, slo.tpot,
                                     seed=seed)
    elif sc.policy == "disaggregation":
        # P: full-prompt chunks (no chunking), never decodes;
        # D: chunk 0 (never prefills)
        s = Sliders(n_p=s.n_p, n_d=s.n_d, s_p=sc.max_ctx, s_d=0)
        instances = build_instances(cost, s, factory, sc.hbm_blocks,
                                    sc.block_size, sc.prefix_cache,
                                    sc.spill_blocks)
        policy = PDDisaggregationPolicy(instances, cost, slo.ttft, slo.tpot,
                                        seed=seed)
    elif sc.policy == "taichi":
        instances = build_instances(cost, s, factory, sc.hbm_blocks,
                                    sc.block_size, sc.prefix_cache,
                                    sc.spill_blocks)
        policy = TaiChiPolicy(instances, cost, slo.ttft, slo.tpot,
                              sliders=s, seed=seed, **(taichi_flags or {}))
    else:
        raise ValueError(sc.policy)
    return Cluster(policy, cost, async_exec=async_exec, ft=ft,
                   recovery=recovery)


def run_sim(sc: ServingConfig, slo: SLO, workload: WorkloadSpec,
            qps: float, n_requests: int = 200, seed: int = 0,
            taichi_flags: Optional[dict] = None) -> RunStats:
    cluster = build_cluster(sc, slo, seed=seed, taichi_flags=taichi_flags)
    reqs = workload.sample_requests(n_requests, qps, seed=seed)
    cluster.run(reqs)
    st = cluster.stats(reqs, slo, qps)
    st.cluster = cluster          # expose counters for breakdown benches
    return st


def goodput_sweep(sc: ServingConfig, slo: SLO, workload: WorkloadSpec,
                  qps_grid: Sequence[float], n_requests: int = 200,
                  seed: int = 0):
    return max_goodput(
        lambda q: run_sim(sc, slo, workload, q, n_requests, seed),
        qps_grid)
