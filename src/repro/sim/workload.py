"""Synthetic workloads matched to the paper's datasets (§4.1, Fig 14).

ShareGPT and ArXiv-Summarization are not redistributable offline, so we
sample from lognormal length mixtures fitted to the paper's Fig 14
histograms, with the paper's own filters (ShareGPT <= 2048 tokens,
ArXiv <= 16384 tokens).  Arrivals are Poisson, as in the paper and in
DistServe/Sarathi.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.engine.request import Request


@dataclasses.dataclass(frozen=True)
class LengthDist:
    mu: float          # lognormal location (of token count)
    sigma: float
    lo: int
    hi: int

    def sample(self, rng, n) -> np.ndarray:
        x = rng.lognormal(self.mu, self.sigma, size=n)
        return np.clip(x.astype(int), self.lo, self.hi)


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    name: str
    prompt: LengthDist
    output: LengthDist

    def sample_requests(self, n: int, qps: float, seed: int = 0,
                        max_new_tokens: int = 4096) -> List[Request]:
        rng = np.random.default_rng(seed)
        gaps = rng.exponential(1.0 / qps, size=n)
        arrivals = np.cumsum(gaps)
        plens = self.prompt.sample(rng, n)
        olens = self.output.sample(rng, n)
        return [
            Request(prompt_len=int(p), max_new_tokens=max_new_tokens,
                    arrival=float(t), hidden_output_len=int(o))
            for p, o, t in zip(plens, olens, arrivals)
        ]


# ShareGPT-like (chatbot): median prompt ~ 250, long tail to 2048 (paper
# filter); outputs median ~ 200, tail to ~1024.
SHAREGPT = WorkloadSpec(
    name="sharegpt",
    prompt=LengthDist(mu=5.5, sigma=1.1, lo=8, hi=2048),
    output=LengthDist(mu=5.3, sigma=0.9, lo=4, hi=1024),
)

# ArXiv-Summarization-like: long prompts 2k–16k (paper §2.5 "prefill
# lengths mostly range from 2k to 16k"), short-ish summaries.
ARXIV = WorkloadSpec(
    name="arxiv",
    prompt=LengthDist(mu=8.6, sigma=0.55, lo=2048, hi=16384),
    output=LengthDist(mu=5.0, sigma=0.6, lo=32, hi=1024),
)

WORKLOADS = {w.name: w for w in (SHAREGPT, ARXIV)}
