"""Synthetic workloads matched to the paper's datasets (§4.1, Fig 14).

ShareGPT and ArXiv-Summarization are not redistributable offline, so we
sample from lognormal length mixtures fitted to the paper's Fig 14
histograms, with the paper's own filters (ShareGPT <= 2048 tokens,
ArXiv <= 16384 tokens).  Arrivals are Poisson, as in the paper and in
DistServe/Sarathi.

``MultiTurnSpec`` additionally models the traffic family the prefix
cache targets: sessions that re-send a shared system prompt plus the
growing conversation history every turn, emitting REAL token-id streams
(so block hashing sees actual content) with a controllable prefix-share
ratio.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Iterator, List, Optional, Tuple

import numpy as np

from repro.engine.request import Request


def _iter_open_loop(spec, qps: float, seed: int, max_new_tokens: int,
                    limit: Optional[int], chunk: int) -> Iterator:
    """Open-loop arrival stream over any spec with ``sample_requests``:
    sample lazily in chunks, offsetting each chunk to continue where the
    previous one ended, so a long (or unbounded when ``limit`` is None)
    run never materializes its trace."""
    t0, k, emitted = 0.0, 0, 0
    while limit is None or emitted < limit:
        batch = spec.sample_requests(chunk, qps, seed=seed + k,
                                     max_new_tokens=max_new_tokens)
        for r in batch:
            r.arrival += t0
            yield r
            emitted += 1
            if limit is not None and emitted >= limit:
                return
        t0 = batch[-1].arrival
        k += 1


@dataclasses.dataclass(frozen=True)
class LengthDist:
    mu: float          # lognormal location (of token count)
    sigma: float
    lo: int
    hi: int

    def sample(self, rng, n) -> np.ndarray:
        x = rng.lognormal(self.mu, self.sigma, size=n)
        return np.clip(x.astype(int), self.lo, self.hi)

    def mean(self) -> float:
        return float(np.exp(self.mu + self.sigma ** 2 / 2))


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    name: str
    prompt: LengthDist
    output: LengthDist
    tokenized: bool = False    # emit random token ids (no shared content
    vocab_size: int = 32000    # — a prefix-share≈0 baseline for caching)

    def sample_requests(self, n: int, qps: float, seed: int = 0,
                        max_new_tokens: int = 4096) -> List[Request]:
        rng = np.random.default_rng(seed)
        gaps = rng.exponential(1.0 / qps, size=n)
        arrivals = np.cumsum(gaps)
        plens = self.prompt.sample(rng, n)
        olens = self.output.sample(rng, n)
        return [
            Request(prompt_len=int(p), max_new_tokens=max_new_tokens,
                    arrival=float(t), hidden_output_len=int(o),
                    prompt_tokens=(
                        [int(x) for x in
                         rng.integers(1, self.vocab_size, size=int(p))]
                        if self.tokenized else None),
                    shared_prefix_len=0 if self.tokenized else None)
            for p, o, t in zip(plens, olens, arrivals)
        ]

    def iter_requests(self, qps: float, seed: int = 0,
                      max_new_tokens: int = 4096,
                      limit: Optional[int] = None,
                      chunk: int = 64) -> Iterator[Request]:
        """Open-loop arrival stream for the online serving runtime."""
        return _iter_open_loop(self, qps, seed, max_new_tokens, limit,
                               chunk)


@dataclasses.dataclass(frozen=True)
class MultiTurnSpec:
    """Multi-turn chat / agentic sessions with shared system prompts.

    Each session draws one of ``n_system_prompts`` shared system
    prefixes, then alternates user turns and (synthetic) assistant
    replies; every turn re-sends system + full history + fresh user
    tokens, so consecutive turns share a growing token prefix and
    first turns share the system prompt across sessions.  Sessions
    arrive Poisson at ``qps / mean_turns`` (request rate ≈ qps); turns
    within a session are spaced by exponential think time.

    Prefix share is controlled by the system-prompt length vs. the
    fresh-user-turn length and the turn count; ``nominal share ≈
    (system + history) / prompt`` is recorded per request in
    ``Request.shared_prefix_len`` (generator ground truth — schedulers
    must not read it)."""
    name: str
    user: LengthDist
    output: LengthDist
    system_prompt_len: int = 512
    n_system_prompts: int = 4
    turns: Tuple[int, int] = (2, 6)     # inclusive turns-per-session range
    think_time: float = 2.0             # mean seconds between turns
    vocab_size: int = 32000
    max_prompt: int = 16384

    @property
    def mean_turns(self) -> float:
        return (self.turns[0] + self.turns[1]) / 2.0

    def sample_requests(self, n: int, qps: float, seed: int = 0,
                        max_new_tokens: int = 4096) -> List[Request]:
        rng = np.random.default_rng(seed)
        systems = [
            [int(x) for x in rng.integers(1, self.vocab_size,
                                          size=self.system_prompt_len)]
            for _ in range(self.n_system_prompts)]
        reqs: List[Request] = []
        t = 0.0
        while len(reqs) < n:
            t += rng.exponential(self.mean_turns / qps)
            arr = t
            n_turns = int(rng.integers(self.turns[0], self.turns[1] + 1))
            history = list(systems[int(rng.integers(self.n_system_prompts))])
            for turn in range(n_turns):
                if len(reqs) >= n:
                    break
                u = int(self.user.sample(rng, 1)[0])
                prompt = history + [
                    int(x) for x in rng.integers(1, self.vocab_size, size=u)]
                if len(prompt) > self.max_prompt:
                    break                      # context budget: end session
                o = int(self.output.sample(rng, 1)[0])
                reqs.append(Request(
                    prompt_len=len(prompt), max_new_tokens=max_new_tokens,
                    arrival=arr, hidden_output_len=o,
                    prompt_tokens=prompt,
                    shared_prefix_len=len(history)))
                # next turn re-sends this prompt + a synthetic stand-in
                # for the assistant reply (sim outputs have no token ids)
                history = prompt + [
                    int(x) for x in rng.integers(1, self.vocab_size, size=o)]
                arr += rng.exponential(self.think_time)
        reqs.sort(key=lambda r: r.arrival)
        return reqs

    def iter_requests(self, qps: float, seed: int = 0,
                      max_new_tokens: int = 4096,
                      limit: Optional[int] = None,
                      chunk: int = 64) -> Iterator[Request]:
        """Open-loop stream (sessions regenerate per chunk — session
        continuity holds within a chunk, which is what the prefix cache
        exploits anyway)."""
        return _iter_open_loop(self, qps, seed, max_new_tokens, limit,
                               chunk)


@dataclasses.dataclass(frozen=True)
class Phase:
    """One leg of a drifting workload: draw arrivals from ``spec`` for
    ``duration`` simulated seconds at ``qps_scale`` x the base rate."""
    spec: object                       # WorkloadSpec | MultiTurnSpec
    duration: float
    qps_scale: float = 1.0


@dataclasses.dataclass(frozen=True)
class PhaseDriftSpec:
    """Traffic whose character shifts mid-run — e.g. prompt-heavy
    (summarization burst) -> decode-heavy (generation burst) ->
    multiturn (chat with shared prefixes).  This is the workload the
    online slider controller exists for: a configuration frozen for any
    single phase leaves goodput on the table in the others.

    ``iter_requests`` yields requests in arrival order, one phase after
    another, so the serving loop can ingest them open-loop without
    materializing the full trace."""
    name: str
    phases: Tuple[Phase, ...]

    @property
    def total_duration(self) -> float:
        return sum(p.duration for p in self.phases)

    def iter_requests(self, qps: float, seed: int = 0,
                      max_new_tokens: int = 4096) -> Iterator[Request]:
        t0 = 0.0
        for k, ph in enumerate(self.phases):
            q = max(qps * ph.qps_scale, 1e-6)
            # oversample, keep arrivals inside the phase window
            n_est = max(8, int(q * ph.duration * 2) + 16)
            for r in ph.spec.sample_requests(n_est, q, seed=seed + k,
                                             max_new_tokens=max_new_tokens):
                if r.arrival >= ph.duration:
                    break
                r.arrival += t0
                yield r
            t0 += ph.duration

    def sample_requests(self, n: int, qps: float, seed: int = 0,
                        max_new_tokens: int = 4096) -> List[Request]:
        """Materialized view (capped at ``n``) for the batch harnesses;
        the drift itself is bounded by phase durations, not ``n``."""
        return list(itertools.islice(
            self.iter_requests(qps, seed, max_new_tokens), n))


def measured_prefix_share(reqs) -> float:
    """Mean fraction of prompt tokens previously emitted in the same
    session/system-prompt group (generator ground truth)."""
    shares = [r.shared_prefix_len / r.prompt_len for r in reqs
              if r.shared_prefix_len is not None and r.prompt_len > 0]
    return float(np.mean(shares)) if shares else 0.0


# ShareGPT-like (chatbot): median prompt ~ 250, long tail to 2048 (paper
# filter); outputs median ~ 200, tail to ~1024.
SHAREGPT = WorkloadSpec(
    name="sharegpt",
    prompt=LengthDist(mu=5.5, sigma=1.1, lo=8, hi=2048),
    output=LengthDist(mu=5.3, sigma=0.9, lo=4, hi=1024),
)

# ArXiv-Summarization-like: long prompts 2k–16k (paper §2.5 "prefill
# lengths mostly range from 2k to 16k"), short-ish summaries.
ARXIV = WorkloadSpec(
    name="arxiv",
    prompt=LengthDist(mu=8.6, sigma=0.55, lo=2048, hi=16384),
    output=LengthDist(mu=5.0, sigma=0.6, lo=32, hi=1024),
)

# Multi-turn chat: ~500-token shared system prompt, short fresh user
# turns, history re-sent every turn — prefix share rises from ~0.65 on
# first turns toward ~0.9 deep into a session.
MULTITURN = MultiTurnSpec(
    name="multiturn",
    user=LengthDist(mu=5.2, sigma=0.7, lo=16, hi=1024),
    output=LengthDist(mu=5.3, sigma=0.9, lo=4, hi=1024),
    system_prompt_len=512, n_system_prompts=4, turns=(2, 6),
    think_time=2.0)

# Agentic loops: one long shared tool/system preamble, tiny fresh
# deltas, many turns — the extreme prefix-share end.
AGENTIC = MultiTurnSpec(
    name="agentic",
    user=LengthDist(mu=4.2, sigma=0.5, lo=8, hi=256),
    output=LengthDist(mu=4.5, sigma=0.6, lo=8, hi=256),
    system_prompt_len=2048, n_system_prompts=2, turns=(4, 10),
    think_time=0.5)

# Prompt-heavy: long prompts, single-token outputs (scoring /
# classification / reranking traffic) — pure TTFT-bound load whose
# capacity scales with how many instances take real prefill chunks
# (aggregation-ward slider settings win).
PROMPT_HEAVY = WorkloadSpec(
    name="prompt_heavy",
    prompt=LengthDist(mu=7.5, sigma=0.4, lo=1024, hi=4096),
    output=LengthDist(mu=0.0, sigma=0.0, lo=1, hi=1),
)

# Decode-heavy: short prompts, long generations — a decode population
# large enough that TPOT is bound by batch size and chunk interference
# (disaggregation-ward settings win: small S_D, more D-heavy instances).
DECODE_HEAVY = WorkloadSpec(
    name="decode_heavy",
    prompt=LengthDist(mu=5.7, sigma=0.35, lo=128, hi=512),
    output=LengthDist(mu=6.1, sigma=0.3, lo=256, hi=768),
)

# The controller's canonical scenario: prompt-heavy -> decode-heavy ->
# multiturn.  No static slider setting is right for all three phases:
# the burst wants every instance prefilling, the decode tsunami wants
# small chunks and a D-rich ratio, and the multiturn tail re-sends
# growing histories (prefill pressure back up, interference still
# fatal).  The decode-heavy leg runs at 2.5x the base rate — token
# demand, not request rate, is what's comparable across phases.
DRIFT = PhaseDriftSpec(
    name="drift",
    phases=(Phase(PROMPT_HEAVY, 24.0, qps_scale=1.4),
            Phase(DECODE_HEAVY, 24.0, qps_scale=1.35),
            Phase(MULTITURN, 32.0, qps_scale=1.1)))

WORKLOADS = {w.name: w for w in (SHAREGPT, ARXIV, MULTITURN, AGENTIC,
                                 PROMPT_HEAVY, DECODE_HEAVY, DRIFT)}
