# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
"""Shared kernel-backend selection.

Pallas kernels lower natively on TPU and fall back to the Pallas
interpreter everywhere else (CPU CI, local dev).  Every ops.py wrapper
resolves its ``interpret`` flag through :func:`resolve_interpret` so the
decision is made in exactly one place:

  * explicit ``interpret=True/False`` at the call site always wins;
  * else the ``REPRO_KERNELS_INTERPRET`` env var (``1``/``true`` forces
    the interpreter, ``0``/``false`` forces native lowering);
  * else autodetect: native iff the default JAX backend is TPU.
"""
from __future__ import annotations

import os
from typing import Optional

_ENV = "REPRO_KERNELS_INTERPRET"


def backend_is_tpu() -> bool:
    import jax
    try:
        return jax.default_backend() == "tpu"
    except Exception:          # backend init failure: interpret is safe
        return False


def resolve_interpret(interpret: Optional[bool] = None) -> bool:
    """Resolve an ops-level ``interpret`` argument to a concrete bool."""
    if interpret is not None:
        return bool(interpret)
    env = os.environ.get(_ENV)
    if env is not None and env.strip() != "":
        return env.strip().lower() not in ("0", "false", "no")
    return not backend_is_tpu()


def kernels_native_default() -> bool:
    """Serving-default kernel wiring: True when the resolved backend
    lowers Pallas natively (real TPU, or the env var forcing native) —
    serving entry points then flip ``attention.use_kernels(True)`` so
    the paged decode/prefill kernels dereference block tables at DMA
    time instead of materializing the jnp gather view."""
    return not resolve_interpret(None)
