"""Pallas TPU kernel: Mamba2 SSD chunked scan.

TPU adaptation of the SSD algorithm (arXiv:2405.21060, GPU Triton
kernels): the chunk axis is the innermost sequential grid dimension and
the inter-chunk recurrent state [P, N] lives in VMEM scratch — the TPU
systolic analogue of the GPU's separate state-passing kernel launch.
Within a chunk everything is MXU matmuls on [L, N] x [N, P] tiles:

  intra:   Y_c  = (C_c B_c^T ∘ Decay) (x_c * dt_c)
  inter:   Y_c += (C_c ∘ exp(cum)) S_prev^T
  state:   S    = exp(seg) S_prev + (x_c dt_c ∘ sdecay)^T B_c

Inputs are pre-fused in ops.py: xdt = x*dt and dA = dt*A are elementwise
and cheaper to compute outside the kernel (keeps VMEM traffic to the
minimum set of operands).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(xdt_ref, dA_ref, b_ref, c_ref, s0_ref, y_ref, sfin_ref,
            state_ref, *, L: int, n_chunks: int):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        state_ref[...] = s0_ref[0, 0].astype(jnp.float32)

    xdt = xdt_ref[0, 0].astype(jnp.float32)       # [L, P]
    dA = dA_ref[0, 0].astype(jnp.float32)         # [L, 1] column
    B = b_ref[0, 0].astype(jnp.float32)           # [L, N]
    C = c_ref[0, 0].astype(jnp.float32)           # [L, N]

    cum = jnp.cumsum(dA[:, 0])                    # [L] inclusive
    seg = cum[L - 1]

    # intra-chunk: (C B^T ∘ decay) xdt
    cb = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [L, L]
    li = cum[:, None]
    lj = cum[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    # mask inside the exponent (anti-causal li - lj > 0 would overflow)
    decay = jnp.exp(jnp.where(ii >= jj, li - lj, -1e30))
    y = jax.lax.dot_general(cb * decay, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # [L, P]

    # inter-chunk: C exp(cum) S_prev^T   (state [P, N])
    s_prev = state_ref[...]
    y += jax.lax.dot_general(C * jnp.exp(cum)[:, None], s_prev,
                             (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)

    # state update: S = exp(seg) S_prev + (xdt ∘ sdecay)^T B
    sdecay = jnp.exp(seg - cum)[:, None]          # [L, 1]
    upd = jax.lax.dot_general(xdt * sdecay, B, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # [P, N]
    state_ref[...] = s_prev * jnp.exp(seg) + upd

    y_ref[0, 0] = y.astype(y_ref.dtype)

    @pl.when(c == n_chunks - 1)
    def _emit_state():
        sfin_ref[0, 0] = state_ref[...]


def ssd_scan_kernel(xdt, dA, B, C, s0, *, chunk: int, interpret: bool = True):
    """xdt: [b, h, t, p]; dA: [b, h, t, 1]; B, C: [b, h, t, n] (already
    repeated over head groups); s0: [b, h, p, n] f32.

    Returns (y [b, h, t, p], final_state [b, h, p, n] f32).
    """
    b, h, t, p = xdt.shape
    n = B.shape[-1]
    assert t % chunk == 0, (t, chunk)
    nc = t // chunk
    kern = functools.partial(_kernel, L=chunk, n_chunks=nc)
    y, sfin = pl.pallas_call(
        kern,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda i, j, c: (i, j, c, 0)),
            pl.BlockSpec((1, 1, chunk, 1), lambda i, j, c: (i, j, c, 0)),
            pl.BlockSpec((1, 1, chunk, n), lambda i, j, c: (i, j, c, 0)),
            pl.BlockSpec((1, 1, chunk, n), lambda i, j, c: (i, j, c, 0)),
            pl.BlockSpec((1, 1, p, n), lambda i, j, c: (i, j, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda i, j, c: (i, j, c, 0)),
            pl.BlockSpec((1, 1, p, n), lambda i, j, c: (i, j, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, t, p), xdt.dtype),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(xdt, dA, B, C, s0)
    return y, sfin
