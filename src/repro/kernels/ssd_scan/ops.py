"""Jit'd wrapper for the SSD scan kernel, matching the signature of
``repro.models.mamba2.ssd_chunked`` so it can be swapped in via the
``ssd_fn`` hook of ``mamba2_block``."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.ssd_kernel import ssd_scan_kernel


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, B, C, chunk: int, init_state=None, *,
             interpret: bool = True):
    """Same contract as mamba2.ssd_chunked:
    x [b,t,h,p], dt [b,t,h], A [h], B/C [b,t,g,n] ->
    (y [b,t,h,p], final_state [b,h,p,n] f32)."""
    b, t, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    dtf = dt.astype(jnp.float32)
    xdt = (x.astype(jnp.float32) * dtf[..., None]).transpose(0, 2, 1, 3)
    dA = (dtf * A[None, None, :]).transpose(0, 2, 1)[..., None]  # [b,h,t,1]
    Bh = jnp.repeat(B, rep, axis=2).transpose(0, 2, 1, 3)    # [b,h,t,n]
    Ch = jnp.repeat(C, rep, axis=2).transpose(0, 2, 1, 3)
    s0 = (init_state.astype(jnp.float32) if init_state is not None
          else jnp.zeros((b, h, p, n), jnp.float32))
    y, sfin = ssd_scan_kernel(
        xdt.astype(jnp.float32), dA.astype(jnp.float32),
        Bh.astype(jnp.float32), Ch.astype(jnp.float32), s0,
        chunk=chunk, interpret=interpret)
    return y.transpose(0, 2, 1, 3).astype(x.dtype), sfin
