"""Pure-jnp oracle for the Mamba2 SSD chunked scan: re-exports the model's
``ssd_chunked`` (which is itself validated against a naive sequential
recurrence in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.mamba2 import ssd_chunked as ssd_chunked_ref


def ssd_naive_ref(x, dt, A, B, C, init_state=None):
    """O(t) sequential recurrence — ground truth for both the chunked jnp
    implementation and the Pallas kernel.

    x [b,t,h,p], dt [b,t,h], A [h], B/C [b,t,g,n] -> (y, final_state)."""
    b, t, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    Bh = jnp.repeat(B.astype(jnp.float32), rep, axis=2)
    Ch = jnp.repeat(C.astype(jnp.float32), rep, axis=2)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    s = (init_state.astype(jnp.float32) if init_state is not None
         else jnp.zeros((b, h, p, n), jnp.float32))

    def step(s, inp):
        xi, di, Bi, Ci = inp
        dA = jnp.exp(di * A[None])                       # [b,h]
        s = s * dA[..., None, None] + jnp.einsum(
            "bhp,bhn,bh->bhpn", xi, Bi, di)
        y = jnp.einsum("bhn,bhpn->bhp", Ci, s)
        return s, y

    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
          jnp.moveaxis(Bh, 1, 0), jnp.moveaxis(Ch, 1, 0))
    s, ys = jax.lax.scan(step, s, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), s
