"""Jit'd public wrapper for the decode-attention kernel: layout + padding."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.decode_attn import decode_attention_kernel


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


@functools.partial(jax.jit, static_argnames=("bk", "interpret"))
def decode_attention(q, k, v, lengths, *, bk: int = 512,
                     interpret: bool = True):
    """q: [B, Hq, D]; k, v: [B, S, Hkv, D]; lengths: [B] int32.

    Returns [B, Hq, D].
    """
    B, Hq, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    Gp = _round_up(G, 8)
    qg = q.reshape(B, Hkv, G, D)
    if Gp != G:
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, Gp - G), (0, 0)))
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    bk = min(bk, _round_up(S, 128))
    pad_s = _round_up(S, bk) - S
    if pad_s:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_s), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_s), (0, 0)))
    out = decode_attention_kernel(qg, kt, vt,
                                  lengths.astype(jnp.int32).reshape(B, 1),
                                  bk=bk, interpret=interpret)
    return out[:, :, :G].reshape(B, Hq, D)
