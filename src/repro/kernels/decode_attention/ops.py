"""Jit'd public wrappers for the decode-attention kernels: layout +
padding + backend selection (native on TPU, interpret elsewhere — see
``repro.kernels.resolve_interpret``)."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import resolve_interpret
from repro.kernels.decode_attention.decode_attn import decode_attention_kernel
from repro.kernels.decode_attention.paged_decode import (
    paged_decode_attention_kernel)


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


@functools.partial(jax.jit, static_argnames=("bk", "interpret"))
def _decode_attention(q, k, v, lengths, *, bk: int, interpret: bool):
    B, Hq, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    Gp = _round_up(G, 8)
    qg = q.reshape(B, Hkv, G, D)
    if Gp != G:
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, Gp - G), (0, 0)))
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    bk = min(bk, _round_up(S, 128))
    pad_s = _round_up(S, bk) - S
    if pad_s:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_s), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_s), (0, 0)))
    out = decode_attention_kernel(qg, kt, vt,
                                  lengths.astype(jnp.int32).reshape(B, 1),
                                  bk=bk, interpret=interpret)
    return out[:, :, :G].reshape(B, Hq, D)


def decode_attention(q, k, v, lengths, *, bk: int = 512,
                     interpret: Optional[bool] = None):
    """q: [B, Hq, D]; k, v: [B, S, Hkv, D]; lengths: [B] int32.

    Returns [B, Hq, D].
    """
    return _decode_attention(q, k, v, lengths, bk=bk,
                             interpret=resolve_interpret(interpret))


def _scale_pool_blocks(scale_pool, n_blk: int, block_size: int):
    """[P, Hkv] f32 scale pool -> [n_blk, Hkv, bs, 1] per-block DMA
    layout (mirrors the KV pool reshape)."""
    Hkv = scale_pool.shape[1]
    return (scale_pool.reshape(n_blk, block_size, Hkv)
            .transpose(0, 2, 1)[..., None])


@functools.partial(jax.jit, static_argnames=("block_size", "interpret"))
def _paged_decode(q, k_pool, v_pool, tables, lengths, k_scale, v_scale, *,
                  block_size: int, interpret: bool):
    B, Hq, D = q.shape
    Hkv = k_pool.shape[1]
    n_blk = k_pool.shape[0] // block_size
    G = Hq // Hkv
    Gp = _round_up(G, 8)
    qg = q.reshape(B, Hkv, G, D)
    if Gp != G:
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, Gp - G), (0, 0)))
    # pool [P, Hkv, D] -> [n_blk, Hkv, bs, D] for per-block DMA
    kp = k_pool.reshape(n_blk, block_size, Hkv, D).transpose(0, 2, 1, 3)
    vp = v_pool.reshape(n_blk, block_size, Hkv, D).transpose(0, 2, 1, 3)
    ks = (None if k_scale is None
          else _scale_pool_blocks(k_scale, n_blk, block_size))
    vs = (None if v_scale is None
          else _scale_pool_blocks(v_scale, n_blk, block_size))
    # unused table entries (-1) are clamped: the kernel masks them via
    # ``lengths`` before any FLOP, so the DMA target is irrelevant
    tbl = jnp.clip(tables, 0, n_blk - 1).astype(jnp.int32)
    out = paged_decode_attention_kernel(
        qg, kp, vp, tbl, lengths.astype(jnp.int32), k_scale=ks, v_scale=vs,
        interpret=interpret)
    return out[:, :, :G].reshape(B, Hq, D)


def paged_decode_attention(q, k_pool, v_pool, tables, lengths, *,
                           block_size: int, k_scale=None, v_scale=None,
                           interpret: Optional[bool] = None):
    """Paged flash-decode: q [B, Hq, D] attends over KV held in a
    physical block pool through per-sequence block tables.

    k_pool/v_pool: [P, Hkv, D] with P = num_blocks * block_size (flat
    token axis, block-major); tables: int32 [B, NB] (entries < 0 are
    unallocated); lengths: int32 [B] context lengths; k_scale/v_scale:
    optional [P, Hkv] f32 per-token scales for int8 pools (the kernel
    dequantizes per DMA'd block).
    Returns [B, Hq, D]."""
    return _paged_decode(q, k_pool, v_pool, tables, lengths,
                         k_scale, v_scale, block_size=block_size,
                         interpret=resolve_interpret(interpret))
