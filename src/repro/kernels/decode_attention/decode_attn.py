"""Pallas TPU kernel: GQA decode attention (flash-decoding style).

One new token per sequence attends over a long KV cache.  TPU adaptation:
the kv sequence is streamed through VMEM in blocks along the innermost
(sequential) grid axis with running-softmax state in VMEM scratch — the
TPU analogue of flash-decoding's split-KV reduction, without the
cross-SM combine step (the sequential grid does the combine for free).

The q "rows" axis carries the GQA group (G q-heads sharing one kv head),
padded to the 8-sublane minimum.  Per-row context lengths (continuous
batching) arrive as an int32 [B, 1] input broadcast into SMEM-like VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
            *, bk: int, n_kb: int, scale: float):
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[0, 0]
    kpos = kb * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)[0]

    @pl.when(kb * bk < length)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)              # [G', D]
        k = k_ref[0, 0].astype(jnp.float32)              # [BK, D]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # [G', BK]
        mask = (kpos < length)[None, :]
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(kb == n_kb - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def decode_attention_kernel(q, k, v, lengths, *, bk: int = 512,
                            interpret: bool = True):
    """q: [B, Hkv, G', D] (G' = padded group size); k/v: [B, Hkv, S, D];
    lengths: int32 [B, 1].  Returns [B, Hkv, G', D]."""
    B, Hkv, Gp, D = q.shape
    S = k.shape[2]
    bk = min(bk, S)
    assert S % bk == 0, (S, bk)
    n_kb = S // bk
    kern = functools.partial(_kernel, bk=bk, n_kb=n_kb, scale=D ** -0.5)
    return pl.pallas_call(
        kern,
        grid=(B, Hkv, n_kb),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, kb: (b, 0)),
            pl.BlockSpec((1, 1, Gp, D), lambda b, h, kb: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, kb: (b, h, kb, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, kb: (b, h, kb, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, Gp, D), lambda b, h, kb: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, Gp, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((Gp, D), jnp.float32),
            pltpu.VMEM((Gp, 1), jnp.float32),
            pltpu.VMEM((Gp, 1), jnp.float32),
        ],
        interpret=interpret,
    )(lengths, q, k, v)
