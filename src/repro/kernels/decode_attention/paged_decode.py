"""Pallas TPU kernel: GQA decode attention over a PAGED KV cache.

Extends ``decode_attn.py`` to a vLLM-style physical block pool: instead
of one contiguous [S, D] cache row per sequence, KV lives in a shared
pool of fixed-size token blocks and each sequence carries an int32 block
table mapping logical block index -> physical block id.

TPU adaptation: the block table is a *scalar-prefetch* input
(``PrefetchScalarGridSpec``), so the BlockSpec index map dereferences it
to pick which physical kv block to DMA for grid step (b, h, j) — the
pointer chase happens at DMA-issue time, not inside the kernel body.
The innermost grid axis walks the sequence's logical blocks with
running-softmax state in VMEM scratch, exactly like the dense
flash-decode kernel; blocks at or beyond the sequence length are
skipped (their DMA lands on a clamped block id but no FLOPs are spent).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(tbl_ref, len_ref, q_ref, k_ref, v_ref, *rest, bs: int,
            n_blk: int, scale: float, quant: bool = False):
    if quant:
        # int8 pools ride with per-token scale blocks [bs, 1]: dequant
        # happens here, on the one block already resident in VMEM
        ks_ref, vs_ref, o_ref, acc_ref, m_ref, l_ref = rest
    else:
        o_ref, acc_ref, m_ref, l_ref = rest
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[b]
    kpos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)[0]

    @pl.when(j * bs < length)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)              # [G', D]
        k = k_ref[0, 0].astype(jnp.float32)              # [bs, D]
        v = v_ref[0, 0].astype(jnp.float32)
        if quant:
            k = k * ks_ref[0, 0]
            v = v * vs_ref[0, 0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # [G', bs]
        mask = (kpos < length)[None, :]
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == n_blk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def paged_decode_attention_kernel(q, k_pool, v_pool, tables, lengths, *,
                                  k_scale=None, v_scale=None,
                                  interpret: bool = True):
    """q: [B, Hkv, G', D] (G' = padded group size);
    k_pool/v_pool: [num_blocks, Hkv, bs, D] physical block pools;
    tables: int32 [B, NB] block tables (entries clamped into range —
    out-of-context entries are masked by ``lengths``);
    lengths: int32 [B] per-sequence context lengths;
    k_scale/v_scale: optional [num_blocks, Hkv, bs, 1] f32 per-token
    dequantization scales for int8 pools (DMA'd per block through the
    same table dereference as the KV they scale).

    Returns [B, Hkv, G', D]."""
    B, Hkv, Gp, D = q.shape
    bs = k_pool.shape[2]
    NB = tables.shape[1]
    quant = k_scale is not None
    kern = functools.partial(_kernel, bs=bs, n_blk=NB, scale=D ** -0.5,
                             quant=quant)
    kv_spec = pl.BlockSpec((1, 1, bs, D),
                           lambda b, h, j, tbl, ln: (tbl[b, j], h, 0, 0))
    in_specs = [
        pl.BlockSpec((1, 1, Gp, D), lambda b, h, j, tbl, ln: (b, h, 0, 0)),
        kv_spec,
        kv_spec,
    ]
    args = [tables, lengths, q, k_pool, v_pool]
    if quant:
        sc_spec = pl.BlockSpec((1, 1, bs, 1),
                               lambda b, h, j, tbl, ln: (tbl[b, j], h, 0, 0))
        in_specs += [sc_spec, sc_spec]
        args += [k_scale, v_scale]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, NB),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, Gp, D),
                               lambda b, h, j, tbl, ln: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Gp, D), jnp.float32),
            pltpu.VMEM((Gp, 1), jnp.float32),
            pltpu.VMEM((Gp, 1), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, Gp, D), q.dtype),
        interpret=interpret,
    )(*args)
