"""Pure-jnp oracle for single-token GQA decode attention over a KV cache
with per-row valid lengths (continuous batching: each request has its own
context length)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_attention_ref(q, k, v, lengths):
    """q: [B, Hq, D]; k, v: [B, S, Hkv, D]; lengths: [B] int32 (number of
    valid cache slots per row, slot index == position).

    Returns [B, Hq, D].
    """
    B, Hq, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    g = Hq // Hkv
    qg = q.reshape(B, Hkv, g, D).astype(jnp.float32)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k.astype(jnp.float32)) * (D ** -0.5)
    mask = jnp.arange(S)[None] < lengths[:, None]          # [B, S]
    s = jnp.where(mask[:, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v.astype(jnp.float32))
    return o.reshape(B, Hq, D).astype(q.dtype)
