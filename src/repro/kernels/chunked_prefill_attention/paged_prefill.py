"""Pallas TPU kernel: chunked-prefill flash attention over a PAGED KV
cache with PER-ROW chunk geometry.

Extends ``chunked_attn.py`` in two directions the fused mixed-batch
executor needs:

  * KV lives in a physical block pool addressed through scalar-prefetch
    block tables (one table row per sequence), so sequences of wildly
    different lengths share one pool with no per-slot reservation;
  * each batch row carries its own ``start`` (absolute chunk offset)
    and ``valid`` (tokens actually present in the padded chunk), so a
    single call executes a TaiChi mixed batch: prefill chunks of
    different lengths AND decode rows (valid == 1) together.

Grid = (batch, kv head, q block, logical kv block); the kv-block axis is
innermost with running-softmax scratch, and blocks at or beyond a row's
write frontier (start + valid) are skipped.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(tbl_ref, start_ref, valid_ref, q_ref, k_ref, v_ref, *rest,
            bq: int, bs: int, tq: int, n_blk: int, scale: float,
            quant: bool = False):
    if quant:
        # int8 pools ride with per-token scale blocks [bs, 1]: dequant
        # happens here, on the one block already resident in VMEM
        ks_ref, vs_ref, o_ref, acc_ref, m_ref, l_ref = rest
    else:
        o_ref, acc_ref, m_ref, l_ref = rest
    b = pl.program_id(0)
    qb = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    start = start_ref[b]
    end = start + valid_ref[b]                    # write frontier (excl.)
    rows = qb * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0)[:, 0]
    t = jax.lax.rem(rows, tq)                     # rows are g-major
    qpos = start + t                              # [BQ]
    kpos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)[0]

    # a kv block contributes iff it holds any key before the frontier
    @pl.when(j * bs < end)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)       # [BQ, D]
        k = k_ref[0, 0].astype(jnp.float32)       # [bs, D]
        v = v_ref[0, 0].astype(jnp.float32)
        if quant:
            k = k * ks_ref[0, 0]
            v = v * vs_ref[0, 0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # [BQ, bs]
        mask = (kpos[None, :] <= qpos[:, None]) & (kpos[None, :] < end)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]                       # [BQ, 1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == n_blk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def paged_prefill_attention_kernel(q, k_pool, v_pool, tables, start, valid,
                                   *, tq: int, bq: int = 128,
                                   k_scale=None, v_scale=None,
                                   interpret: bool = True):
    """q: [B, Hkv, R, D] with R = G*Tq (g-major rows);
    k_pool/v_pool: [num_blocks, Hkv, bs, D]; tables: int32 [B, NB]
    (clamped into range); start/valid: int32 [B] per-row chunk offset
    and valid token count; k_scale/v_scale: optional
    [num_blocks, Hkv, bs, 1] f32 per-token dequantization scales for
    int8 pools.  Returns [B, Hkv, R, D]."""
    B, Hkv, R, D = q.shape
    bs = k_pool.shape[2]
    NB = tables.shape[1]
    bq = min(bq, R)
    assert R % bq == 0, (R, bq)
    n_qb = R // bq
    quant = k_scale is not None
    kern = functools.partial(_kernel, bq=bq, bs=bs, tq=tq, n_blk=NB,
                             scale=D ** -0.5, quant=quant)
    kv_spec = pl.BlockSpec((1, 1, bs, D),
                           lambda b, h, qb, j, tbl, st, vl:
                           (tbl[b, j], h, 0, 0))
    in_specs = [
        pl.BlockSpec((1, 1, bq, D),
                     lambda b, h, qb, j, tbl, st, vl: (b, h, qb, 0)),
        kv_spec,
        kv_spec,
    ]
    args = [tables, start, valid, q, k_pool, v_pool]
    if quant:
        sc_spec = pl.BlockSpec((1, 1, bs, 1),
                               lambda b, h, qb, j, tbl, st, vl:
                               (tbl[b, j], h, 0, 0))
        in_specs += [sc_spec, sc_spec]
        args += [k_scale, v_scale]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, Hkv, n_qb, NB),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, bq, D),
                               lambda b, h, qb, j, tbl, st, vl:
                               (b, h, qb, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, R, D), q.dtype),
        interpret=interpret,
    )(*args)
