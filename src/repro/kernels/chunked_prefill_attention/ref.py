"""Pure-jnp oracle for chunked-prefill attention.

A prefill *chunk* of Tq tokens (absolute positions prefix..prefix+Tq-1)
attends causally over a KV cache whose first prefix+Tq slots are valid
(slot index == absolute position; the chunk's own K/V have already been
written).  This is the compute hot-spot of chunked prefill (paper §2.3.1):
P-heavy and D-heavy instances differ only in how large Tq is.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def chunked_prefill_attention_ref(q, k, v, prefix: int):
    """q: [B, Tq, Hq, D]; k, v: [B, S, Hkv, D] with S >= prefix + Tq.

    Returns [B, Tq, Hq, D] (same dtype as q).
    """
    B, Tq, Hq, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    g = Hq // Hkv
    qg = q.reshape(B, Tq, Hkv, g, D).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    s = jnp.einsum("btkgd,bskd->bkgts", qg, kf) * (D ** -0.5)
    qpos = prefix + jnp.arange(Tq)
    kpos = jnp.arange(S)
    mask = (kpos[None, :] <= qpos[:, None]) & (kpos[None, :] < prefix + Tq)
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgts,bskd->btkgd", p, v.astype(jnp.float32))
    return o.reshape(B, Tq, Hq, D).astype(q.dtype)
