"""Pallas TPU kernel: flash attention for a chunked-prefill chunk.

TPU adaptation notes (vs the CUDA chunked-prefill kernels in vLLM):
  * no warps / shared memory — the streaming-softmax state (m, l, acc)
    lives in VMEM scratch that persists across the sequential TPU grid;
  * HBM->VMEM movement is expressed declaratively with BlockSpecs; the
    kv-block axis is the innermost grid dimension so each (batch, head,
    q-block) accumulates over kv blocks in order;
  * GQA is handled by folding the q-head group into the q-row axis
    (rows = g * Tq + t), so the MXU matmul operates on [BQ, D] x [D, BK]
    tiles with D and BK multiples of 128 and BQ a multiple of 8.

Out-of-range kv blocks (beyond the causal frontier of a q block) are
skipped with ``pl.when`` — their DMA still lands but no FLOPs are spent.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(prefix_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
            *, bq: int, bk: int, tq: int, n_kb: int, scale: float):
    kb = pl.program_id(3)
    qb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    prefix = prefix_ref[0, 0]
    rows = qb * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0)[:, 0]
    t = jax.lax.rem(rows, tq)
    qpos = prefix + t                                   # [BQ]
    kpos = kb * bk + jax.lax.broadcasted_iota(jnp.int32, (bk, 1), 0)[:, 0]

    # causal frontier: this kv block contributes iff its first key position
    # is <= the largest query position in the q block
    @pl.when(kb * bk <= prefix + (qb + 1) * bq - 1)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)             # [BQ, D]
        k = k_ref[0, 0].astype(jnp.float32)             # [BK, D]
        v = v_ref[0, 0].astype(jnp.float32)             # [BK, D]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # [BQ, BK]
        mask = (kpos[None, :] <= qpos[:, None]) & (kpos[None, :] < prefix + tq)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]                             # [BQ, 1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(kb == n_kb - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def chunked_prefill_attention_kernel(q, k, v, prefix, *, tq: int,
                                     bq: int = 128, bk: int = 128,
                                     interpret: bool = True):
    """q: [B, Hkv, R, D] with R = G*Tq (g-major rows); k/v: [B, Hkv, S, D];
    prefix: int32 [1, 1].  Returns [B, Hkv, R, D]."""
    B, Hkv, R, D = q.shape
    S = k.shape[2]
    bq = min(bq, R)
    bk = min(bk, S)
    assert R % bq == 0 and S % bk == 0, (R, bq, S, bk)
    n_qb, n_kb = R // bq, S // bk
    grid = (B, Hkv, n_qb, n_kb)

    kern = functools.partial(_kernel, bq=bq, bk=bk, tq=tq, n_kb=n_kb,
                             scale=D ** -0.5)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, qb, kb: (0, 0)),
            pl.BlockSpec((1, 1, bq, D), lambda b, h, qb, kb: (b, h, qb, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, qb, kb: (b, h, kb, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, qb, kb: (b, h, kb, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D),
                               lambda b, h, qb, kb: (b, h, qb, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, R, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(prefix, q, k, v)
