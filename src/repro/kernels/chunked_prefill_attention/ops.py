"""Jit'd public wrapper around the chunked-prefill attention kernel.

Handles layout: model-side tensors are [B, Tq, Hq, D] / [B, S, Hkv, D];
the kernel wants GQA folded into q rows ([B, Hkv, G*Tq, D], g-major) and
KV in [B, Hkv, S, D].  Pads q rows to a multiple of the q block and S to
a multiple of the kv block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.chunked_prefill_attention.chunked_attn import (
    chunked_prefill_attention_kernel)


@functools.partial(jax.jit,
                   static_argnames=("bq", "bk", "interpret"))
def chunked_prefill_attention(q, k, v, prefix, *, bq: int = 128,
                              bk: int = 128, interpret: bool = True):
    """q: [B, Tq, Hq, D]; k, v: [B, S, Hkv, D]; prefix: int32 scalar
    (absolute start position of the chunk; cache slots < prefix+Tq valid).

    Returns [B, Tq, Hq, D].
    """
    B, Tq, Hq, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    # fold heads: rows = g-major [B, Hkv, G*Tq, D]
    qr = q.reshape(B, Tq, Hkv, G, D).transpose(0, 2, 3, 1, 4)
    qr = qr.reshape(B, Hkv, G * Tq, D)
    R = G * Tq
    bq = min(bq, _round_up(R, 8))
    pad_r = _round_up(R, bq) - R
    if pad_r:
        qr = jnp.pad(qr, ((0, 0), (0, 0), (0, pad_r), (0, 0)))
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    bk = min(bk, _round_up(S, 128))
    pad_s = _round_up(S, bk) - S
    if pad_s:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_s), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_s), (0, 0)))
    prefix_arr = jnp.asarray(prefix, jnp.int32).reshape(1, 1)
    # NOTE: rows are g-major, so row % Tq == t only when padding keeps the
    # row count a multiple of Tq per g — we pass tq and mask padded rows'
    # outputs away below instead.
    out = chunked_prefill_attention_kernel(
        qr, kt, vt, prefix_arr, tq=Tq, bq=bq, bk=bk, interpret=interpret)
    out = out[:, :, :R].reshape(B, Hkv, G, Tq, D)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Tq, Hq, D)


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m
