"""Jit'd public wrappers around the chunked-prefill attention kernels.

Handles layout: model-side tensors are [B, Tq, Hq, D] / [B, S, Hkv, D];
the kernels want GQA folded into q rows ([B, Hkv, G*Tq, D], g-major) and
KV in [B, Hkv, S, D] (dense) or [num_blocks, Hkv, bs, D] (paged).  Pads
q rows to a multiple of the q block and S to a multiple of the kv block.
Backend is native on TPU, interpret elsewhere (``resolve_interpret``).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import resolve_interpret
from repro.kernels.chunked_prefill_attention.chunked_attn import (
    chunked_prefill_attention_kernel)
from repro.kernels.chunked_prefill_attention.paged_prefill import (
    paged_prefill_attention_kernel)


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


@functools.partial(jax.jit,
                   static_argnames=("bq", "bk", "interpret"))
def _chunked_prefill(q, k, v, prefix, *, bq: int, bk: int, interpret: bool):
    B, Tq, Hq, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    # fold heads: rows = g-major [B, Hkv, G*Tq, D]
    qr = q.reshape(B, Tq, Hkv, G, D).transpose(0, 2, 3, 1, 4)
    qr = qr.reshape(B, Hkv, G * Tq, D)
    R = G * Tq
    bq = min(bq, _round_up(R, 8))
    pad_r = _round_up(R, bq) - R
    if pad_r:
        qr = jnp.pad(qr, ((0, 0), (0, 0), (0, pad_r), (0, 0)))
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    bk = min(bk, _round_up(S, 128))
    pad_s = _round_up(S, bk) - S
    if pad_s:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_s), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_s), (0, 0)))
    prefix_arr = jnp.asarray(prefix, jnp.int32).reshape(1, 1)
    # NOTE: rows are g-major, so row % Tq == t only when padding keeps the
    # row count a multiple of Tq per g — we pass tq and mask padded rows'
    # outputs away below instead.
    out = chunked_prefill_attention_kernel(
        qr, kt, vt, prefix_arr, tq=Tq, bq=bq, bk=bk, interpret=interpret)
    out = out[:, :, :R].reshape(B, Hkv, G, Tq, D)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Tq, Hq, D)


def chunked_prefill_attention(q, k, v, prefix, *, bq: int = 128,
                              bk: int = 128,
                              interpret: Optional[bool] = None):
    """q: [B, Tq, Hq, D]; k, v: [B, S, Hkv, D]; prefix: int32 scalar
    (absolute start position of the chunk; cache slots < prefix+Tq valid).

    Returns [B, Tq, Hq, D].
    """
    return _chunked_prefill(q, k, v, prefix, bq=bq, bk=bk,
                            interpret=resolve_interpret(interpret))


def _scale_pool_blocks(scale_pool, n_blk: int, block_size: int):
    """[P, Hkv] f32 scale pool -> [n_blk, Hkv, bs, 1] per-block DMA
    layout (mirrors the KV pool reshape)."""
    Hkv = scale_pool.shape[1]
    return (scale_pool.reshape(n_blk, block_size, Hkv)
            .transpose(0, 2, 1)[..., None])


@functools.partial(jax.jit,
                   static_argnames=("block_size", "bq", "interpret"))
def _paged_prefill(q, k_pool, v_pool, tables, start, valid, k_scale,
                   v_scale, *, block_size: int, bq: int, interpret: bool):
    B, Tq, Hq, D = q.shape
    Hkv = k_pool.shape[1]
    n_blk = k_pool.shape[0] // block_size
    G = Hq // Hkv
    qr = q.reshape(B, Tq, Hkv, G, D).transpose(0, 2, 3, 1, 4)
    qr = qr.reshape(B, Hkv, G * Tq, D)
    R = G * Tq
    bq = min(bq, _round_up(R, 8))
    pad_r = _round_up(R, bq) - R
    if pad_r:
        qr = jnp.pad(qr, ((0, 0), (0, 0), (0, pad_r), (0, 0)))
    kp = k_pool.reshape(n_blk, block_size, Hkv, D).transpose(0, 2, 1, 3)
    vp = v_pool.reshape(n_blk, block_size, Hkv, D).transpose(0, 2, 1, 3)
    ks = (None if k_scale is None
          else _scale_pool_blocks(k_scale, n_blk, block_size))
    vs = (None if v_scale is None
          else _scale_pool_blocks(v_scale, n_blk, block_size))
    tbl = jnp.clip(tables, 0, n_blk - 1).astype(jnp.int32)
    out = paged_prefill_attention_kernel(
        qr, kp, vp, tbl, start.astype(jnp.int32), valid.astype(jnp.int32),
        tq=Tq, bq=bq, k_scale=ks, v_scale=vs, interpret=interpret)
    out = out[:, :, :R].reshape(B, Hkv, G, Tq, D)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Tq, Hq, D)


def paged_chunked_prefill_attention(q, k_pool, v_pool, tables, start, valid,
                                    *, block_size: int, bq: int = 128,
                                    k_scale=None, v_scale=None,
                                    interpret: Optional[bool] = None):
    """Paged chunked-prefill attention with PER-ROW chunk geometry.

    q: [B, Tq, Hq, D] (rows padded to a common Tq bucket);
    k_pool/v_pool: [P, Hkv, D] with P = num_blocks * block_size;
    tables: int32 [B, NB]; start/valid: int32 [B] per-row absolute chunk
    offset and valid token count (valid == 1 rows are decode steps —
    one call executes a whole mixed prefill+decode batch);
    k_scale/v_scale: optional [P, Hkv] f32 per-token scales for int8
    pools (the kernel dequantizes per DMA'd block).
    Returns [B, Tq, Hq, D]; rows/tokens beyond ``valid`` are garbage and
    must be discarded by the caller."""
    return _paged_prefill(q, k_pool, v_pool, tables, start, valid,
                          k_scale, v_scale, block_size=block_size, bq=bq,
                          interpret=resolve_interpret(interpret))
