"""Pluggable serving clocks.

The cluster's discrete-event core runs on *estimator time* (virtual
seconds).  The serving loop needs a policy for how virtual event times
relate to the caller's experience of time:

* ``VirtualClock`` — events process as fast as Python allows; ``now``
  jumps to each event's timestamp.  Deterministic: the test tier and the
  simulator run on this.
* ``WallClock`` — the loop *paces* itself to real time: before
  processing an event at virtual time ``t`` it sleeps until ``t``
  seconds after the epoch anchor.  This is the live-demo mode where
  streamed tokens arrive at the modeled rate.  If event processing
  (e.g. real JAX execution) already took longer than the modeled
  duration, no sleep happens — the loop simply runs behind, exactly
  like an overloaded server.
"""
from __future__ import annotations

import time


class VirtualClock:
    """Simulated time: no sleeping, ``now`` tracks the last event."""

    def __init__(self, start: float = 0.0):
        self.now = start

    def sleep_until(self, t: float):
        if t > self.now:
            self.now = t


class WallClock:
    """Real time, anchored at construction (virtual t=0 == anchor)."""

    def __init__(self, start: float = 0.0):
        self._anchor = time.monotonic() - start

    @property
    def now(self) -> float:
        return time.monotonic() - self._anchor

    def sleep_until(self, t: float):
        dt = t - self.now
        if dt > 0:
            time.sleep(dt)
