"""Warm recovery: request-progress checkpoints + post-crash KV
re-replication.

PR 8's fault tolerance resolves every crash victim terminally, but a
crash still costs each victim its whole prefix — evacuation is
preemption-by-recompute from token 0, and hot-prefix replicas that
lived on the dead instance stay gone until the controller's next
epoch.  The ``RecoveryManager`` converts "survive crashes" into
"barely pay for crashes":

* **Progress checkpoints.**  At every committed iteration the manager
  snapshots each resident request's stream position (prompt + emitted
  tokens processed so far), advancing a per-request record whenever it
  grew by ``checkpoint_tokens`` since the last snapshot.  The records
  are rid-keyed and token-free, so they work for the simulator's
  tokenless workloads too.

* **KV materialization** (optional, ``materialize_kv``).  When the
  executor can export paged blocks (``export_request_blocks``), the
  checkpointed blocks are copied into a cluster-level
  ``HostSpillPool`` keyed by the same chained block hashes the prefix
  tree uses — the pool lives on the router host, so a victim's blocks
  survive its instance.  Only blocks absent from the pool are copied
  (incremental), and refresh order is tail-to-head so LRU drops eat
  run tails instead of punching holes at the front.

* **Warm restore.**  On ``fail_instance`` the cluster consults
  ``plan_restore`` before falling back to recompute-from-0: the victim
  resumes from its snapshot (re-prefilling only the tokens since it),
  adopting materialized blocks directly when a live engine can land
  them — greedy token-exact either way, because the resume path is the
  ordinary recompute stream at a non-zero start position.

* **Post-crash re-replication** (``rereplicate``).  The manager records
  where hot-prefix replicas land (``on_replica_landed``); when an
  instance dies, every replicated path it held is immediately
  re-established from a surviving holder onto the coldest healthy peer
  instead of waiting for the controller's next epoch.

Everything is default-off (``RecoveryConfig.enable=False``): a cluster
without a manager attached takes none of these paths and stays
bit-identical to the pre-recovery build.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, Optional, Set, Tuple

from repro.cache.prefix_tree import chain_hashes
from repro.cache.spill import HostSpillPool


@dataclasses.dataclass
class RecoveryConfig:
    """Warm-recovery knobs (pass ``recovery=`` to ``Cluster`` /
    ``build_cluster``).  ``enable=False`` keeps every hook inert."""
    enable: bool = False
    #: snapshot a request's progress every N newly processed tokens —
    #: smaller = less re-prefill after a crash, more checkpoint work
    checkpoint_tokens: int = 32
    #: copy checkpointed KV blocks into the host-side recovery pool
    #: (needs an executor with ``export_request_blocks``; the sim's
    #: bookkeeping executor restores from the progress record alone)
    materialize_kv: bool = True
    #: recovery-pool capacity in blocks (cluster-level host RAM)
    store_blocks: int = 4096
    #: re-establish hot-prefix replicas lost with a crashed instance
    #: immediately, instead of waiting for the controller's next epoch
    rereplicate: bool = True


class RecoveryManager:
    """Cluster-level warm-recovery state: per-request progress records,
    an optional materialized-KV pool, and the replica-placement
    registry.  Survives any single instance (it models state on the
    router host, outside every instance's HBM and spill tier)."""

    def __init__(self, cfg: Optional[RecoveryConfig] = None):
        self.cfg = cfg or RecoveryConfig(enable=True)
        #: rid -> furthest checkpointed stream position (monotone)
        self._progress: Dict[int, int] = {}
        #: materialized KV blocks, keyed by chained block hash; created
        #: lazily at first capture so the block size matches the source
        #: executor's
        self.pool: Optional[HostSpillPool] = None
        #: replicated-prefix registry: tokens -> holder instance ids
        self._replicas: Dict[Tuple[int, ...], Set[int]] = {}
        # counters (exposed via Cluster.recovery_counters)
        self.checkpoints = 0
        self.ckpt_blocks = 0
        self.warm_plans = 0
        self.rereplications = 0

    # ------------------------------------------------------------------
    # checkpoint capture (called from Cluster._post_iteration: the
    # executor pipeline is flushed there on both sync and async paths)
    # ------------------------------------------------------------------
    def on_commit(self, cluster, inst, now: float):
        tracer = cluster.tracer
        for req in itertools.chain(inst.decoding.values(),
                                   inst.pending_decode,
                                   inst.prefill_queue):
            out = req.output_len
            # KV written so far covers [0, context_len - 1) once decode
            # has started (the engine's slot position trails the emitted
            # token by one); cap at stream length - 1 so a warm restore
            # always has >= 1 token left to re-prefill (the completion
            # of which emits the next NEW token, exactly like cold)
            ctx = min(req.context_len - (1 if out else 0),
                      req.prompt_len + out - 1)
            last = self._progress.get(req.rid, 0)
            if ctx - last < self.cfg.checkpoint_tokens:
                continue
            self._progress[req.rid] = ctx
            self.checkpoints += 1
            blocks = (self._materialize(inst, req, ctx, out)
                      if self.cfg.materialize_kv else 0)
            if tracer is not None:
                tracer.event(req.rid, now, "checkpoint", ctx=ctx,
                             blocks=blocks)

    def _materialize(self, inst, req, ctx: int, out: int) -> int:
        hook = getattr(inst.executor, "export_request_blocks", None)
        if hook is None or not req.prompt_tokens:
            return 0
        bs = getattr(inst.executor, "cache_block_size", 16)
        if self.pool is None:
            self.pool = HostSpillPool(self.cfg.store_blocks, bs)
        elif self.pool.block_size != bs:
            return 0                      # mixed-block-size cluster
        stream = tuple(req.prompt_tokens) \
            + tuple(req.output_tokens[:out])
        n = min(ctx, len(stream)) // bs
        if n <= 0:
            return 0
        chains = []
        for i, (h, blk) in enumerate(chain_hashes(stream, bs)):
            if i >= n:
                break
            chains.append((h, blk))
        missing = [i for i, (h, _) in enumerate(chains)
                   if h not in self.pool]
        payloads = hook(req, missing) if missing else {}
        if payloads is None:
            return 0
        landed = 0
        # tail-to-head so the head of the run is always the most
        # recently used: capacity drops then eat tails, never punch
        # holes that truncate the whole contiguous restore run
        for i in range(len(chains) - 1, -1, -1):
            h, blk = chains[i]
            if i in payloads:
                self.pool.put(h, blk, payloads[i])
                landed += 1
            else:
                self.pool.touch(h)
        self.ckpt_blocks += landed
        return landed

    def drop(self, rid: int):
        """A request resolved terminally: its progress record is dead
        weight.  Materialized blocks are NOT dropped — they are keyed
        by content chain (shared across identical prefixes) and age out
        of the pool by LRU instead."""
        self._progress.pop(rid, None)

    # ------------------------------------------------------------------
    # warm restore
    # ------------------------------------------------------------------
    def plan_restore(self, req) -> Optional[dict]:
        """Restore plan for a crash victim, or None for cold recompute.
        ``pos`` is the checkpointed stream position (progress-record
        restore, bookkeeping executors); ``engine`` is an assembled
        migration-format state when a contiguous materialized run
        exists (live paged executors adopt it via ``insert_state``)."""
        if not self.cfg.enable:
            return None
        out = req.output_len
        ctx = min(self._progress.get(req.rid, 0),
                  req.prompt_len + out - 1)
        if ctx < 1:
            return None
        engine = (self._assemble(req, ctx, out)
                  if self.cfg.materialize_kv and self.pool is not None
                  else None)
        self.warm_plans += 1
        return {"pos": ctx, "engine": engine}

    def _assemble(self, req, ctx: int, out: int) -> Optional[dict]:
        if not req.prompt_tokens:
            return None
        bs = self.pool.block_size
        stream = tuple(req.prompt_tokens) \
            + tuple(req.output_tokens[:out])
        n_max = ctx // bs
        if n_max < 1:
            return None
        run = self.pool.match_from(stream, 0, max_blocks=n_max)
        fmt = None
        kvs = []
        for _, payload in run:
            if payload is None:
                break                     # bookkeeping entry: no tensors
            if fmt is None:
                fmt = payload["fmt"]
            if payload["fmt"] != fmt:
                break
            kvs.append(payload["kv"])
        if not kvs:
            return None
        n = len(kvs)
        pos = n * bs
        import jax                        # live payloads only: the sim
        import numpy as np                # never reaches this path
        blocks = kvs[0] if n == 1 else jax.tree.map(
            lambda *xs: np.concatenate(xs, axis=1), *kvs)
        return {"paged_blocks": blocks, "n_blocks": n, "pos": pos,
                "last_token": int(stream[pos - 1]),
                "prompt_tokens": list(req.prompt_tokens),
                "kv_format": fmt, "block_size": bs}

    # ------------------------------------------------------------------
    # post-crash KV re-replication
    # ------------------------------------------------------------------
    def on_replica_landed(self, tokens, src_iid: Optional[int],
                          dst_iid: int):
        """A "replicate" TRANSFER landed: record both ends as holders
        of the path (the registry is what makes a crashed holder's
        replicas recoverable without an epoch-boundary rescan)."""
        key = tuple(tokens)
        if not key:
            return
        holders = self._replicas.setdefault(key, set())
        if src_iid is not None:
            holders.add(src_iid)
        holders.add(dst_iid)

    def holders(self, tokens) -> Set[int]:
        return self._replicas.get(tuple(tokens), set())

    def on_instance_failed(self, cluster, inst, now: float) -> int:
        """Re-establish every replicated path the dead instance held:
        ship it from a surviving holder to the coldest healthy peer
        (fewest used blocks) that misses it.  Best effort — replicas
        are a performance tier, never correctness."""
        if not self.cfg.rereplicate:
            return 0
        shipped = 0
        for key, holders in list(self._replicas.items()):
            if inst.iid not in holders:
                continue
            holders.discard(inst.iid)
            src = self._find_source(cluster, key, holders)
            if src is None:
                if not holders:
                    self._replicas.pop(key, None)
                continue
            cands = [i for i in cluster.instances
                     if i is not src and i.schedulable
                     and i.prefix_cache is not None
                     and not self._holds_path(i, key)]
            if not cands:
                continue
            dst = min(cands, key=lambda i: i.allocator.used_blocks)
            if cluster.replicate_prefix(src, dst, list(key), now):
                shipped += 1
                self.rereplications += 1
                if cluster.tracer is not None:
                    cluster.tracer.global_event(
                        now, "rereplicate", src=src.iid, dst=dst.iid,
                        tokens=len(key))
        return shipped

    @staticmethod
    def _holds_path(inst, key: Tuple[int, ...]) -> bool:
        pc = inst.prefix_cache
        n = len(key) // pc.block_size
        if n <= 0:
            return True
        return len(pc.tree.match(key, n, touch=False)) >= n

    def _find_source(self, cluster, key, holders):
        # surviving registered holders first (cheap), then any healthy
        # instance that still caches the path
        ranked = sorted(cluster.instances,
                        key=lambda i: i.iid not in holders)
        for i in ranked:
            if i.schedulable and i.prefix_cache is not None \
                    and self._holds_path(i, key):
                return i
        return None

    # ------------------------------------------------------------------
    def counters(self) -> dict:
        c = {
            "checkpoints": self.checkpoints,
            "checkpointed_requests": len(self._progress),
            "ckpt_blocks": self.ckpt_blocks,
            "warm_plans": self.warm_plans,
            "rereplications": self.rereplications,
        }
        if self.pool is not None:
            c["pool"] = self.pool.stats()
        return c
