"""Online serving runtime: continuous ingestion + streaming + adaptive
slider control on top of the discrete-event cluster core.

Re-exports resolve lazily (PEP 562): the cluster core imports
``repro.serving.faults`` at module load, and an eager ``server`` import
here would close the cycle back onto the half-initialized cluster.
"""
_EXPORTS = {
    "VirtualClock": "repro.serving.clock",
    "WallClock": "repro.serving.clock",
    "ControllerConfig": "repro.serving.controller",
    "SliderController": "repro.serving.controller",
    "Fault": "repro.serving.faults",
    "FaultInjector": "repro.serving.faults",
    "MetricsLog": "repro.serving.metrics",
    "RecoveryConfig": "repro.serving.recovery",
    "RecoveryManager": "repro.serving.recovery",
    "TelemetryWindow": "repro.serving.metrics",
    "AbortMsg": "repro.serving.server",
    "RequestHandle": "repro.serving.server",
    "ServingLoop": "repro.serving.server",
    "SubmitMsg": "repro.serving.server",
    "WatchdogConfig": "repro.serving.server",
    "TraceConfig": "repro.serving.tracing",
    "Tracer": "repro.serving.tracing",
    "prometheus_text": "repro.serving.tracing",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name):
    mod = _EXPORTS.get(name)
    if mod is None:
        raise AttributeError(
            f"module {__name__!r} has no attribute {name!r}")
    import importlib
    return getattr(importlib.import_module(mod), name)
