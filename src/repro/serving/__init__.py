"""Online serving runtime: continuous ingestion + streaming + adaptive
slider control on top of the discrete-event cluster core."""
from repro.serving.clock import VirtualClock, WallClock
from repro.serving.controller import ControllerConfig, SliderController
from repro.serving.metrics import MetricsLog, TelemetryWindow
from repro.serving.server import RequestHandle, ServingLoop, SubmitMsg

__all__ = [
    "ControllerConfig", "MetricsLog", "RequestHandle", "ServingLoop",
    "SliderController", "SubmitMsg", "TelemetryWindow", "VirtualClock",
    "WallClock",
]
