"""Epoch-based adaptive slider control.

The paper's thesis is that one system spans the aggregation<->
disaggregation spectrum by moving three sliders — R_PD (P-heavy :
D-heavy instance ratio) and the chunk sizes S_P / S_D (§3.1) — but it
positions them via *offline* search.  This controller moves them
*online*: every ``epoch`` virtual seconds it reads the telemetry
window's TTFT/TPOT attainment and walks the configuration toward
whichever dimension is starved:

* **TTFT starved** (prefill capacity short): raise S_D — D-heavy
  instances take bigger prefill chunks (aggregation-ward).  When S_D is
  maxed, flip the least decode-loaded D-heavy instance to P-heavy
  (drain-and-flip via the cluster's migration machinery).
* **TPOT starved** (decode interference high): lower S_D
  (disaggregation-ward).  When S_D is floored, flip a P-heavy instance
  to D-heavy.

Moves are damped by a deadband around the attainment target, a cooldown
of ``cooldown`` epochs after every move, and min-instance floors per
role; both attainment signals starving simultaneously means the cluster
is saturated — reconfiguration cannot help, so the controller holds
(admission control, not slider motion, is the right tool there).

The controller is deliberately model-free: it reads only *attained*
service quality, so it works unchanged on the simulator and the real
engine, and under workloads the offline search never saw.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

from repro.core.instance import D_HEAVY, P_HEAVY


@dataclasses.dataclass
class ControllerConfig:
    epoch: float = 5.0          # seconds between control decisions
    target: float = 0.9         # per-dimension attainment target
    deadband: float = 0.03      # hysteresis below target before acting
    cooldown: int = 1           # full epochs to hold after a move
    # the S_D ladder; S_P stays at its configured value — the paper
    # moves S_D for interference control, S_P mainly scales with prompt
    # length, which routing already handles.  The floor is 64, not 0: a
    # pure-decode instance strands whatever prefill work is already
    # queued on it, and a minimal chunk keeps the corner reachable
    # without that cliff (S_D=0 remains expressible as a static config).
    sd_steps: Tuple[int, ...] = (64, 128, 256, 512, 1024)
    min_p: int = 1              # role-count floors for R_PD flips
    min_d: int = 1
    min_evidence: int = 4       # windowed events needed before acting
    # raising S_D is only safe while decode has real headroom: require
    # windowed p90 TPOT below this fraction of the SLO, else go straight
    # to a D->P flip (bigger chunks would trade one violation for
    # another)
    tpot_guard: float = 0.85
    # a chunk move that breaks the other dimension is reverted and its
    # direction embargoed for this many epochs (local search with tabu —
    # prevents oscillation when neither chunk direction can win)
    tabu_epochs: int = 4
    # ---- admission control as an actuator --------------------------
    # when BOTH attainment signals starve, sliders cannot conjure
    # capacity — shed from the router-side admission queue instead
    # (lowest priority classes first; no-op when the loop runs without
    # an admission queue).  Queue pressure also feeds the TTFT signal:
    # a queue whose oldest entry has burned ``queue_guard`` of the TTFT
    # SLO counts as prefill starvation even before first tokens lag.
    shed: bool = True
    shed_fraction: float = 0.5       # share of queued entries per shed
    queue_guard: float = 0.5         # oldest-wait fraction of TTFT SLO
    # ---- hot-prefix replication (off by default) -------------------
    # every epoch, copy each instance's hottest matchable prefixes to
    # the instance with the fewest local hits for them — cache-aware
    # routing then spreads that traffic instead of pinning it
    replicate: bool = False
    replicate_max_paths: int = 2     # hot paths exported per source
    replicate_min_hits: int = 3      # touch count before a path is hot
    replicate_max_blocks: int = 64   # per-epoch block budget per source
    # ---- decision audit trail --------------------------------------
    # one record per epoch: the input signals, every action taken (or
    # the reason for holding), guard/tabu outcomes, and — filled in at
    # the NEXT epoch — the observed effect.  Lightweight (a few dict
    # appends per epoch), so on by default.
    audit: bool = True
    audit_max_epochs: int = 4096


class SliderController:
    def __init__(self, cfg: Optional[ControllerConfig] = None):
        self.cfg = cfg or ControllerConfig()
        self.loop = None
        self.moves: List[dict] = []      # chunk retunes + role flips
        self.replications = 0            # hot-prefix transfers started
        self._next_epoch: Optional[float] = None
        self._hold_until = 0.0
        self._pending_eval: Optional[dict] = None   # last chunk move
        self._tabu: dict = {}            # direction -> embargo-until time
        # decision audit trail: one record per epoch (see
        # ControllerConfig.audit); the current epoch's record while
        # ``on_epoch`` runs, so helpers can annotate it
        self.audit: List[dict] = []
        self._cur: Optional[dict] = None

    # ------------------------------------------------------------------
    def bind(self, loop):
        self.loop = loop
        self._next_epoch = self.cfg.epoch

    @property
    def n_moves(self) -> int:
        return len(self.moves)

    @property
    def n_flips(self) -> int:
        return sum(m["kind"] == "flip" for m in self.moves)

    # ------------------------------------------------------------------
    def _instances(self, itype: str) -> List:
        return [i for i in self.loop.cluster.instances
                if i.itype == itype and not i.draining and i.schedulable]

    def _flip_in_progress(self) -> bool:
        return any(i.pending_flip is not None
                   for i in self.loop.cluster.instances)

    def _current_sd(self) -> int:
        d = self._instances(D_HEAVY)
        return min((i.chunk_size for i in d), default=0)

    def _current_sp(self) -> int:
        p = self._instances(P_HEAVY)
        return max((i.chunk_size for i in p), default=0)

    def _record(self, now: float, kind: str, **detail):
        mv = {"t": round(now, 3), "kind": kind, **detail}
        self.moves.append(mv)
        self._hold_until = now + self.cfg.cooldown * self.cfg.epoch
        if self._cur is not None:
            self._cur["actions"].append(mv)
        tr = getattr(self.loop, "tracer", None)
        if tr is not None:
            tr.global_event(now, "controller_" + kind, **detail)

    def _note(self, key: str, val):
        """Annotate the current epoch's audit record (no-op when the
        audit is off)."""
        if self._cur is not None:
            self._cur[key] = val

    # ------------------------------------------------------------------
    def maybe_epoch(self, now: float):
        if self._next_epoch is None or now < self._next_epoch:
            return
        # one decision per elapsed epoch boundary, not per backlogged one
        self._next_epoch = (now - now % self.cfg.epoch) + self.cfg.epoch
        self.on_epoch(now)

    def on_epoch(self, now: float):
        tele = self.loop.telemetry
        att_ttft = tele.ttft_attainment(now)
        # TPOT: prefer the in-flight signal — finished-request TPOT lags
        # a whole generation behind (it reports violations long after
        # they began, and keeps reporting them long after a fix lands);
        # the in-flight view tracks the population actually decoding now
        att_live = tele.tpot_inflight_attainment(
            now, self.loop.cluster.instances)
        att_tpot = (att_live if att_live is not None
                    else tele.tpot_attainment(now))
        # close the loop on the PREVIOUS record: what the last decision
        # actually did to the signals, captured before this epoch's
        # queue-guard mutates them
        if self.audit and "observed" not in self.audit[-1]:
            self.audit[-1]["observed"] = {
                "t": round(now, 3),
                "ttft_att": att_ttft,
                "tpot_att": att_tpot,
                "goodput_rps": tele.goodput(now),
            }
        low = self.cfg.target - self.cfg.deadband
        ttft_bad = att_ttft is not None and att_ttft < low
        tpot_bad = att_tpot is not None and att_tpot < low
        # the admission queue is a first-class controller signal: work
        # aging in the router queue IS prefill starvation, visible one
        # window earlier than the first-token stream it delays
        queue_forced = False
        adm = getattr(self.loop, "admission", None)
        if adm is not None and len(adm) \
                and adm.oldest_wait(now) > self.cfg.queue_guard \
                * self.loop.slo.ttft:
            ttft_bad = True
            queue_forced = True
            if att_ttft is None:
                att_ttft = 0.0
        n_evidence = len(tele._first) + len(tele._fin)
        if self.cfg.audit:
            rec = {
                "t": round(now, 3),
                "signals": {
                    "ttft_att": att_ttft,
                    "tpot_att": att_tpot,
                    "tpot_inflight": att_live is not None,
                    "ttft_bad": ttft_bad,
                    "tpot_bad": tpot_bad,
                    "queue_forced": queue_forced,
                    "queue_depth": len(adm) if adm is not None else 0,
                    "queue_oldest_wait_s": (
                        round(adm.oldest_wait(now), 3)
                        if adm is not None and len(adm) else 0.0),
                    "s_d": self._current_sd(),
                    "s_p": self._current_sp(),
                    "n_p": len(self._instances(P_HEAVY)),
                    "n_d": len(self._instances(D_HEAVY)),
                    "evidence": n_evidence,
                },
                "actions": [],
            }
            self.audit.append(rec)
            if len(self.audit) > self.cfg.audit_max_epochs:
                del self.audit[0]
            self._cur = rec
        else:
            self._cur = None
        self._evaluate_last_move(now, ttft_bad, tpot_bad)
        if self.cfg.replicate:
            # orthogonal to slider motion: replication never reconfigures
            # roles, so it runs regardless of cooldown or staged flips
            self._replicate_hot(now)
        if now < self._hold_until:
            self._note("hold", "cooldown")
            return
        if self._flip_in_progress():
            self._note("hold", "flip_in_progress")
            return
        if n_evidence < self.cfg.min_evidence:
            self._note("hold", "insufficient_evidence")
            return
        if ttft_bad and tpot_bad:
            # saturated on both axes: sliders cannot conjure capacity —
            # admission control can: early-reject queued work from the
            # lowest priority classes so what remains meets its SLOs
            self._shed(now, att_ttft, att_tpot)
            return
        if ttft_bad:
            self._more_prefill(now, att_ttft)
        elif tpot_bad:
            self._more_decode(now, att_tpot)
        else:
            self._note("hold", "within_deadband")

    def _shed(self, now: float, att_ttft, att_tpot):
        self._note("branch", "saturated_both")
        if not self.cfg.shed:
            self._note("hold", "shed_disabled")
            return
        shed_fn = getattr(self.loop, "shed_admission", None)
        if shed_fn is None:
            self._note("hold", "no_admission_queue")
            return
        n = shed_fn(self.cfg.shed_fraction)
        if n:
            self._record(now, "shed", count=n,
                         why=f"ttft_att={att_ttft:.2f} "
                             f"tpot_att={att_tpot:.2f}")

    def _evaluate_last_move(self, now: float, ttft_bad: bool,
                            tpot_bad: bool):
        """Local-search backtracking: a chunk move that broke the OTHER
        dimension is undone and its direction embargoed, so the next
        starved epoch escalates to a role flip instead of oscillating."""
        mv = self._pending_eval
        self._pending_eval = None
        if mv is None:
            return
        broke_other = (tpot_bad if mv["dir"] == "up" else ttft_bad)
        if not broke_other:
            return
        self.loop.set_chunks(D_HEAVY, mv["frm"])
        until = now + self.cfg.tabu_epochs * self.cfg.epoch
        self._tabu["sd_" + mv["dir"]] = until
        self._record(now, "revert", slider="s_d", frm=mv["to"],
                     to=mv["frm"],
                     why=("tpot broke" if mv["dir"] == "up"
                          else "ttft broke"))

    def _tabued(self, direction: str, now: float) -> bool:
        return now < self._tabu.get("sd_" + direction, 0.0)

    # ------------------------------------------------------------------
    def _replicate_hot(self, now: float):
        """Epoch-boundary hot-prefix replication: for every instance's
        hottest matchable prefixes (per-instance hit telemetry), ship
        the blocks the COLDEST peer is missing.  Best effort and off the
        critical path — the transfer lands through the cluster's
        ordinary migration machinery, and a full destination pool admits
        nothing rather than evicting its own content."""
        cfg = self.cfg
        cluster = self.loop.cluster
        insts = [i for i in cluster.instances
                 if i.prefix_cache is not None and not i.draining
                 and i.schedulable]
        if len(insts) < 2:
            return
        rec = getattr(cluster, "recovery", None)
        for src in insts:
            budget = cfg.replicate_max_blocks
            for tokens, hits in src.hot_prefixes(cfg.replicate_max_paths,
                                                 cfg.replicate_min_hits):
                if budget <= 0:
                    break
                bs = src.prefix_cache.block_size
                n = len(tokens) // bs
                if rec is not None:
                    # warm recovery already re-replicated this path after
                    # a crash: spend the epoch budget elsewhere while two
                    # healthy holders survive
                    live = [iid for iid in rec.holders(tokens)
                            if (cluster._inst_by_id.get(iid) is not None
                                and cluster._inst_by_id[iid].schedulable)]
                    if len(live) >= 2:
                        continue

                def depth(inst):
                    return len(inst.prefix_cache.tree.match(
                        tokens, n, touch=False))

                dst = min((i for i in insts if i is not src), key=depth)
                have = depth(dst)
                if have >= n:
                    continue          # path already everywhere it fits
                ship = tokens[:min(n, have + budget) * bs]
                if cluster.replicate_prefix(src, dst, ship, now):
                    budget -= len(ship) // bs
                    self.replications += 1

    # ------------------------------------------------------------------
    def _more_prefill(self, now: float, att: float):
        """Aggregation-ward: S_D up while decode has headroom, else flip
        D->P (drain-and-flip)."""
        cfg = self.cfg
        sd = self._current_sd()
        sp = self._current_sp()
        higher = [s for s in cfg.sd_steps if s > sd]
        tele = self.loop.telemetry
        p90 = tele.p90_tpot_inflight(now, self.loop.cluster.instances)
        if p90 is None:
            p90 = tele.p90_tpot(now)
        tpot_headroom = (p90 is None
                         or p90 < cfg.tpot_guard * self.loop.slo.tpot)
        higher = [s for s in higher if not sp or s <= sp]
        self._note("guards", {"branch": "more_prefill",
                              "tpot_headroom": tpot_headroom,
                              "tabu_up": self._tabued("up", now),
                              "sd_at_ceiling": not higher})
        if higher and tpot_headroom and not self._tabued("up", now):
            # cratered TTFT jumps the ladder (mirror of _more_decode)
            to = higher[-1] if att < cfg.target / 2 else higher[0]
            if self.loop.set_chunks(D_HEAVY, to):
                self._record(now, "chunk", slider="s_d", frm=sd,
                             to=to, why=f"ttft_att={att:.2f}")
                self._pending_eval = {"dir": "up", "frm": sd, "to": to}
                return
        d = self._instances(D_HEAVY)
        if len(d) > cfg.min_d:
            inst = min(d, key=lambda i: i.decode_load())
            if self.loop.flip_role(inst, P_HEAVY, sp or max(cfg.sd_steps)):
                self._record(now, "flip", iid=inst.iid, to=P_HEAVY,
                             why=f"ttft_att={att:.2f}")
                return
        self._note("hold", "at_role_floor")

    def _more_decode(self, now: float, att: float):
        """Disaggregation-ward: S_D down, then P->D flip.  A cratered
        signal (att < 1/2 target) jumps straight to the ladder floor —
        stepping down one notch per epoch pays the violation bill for
        every epoch the descent takes."""
        cfg = self.cfg
        sd = self._current_sd()
        lower = [s for s in cfg.sd_steps if s < sd]
        self._note("guards", {"branch": "more_decode",
                              "tabu_down": self._tabued("down", now),
                              "sd_at_floor": not lower})
        if lower and not self._tabued("down", now):
            to = lower[0] if att < cfg.target / 2 else lower[-1]
            if self.loop.set_chunks(D_HEAVY, to):
                self._record(now, "chunk", slider="s_d", frm=sd,
                             to=to, why=f"tpot_att={att:.2f}")
                self._pending_eval = {"dir": "down", "frm": sd,
                                      "to": to}
                return
        p = self._instances(P_HEAVY)
        if len(p) > cfg.min_p:
            inst = min(p, key=lambda i: i.decode_load())
            # floor at the smallest ladder step: chunk 0 would strand
            # whatever prefill work is already queued on the instance
            new_sd = self._current_sd() or min(cfg.sd_steps)
            if self.loop.flip_role(inst, D_HEAVY, new_sd):
                self._record(now, "flip", iid=inst.iid, to=D_HEAVY,
                             why=f"tpot_att={att:.2f}")
                return
        self._note("hold", "at_role_floor")
