"""Request-lifecycle tracing and SLO attribution.

``TelemetryWindow`` answers "how is the fleet doing *right now*";
nothing answers "where did THIS request's latency go" — which is the
question the paper's whole latency-shifting argument turns on (queueing
vs prefill vs transfer vs decode interference, DistServe Fig. 4 /
Tropical §5).  ``Tracer`` records a per-request timeline as a chain of
**phases** plus fine-grained **events**:

phases (contiguous, non-overlapping by construction — each ``phase()``
call closes the current span at the new span's start time):

* ``admission``   — router-side admission-queue wait
* ``queue``       — event-heap wait + placement + instance prefill queue
                    (re-entered after preemption / crash recovery)
* ``prefill``     — first chunk dispatched -> prefill complete
* ``transfer``    — KV/state migration on the wire (incl. retries)
* ``decode_wait`` — landed on the decode instance, awaiting batch slot
* ``decode``      — in the decode batch -> finish (or eject)

events ride on the timeline without breaking it: per-chunk prefill
commits (with cache-hit offset), per-commit decode horizons (with
co-batched prefill interference), transfer retries, preemptions,
recoveries, routing decisions.  Cluster-scoped happenings (stalls,
quarantines, controller actuations) land in a global event log.

The tracer is **clock-agnostic**: every hook passes the time it already
has (virtual event time in sim, wall time under ``WallClock``), so the
same instrumentation serves both.  It is **observational only** — no
RNG, no scheduling influence — so a traced run produces bit-identical
request outcomes to an untraced one, and with ``tracing=None`` every
call site short-circuits on ``tracer is None`` (zero overhead, asserted
by ``benchmarks/trace_overhead_bench.py``).

Attribution:

* ``breakdown(rid)`` -> phase -> seconds, summing exactly to the
  request's end-to-end latency (spans share endpoints);
* ``ttft_breakdown(rid)`` clips the timeline at the first token — where
  the TTFT budget went;
* ``violation_report(slo)`` aggregates the per-phase budget of every
  SLO-violating finished request — "where did violated requests lose
  their budget".

Exporters: Chrome-trace/Perfetto JSON (``to_chrome_trace`` /
``dump_chrome`` — load in ui.perfetto.dev), JSONL event log
(``dump_jsonl``), and a Prometheus text renderer over telemetry
snapshots (``prometheus_text``, content-negotiated on the gateway's
``/metrics``).
"""
from __future__ import annotations

import dataclasses
import json
import re
from collections import deque
from typing import Dict, Iterator, List, Optional

PH_ADMISSION = "admission"
PH_QUEUE = "queue"
PH_PREFILL = "prefill"
PH_TRANSFER = "transfer"
PH_DECODE_WAIT = "decode_wait"
PH_DECODE = "decode"

PHASES = (PH_ADMISSION, PH_QUEUE, PH_PREFILL, PH_TRANSFER,
          PH_DECODE_WAIT, PH_DECODE)


@dataclasses.dataclass
class TraceConfig:
    """Tracing knobs.  Constructing one and passing it to
    ``ServingLoop(tracing=...)`` is the ON switch; the default is off
    (no tracer object, every instrumentation site inert)."""
    #: completed traces retained (ring buffer; live requests always kept)
    max_requests: int = 4096
    #: record fine-grained sub-events (chunk/horizon/retry granularity).
    #: Phases are always recorded — they are the attribution substrate.
    events: bool = True
    #: per-request event cap (a 10k-token decode at K=1 would otherwise
    #: log 10k commit events; the counter keeps the truth)
    max_events_per_request: int = 512
    #: cluster-scoped event cap (stalls, quarantines, controller moves)
    max_global_events: int = 8192


class Span:
    __slots__ = ("phase", "t0", "t1", "attrs")

    def __init__(self, phase: str, t0: float,
                 attrs: Optional[dict] = None):
        self.phase = phase
        self.t0 = t0
        self.t1: Optional[float] = None   # open until the next phase
        self.attrs = attrs

    def dur(self) -> float:
        return (self.t1 - self.t0) if self.t1 is not None else 0.0


class RequestTrace:
    __slots__ = ("rid", "t_begin", "t_end", "spans", "events", "state",
                 "finish_reason", "arrival", "first_token_t",
                 "prompt_len", "output_len", "n_recoveries",
                 "events_dropped")

    def __init__(self, rid: int, t_begin: float):
        self.rid = rid
        self.t_begin = t_begin
        self.t_end: Optional[float] = None
        self.spans: List[Span] = []
        self.events: List[tuple] = []     # (t, name, attrs | None)
        self.state: Optional[str] = None
        self.finish_reason: Optional[str] = None
        self.arrival = t_begin
        self.first_token_t: Optional[float] = None
        self.prompt_len = 0
        self.output_len = 0
        self.n_recoveries = 0
        self.events_dropped = 0

    @property
    def done(self) -> bool:
        return self.t_end is not None

    def e2e(self) -> Optional[float]:
        return None if self.t_end is None else self.t_end - self.t_begin


class Tracer:
    """Low-overhead span recorder.  All mutators take the caller's
    timestamp — the tracer never reads a clock, so sim and live runs
    use it identically.  Single-writer by design: every hook runs on
    the engine/event thread (exports may run anywhere after the run)."""

    def __init__(self, cfg: Optional[TraceConfig] = None):
        self.cfg = cfg or TraceConfig()
        self._live: Dict[int, RequestTrace] = {}
        self._done: Dict[int, RequestTrace] = {}
        self._done_order: deque = deque()
        self.global_events: deque = deque(
            maxlen=self.cfg.max_global_events)
        self.dropped_traces = 0           # evicted past max_requests

    # ------------------------------------------------------------------
    # recording
    # ------------------------------------------------------------------
    def begin(self, req, t: float, phase: str = PH_QUEUE):
        """Open a request's trace at its (receipt-stamped) arrival."""
        rid = req.rid
        if rid in self._live or rid in self._done:
            return
        tr = RequestTrace(rid, t)
        tr.prompt_len = getattr(req, "prompt_len", 0)
        tr.spans.append(Span(phase, t))
        self._live[rid] = tr

    def phase(self, rid: int, t: float, name: str, **attrs):
        """Transition to ``name``: closes the current span at ``t`` and
        opens the new one there — contiguity by construction.  A
        same-phase transition is a no-op (the original start stands)."""
        tr = self._live.get(rid)
        if tr is None:
            return
        cur = tr.spans[-1]
        if cur.phase == name:
            return
        t = max(t, cur.t0)          # never a negative-duration span
        cur.t1 = t
        tr.spans.append(Span(name, t, attrs or None))

    def event(self, rid: int, t: float, name: str, **attrs):
        if not self.cfg.events:
            return
        tr = self._live.get(rid)
        if tr is None:
            return
        if len(tr.events) >= self.cfg.max_events_per_request:
            tr.events_dropped += 1
            return
        tr.events.append((t, name, attrs or None))

    def global_event(self, t: float, name: str, **attrs):
        if self.cfg.events:
            self.global_events.append((t, name, attrs or None))

    def finish(self, req, t: float):
        """Seal a request's trace at its terminal state.  A request the
        loop refused at the door (graceful drain) may never have begun —
        it still gets a (degenerate) trace, so "every terminal request
        has a trace" holds unconditionally."""
        rid = req.rid
        tr = self._live.pop(rid, None)
        if tr is None:
            if rid in self._done:
                return
            t0 = min(getattr(req, "arrival", t) or t, t)
            tr = RequestTrace(rid, t0)
            tr.prompt_len = getattr(req, "prompt_len", 0)
            tr.spans.append(Span(PH_QUEUE, t0))
        last = tr.spans[-1]
        last.t1 = max(t, last.t0)
        tr.t_end = last.t1
        state = getattr(req, "state", None)
        tr.state = getattr(state, "value", state)
        tr.finish_reason = getattr(req, "finish_reason", None)
        tr.arrival = getattr(req, "arrival", tr.t_begin)
        tr.first_token_t = getattr(req, "first_token_time", None)
        tr.output_len = getattr(req, "output_len", 0)
        tr.n_recoveries = getattr(req, "n_recoveries", 0)
        self._done[rid] = tr
        self._done_order.append(rid)
        while len(self._done_order) > self.cfg.max_requests:
            old = self._done_order.popleft()
            self._done.pop(old, None)
            self.dropped_traces += 1

    # ------------------------------------------------------------------
    # lookup / attribution
    # ------------------------------------------------------------------
    def get(self, rid: int) -> Optional[RequestTrace]:
        return self._done.get(rid) or self._live.get(rid)

    def traces(self) -> Iterator[RequestTrace]:
        yield from self._done.values()
        yield from self._live.values()

    def __len__(self) -> int:
        return len(self._done) + len(self._live)

    def breakdown(self, rid: int,
                  until: Optional[float] = None) -> Optional[Dict[str, float]]:
        """Phase -> seconds for one request.  For a finished request the
        values sum exactly to ``t_end - t_begin`` (spans share their
        endpoints); for a live one the open span is clipped at
        ``until`` (default: its start — i.e. excluded)."""
        tr = self.get(rid)
        if tr is None:
            return None
        out: Dict[str, float] = {}
        for sp in tr.spans:
            t1 = sp.t1 if sp.t1 is not None else max(until or sp.t0, sp.t0)
            out[sp.phase] = out.get(sp.phase, 0.0) + (t1 - sp.t0)
        return out

    @staticmethod
    def _clipped(tr: RequestTrace, lo: float,
                 hi: float) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for sp in tr.spans:
            t1 = sp.t1 if sp.t1 is not None else sp.t0
            a, b = max(sp.t0, lo), min(t1, hi)
            if b > a:
                out[sp.phase] = out.get(sp.phase, 0.0) + (b - a)
        return out

    def ttft_breakdown(self, rid: int) -> Optional[Dict[str, float]]:
        """Where the TTFT budget went: phase seconds clipped at the
        first token (None before one exists)."""
        tr = self.get(rid)
        if tr is None or tr.first_token_t is None:
            return None
        return self._clipped(tr, tr.t_begin, tr.first_token_t)

    def violation_report(self, slo) -> dict:
        """Aggregate SLO attribution over retained finished traces:
        for TTFT violators, mean per-phase seconds up to the first
        token; for TPOT violators, mean per-phase seconds after it —
        "where did violated requests lose their budget"."""
        ttft_acc: Dict[str, float] = {}
        tpot_acc: Dict[str, float] = {}
        n_fin = n_ttft = n_tpot = 0
        n_ttft_rec = n_tpot_rec = 0
        ttft_excess = 0.0
        for tr in self._done.values():
            if tr.state != "finished" or tr.first_token_t is None:
                continue
            n_fin += 1
            ttft = tr.first_token_t - tr.t_begin
            if ttft > slo.ttft:
                n_ttft += 1
                n_ttft_rec += tr.n_recoveries > 0
                ttft_excess += ttft - slo.ttft
                for ph, s in self._clipped(
                        tr, tr.t_begin, tr.first_token_t).items():
                    ttft_acc[ph] = ttft_acc.get(ph, 0.0) + s
            if tr.output_len > 1 and tr.t_end is not None:
                tpot = (tr.t_end - tr.first_token_t) / (tr.output_len - 1)
                if tpot > slo.tpot:
                    n_tpot += 1
                    n_tpot_rec += tr.n_recoveries > 0
                    for ph, s in self._clipped(
                            tr, tr.first_token_t, tr.t_end).items():
                        tpot_acc[ph] = tpot_acc.get(ph, 0.0) + s

        def mean(acc, n):
            return {ph: round(s / n, 6) for ph, s in sorted(acc.items())} \
                if n else {}

        return {
            "finished": n_fin,
            "ttft": {"violations": n_ttft,
                     "budget_s": slo.ttft,
                     "mean_excess_s": round(ttft_excess / n_ttft, 6)
                     if n_ttft else 0.0,
                     "mean_phase_s": mean(ttft_acc, n_ttft),
                     # violators that went through a crash recovery —
                     # separates recovery-dominated violations from
                     # ordinary congestion
                     "recovered_violators": n_ttft_rec},
            "tpot": {"violations": n_tpot,
                     "budget_s": slo.tpot,
                     "mean_phase_s": mean(tpot_acc, n_tpot),
                     "recovered_violators": n_tpot_rec},
        }

    # ------------------------------------------------------------------
    # exporters
    # ------------------------------------------------------------------
    def to_chrome_trace(self) -> dict:
        """Chrome-trace/Perfetto JSON: one row (tid) per request under
        pid 1, cluster-scoped events under pid 2 (one row per
        instance).  Times in microseconds as the format requires."""
        evs: List[dict] = [
            {"ph": "M", "pid": 1, "tid": 0, "name": "process_name",
             "args": {"name": "requests"}},
            {"ph": "M", "pid": 2, "tid": 0, "name": "process_name",
             "args": {"name": "cluster"}},
        ]
        for tr in sorted(self.traces(), key=lambda r: r.rid):
            evs.append({"ph": "M", "pid": 1, "tid": tr.rid,
                        "name": "thread_name",
                        "args": {"name": f"req {tr.rid}"}})
            for sp in tr.spans:
                t1 = sp.t1 if sp.t1 is not None else sp.t0
                ev = {"ph": "X", "pid": 1, "tid": tr.rid, "cat": "request",
                      "name": sp.phase, "ts": round(sp.t0 * 1e6, 3),
                      "dur": round((t1 - sp.t0) * 1e6, 3)}
                if sp.attrs:
                    ev["args"] = sp.attrs
                evs.append(ev)
            for t, name, attrs in tr.events:
                ev = {"ph": "i", "pid": 1, "tid": tr.rid, "cat": "event",
                      "name": name, "ts": round(t * 1e6, 3), "s": "t"}
                if attrs:
                    ev["args"] = attrs
                evs.append(ev)
        for t, name, attrs in self.global_events:
            ev = {"ph": "i", "pid": 2,
                  "tid": (attrs or {}).get("iid", 0), "cat": "cluster",
                  "name": name, "ts": round(t * 1e6, 3), "s": "p"}
            if attrs:
                ev["args"] = attrs
            evs.append(ev)
        return {"traceEvents": evs, "displayTimeUnit": "ms"}

    def dump_chrome(self, path: str):
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f)

    def dump_jsonl(self, path: str):
        """Flat JSONL event log: one ``meta`` line per request, then its
        spans and events; global events last.  Grep-able and streamable
        where the Chrome JSON is a single document."""
        with open(path, "w") as f:
            for tr in sorted(self.traces(), key=lambda r: r.rid):
                f.write(json.dumps({
                    "kind": "meta", "rid": tr.rid, "state": tr.state,
                    "finish_reason": tr.finish_reason,
                    "t_begin": tr.t_begin, "t_end": tr.t_end,
                    "prompt_len": tr.prompt_len,
                    "output_len": tr.output_len,
                    "first_token_t": tr.first_token_t,
                    "n_recoveries": tr.n_recoveries,
                    "events_dropped": tr.events_dropped}) + "\n")
                for sp in tr.spans:
                    rec = {"kind": "span", "rid": tr.rid,
                           "phase": sp.phase, "t0": sp.t0, "t1": sp.t1}
                    if sp.attrs:
                        rec["attrs"] = sp.attrs
                    f.write(json.dumps(rec) + "\n")
                for t, name, attrs in tr.events:
                    rec = {"kind": "event", "rid": tr.rid,
                           "name": name, "t": t}
                    if attrs:
                        rec["attrs"] = attrs
                    f.write(json.dumps(rec) + "\n")
            for t, name, attrs in self.global_events:
                rec = {"kind": "global", "name": name, "t": t}
                if attrs:
                    rec["attrs"] = attrs
                f.write(json.dumps(rec) + "\n")


# ----------------------------------------------------------------------
# Prometheus text exposition over a telemetry snapshot
# ----------------------------------------------------------------------
_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")


def _metric_name(*parts: str) -> str:
    return _NAME_RE.sub("_", "_".join(p.strip("_") for p in parts if p))


def _samples_from(prefix: str, obj, labels: dict, out: list):
    """Flatten a snapshot subtree into (name, labels, value) samples.
    Strings are skipped (Prometheus samples are numeric); bools become
    0/1; ``None`` (windowed stat with no evidence) is skipped."""
    if isinstance(obj, bool):
        out.append((prefix, labels, int(obj)))
    elif isinstance(obj, (int, float)):
        out.append((prefix, labels, obj))
    elif isinstance(obj, dict):
        for k, v in obj.items():
            _samples_from(_metric_name(prefix, str(k)), v, labels, out)


def prometheus_text(snap: dict, prefix: str = "taichi") -> str:
    """Render a ``ServingLoop.snapshot()`` dict in Prometheus text
    exposition format (one scrape = one snapshot).  Scalar keys become
    gauges (``*_total`` lifetime counters become counters); the
    per-instance gauge list becomes label-dimensioned series
    (``iid``/``itype``); per-class admission depths get a ``cls``
    label."""
    samples: List[tuple] = []
    for key, val in snap.items():
        if key == "instances":
            continue
        if key == "admission" and isinstance(val, dict):
            for k, v in val.items():
                if k == "depth_by_class" and isinstance(v, dict):
                    for cls, d in v.items():
                        samples.append((_metric_name(prefix,
                                                     "admission_depth"),
                                        {"cls": cls}, d))
                elif k == "released_by_class" and isinstance(v, dict):
                    for cls, d in v.items():
                        samples.append((
                            _metric_name(prefix,
                                         "admission_released_by_class_"
                                         "total"),
                            {"cls": cls}, d))
                else:
                    _samples_from(_metric_name(prefix, "admission", k),
                                  v, {}, samples)
            continue
        _samples_from(_metric_name(prefix, key), val, {}, samples)
    for g in snap.get("instances", ()):
        labels = {"iid": str(g.get("iid")), "itype": str(g.get("itype"))}
        for k, v in g.items():
            if k in ("iid", "itype"):
                continue
            if k == "horizon_hist" and isinstance(v, dict):
                for kk, n in v.items():
                    samples.append((
                        _metric_name(prefix, "instance_horizon_hist"),
                        {**labels, "k": str(kk)}, n))
                continue
            if isinstance(v, str):
                # state-style gauges (health) export as labeled 1
                samples.append((_metric_name(prefix, "instance", k),
                                {**labels, k: v}, 1))
                continue
            _samples_from(_metric_name(prefix, "instance", k), v,
                          labels, samples)
    by_name: Dict[str, list] = {}
    for name, labels, value in samples:
        by_name.setdefault(name, []).append((labels, value))
    lines: List[str] = []
    for name in sorted(by_name):
        kind = "counter" if name.endswith("_total") else "gauge"
        lines.append(f"# HELP {name} repro serving telemetry")
        lines.append(f"# TYPE {name} {kind}")
        for labels, value in by_name[name]:
            if isinstance(value, bool):
                value = int(value)
            lbl = ""
            if labels:
                lbl = "{" + ",".join(
                    f'{k}="{v}"' for k, v in sorted(labels.items())) + "}"
            lines.append(f"{name}{lbl} {value}")
    return "\n".join(lines) + "\n"
