"""Sliding-window serving telemetry.

The adaptive controller (and any operator dashboard) needs *recent*
attainment, not lifetime averages: a run that starts prompt-heavy and
turns decode-heavy looks fine on cumulative TTFT long after its TPOT has
collapsed.  ``TelemetryWindow`` keeps the last ``window`` seconds of
first-token / per-token / finish / reject events in deques and computes
windowed TTFT/TPOT attainment, latency percentiles, goodput, and
throughput on demand; ``snapshot`` additionally samples instance gauges
(queue depths, decode population, HBM utilization, prefill-on-decode
interference, cache hit rate).

``MetricsLog`` accumulates snapshots for JSON export (the controller
bench and ``--engine live`` write these to disk).
"""
from __future__ import annotations

import dataclasses
import json
import threading
from collections import deque
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.latency import SLO
from repro.engine.request import Request

#: how many trailing interference_log entries feed the per-instance gauge
INTERFERENCE_TAIL = 64


class TelemetryWindow:
    def __init__(self, slo: SLO, window: float = 10.0):
        self.slo = slo
        self.window = window
        self._first: deque = deque()     # (t, ttft)
        self._tokens: deque = deque()    # (t,)
        self._fin: deque = deque()       # (t, tpot | None, slo_ok)
        self._rej: deque = deque()       # (t,)
        # time origin for rate denominators: set explicitly (the serving
        # loop anchors at its start time) or lazily at the first event.
        # Without it, a window created at wall/virtual time T0 > 0 would
        # divide its first rates by min(window, now) — a span covering
        # time the window never observed
        self._anchor: Optional[float] = None
        # admission-queue wait spans (release time - arrival): the
        # router-side queue's first-class latency signal
        self._qwait: deque = deque()     # (t, wait)
        # wire-latency spans: wall seconds from the engine emitting a
        # token to its SSE frame hitting the socket.  Fed from the
        # asyncio thread (own lock; these carry wall timestamps, not
        # window time, so they are bounded by count, not trimmed)
        self._wire: deque = deque(maxlen=4096)   # (dt,)
        self._wire_lock = threading.Lock()
        # consistency lock for the window deques and lifetime counters:
        # mutators run on the engine thread, but ``/metrics`` snapshots
        # from the HTTP thread — without the lock a snapshot could read
        # ``total_finished`` and ``total_ok`` across a finish event, or
        # trip "deque mutated during iteration".  Reentrant because
        # ``snapshot`` calls the locked stat readers.
        self._lock = threading.RLock()
        # lifetime counters
        self.total_first = 0
        self.total_tokens = 0
        self.total_finished = 0
        self.total_ok = 0
        self.total_rejected = 0
        self.total_cancelled = 0
        self.total_queue_waits = 0
        self.total_wire_frames = 0
        # fault-tolerance outcomes: aborts (client hung up), failures
        # (unrecoverable fault), and finishes that survived >=1
        # crash/quarantine recovery (the "recovered goodput" the chaos
        # bench credits to the recovery path)
        self.total_aborted = 0
        self.total_failed = 0
        self.total_recovered = 0
        self.total_recovered_ok = 0

    # ------------------------------------------------------------------
    # event ingestion (wired to Instance.token_sink / Cluster callbacks)
    # ------------------------------------------------------------------
    def anchor(self, t: float):
        """Pin the window's time origin (idempotent: first call wins).
        Rates report per second OBSERVED, not per second since epoch."""
        if self._anchor is None:
            self._anchor = t

    def _span(self, now: float) -> float:
        """Seconds the window actually covers at ``now``: capped by the
        window length AND by how long the telemetry has existed."""
        if self._anchor is None:
            return 1e-9
        return max(min(self.window, now - self._anchor), 1e-9)

    def on_token(self, req: Request, t: float):
        with self._lock:
            self.anchor(t)
            self._tokens.append((t,))
            self.total_tokens += 1
            if req.output_len == 1:      # this token WAS the first token
                self._first.append((t, req.ttft()))
                self.total_first += 1

    def on_finish(self, req: Request, t: float):
        with self._lock:
            self.anchor(t)
            ok = self.slo.satisfied(req)
            self._fin.append((t, req.tpot(), ok))
            self.total_finished += 1
            self.total_ok += int(ok)
            if getattr(req, "n_recoveries", 0) > 0:
                self.total_recovered += 1
                self.total_recovered_ok += int(ok)

    def on_reject(self, req: Request, t: float):
        with self._lock:
            self.anchor(t)
            self._rej.append((t,))
            self.total_rejected += 1

    def on_cancel(self, req: Request, t: float):
        """Graceful-drain cancellation (still queued at shutdown) —
        counted separately from rejection: the server chose to stop,
        the request did not fail admission."""
        with self._lock:
            self.anchor(t)
            self.total_cancelled += 1

    def on_abort(self, req: Request, t: float):
        """Client-initiated abort (disconnect propagation): the request
        left the system by the client's choice — neither a finish nor a
        rejection."""
        with self._lock:
            self.anchor(t)
            self.total_aborted += 1

    def on_failed(self, req: Request, t: float):
        """Unrecoverable fault outcome (fail-stop crash loss, transfer
        retries exhausted, recovery loop bound)."""
        with self._lock:
            self.anchor(t)
            self.total_failed += 1

    def on_queue_wait(self, t: float, wait: float):
        """Admission-queue span: seconds between a request's arrival
        and its release into the cluster."""
        with self._lock:
            self.anchor(t)
            self._qwait.append((t, wait))
            self.total_queue_waits += 1

    def record_wire(self, dt: float):
        """Wire span: engine token event -> socket write (thread-safe;
        called from the HTTP writer)."""
        with self._wire_lock:
            self._wire.append(dt)
        self.total_wire_frames += 1

    def _trim(self, now: float):
        cut = now - self.window
        for dq in (self._first, self._tokens, self._fin, self._rej,
                   self._qwait):
            while dq and dq[0][0] < cut:
                dq.popleft()

    # ------------------------------------------------------------------
    # windowed statistics
    # ------------------------------------------------------------------
    def ttft_attainment(self, now: float) -> Optional[float]:
        """Share of windowed first tokens inside the TTFT SLO (None when
        the window saw no first tokens — the controller treats that as
        'no evidence', not 'perfect')."""
        with self._lock:
            self._trim(now)
            if not self._first:
                return None
            return sum(v <= self.slo.ttft for _, v in self._first) \
                / len(self._first)

    def tpot_attainment(self, now: float) -> Optional[float]:
        with self._lock:
            self._trim(now)
            if not self._fin:
                return None
            return sum(tp is None or tp <= self.slo.tpot
                       for _, tp, _ in self._fin) / len(self._fin)

    def goodput(self, now: float) -> float:
        """SLO-attained finishes per second over the window."""
        with self._lock:
            self._trim(now)
            return sum(ok for _, _, ok in self._fin) / self._span(now)

    @staticmethod
    def _decode_tpots(now: float, instances: Sequence) -> List[float]:
        """Current TPOTs of the in-flight decode population.  The
        ``decoding`` dicts belong to the engine thread and are NOT under
        this window's lock, so a concurrent snapshot can see them mutate
        mid-iteration — retry the (cheap) list() a bounded number of
        times and settle for the instance's last consistent view."""
        vals: List[float] = []
        for inst in instances:
            reqs: List = []
            for _ in range(8):
                try:
                    reqs = list(inst.decoding.values())
                    break
                except RuntimeError:
                    continue
            for r in reqs:
                tp = r.current_tpot(now)
                if tp is not None:
                    vals.append(tp)
        return vals

    def tpot_inflight_attainment(self, now: float,
                                 instances: Sequence) -> Optional[float]:
        """Share of currently-decoding requests whose TPOT *since their
        last reset* is inside the SLO.  Finished-request TPOT lags by a
        whole generation (several seconds); this is the controller's
        early-warning signal — it moves the moment a decode population
        starts slipping, not after it has already failed."""
        vals = self._decode_tpots(now, instances)
        if not vals:
            return None
        return sum(v <= self.slo.tpot for v in vals) / len(vals)

    def p90_tpot_inflight(self, now: float,
                          instances: Sequence) -> Optional[float]:
        vals = self._decode_tpots(now, instances)
        return float(np.percentile(vals, 90)) if vals else None

    def p90_ttft(self, now: float) -> Optional[float]:
        with self._lock:
            self._trim(now)
            if not self._first:
                return None
            return float(np.percentile([v for _, v in self._first], 90))

    def p90_tpot(self, now: float) -> Optional[float]:
        with self._lock:
            self._trim(now)
            xs = [tp for _, tp, _ in self._fin if tp is not None]
            return float(np.percentile(xs, 90)) if xs else None

    def queue_wait_stats(self, now: float) -> Optional[dict]:
        """Windowed admission-queue wait percentiles (None before any
        release went through the queue)."""
        with self._lock:
            self._trim(now)
            xs = [w for _, w in self._qwait]
        if not xs:
            return None
        return {"p50_s": round(float(np.percentile(xs, 50)), 5),
                "p95_s": round(float(np.percentile(xs, 95)), 5),
                "max_s": round(max(xs), 5),
                "releases": len(xs)}

    def wire_stats(self) -> Optional[dict]:
        """Per-token wire overhead percentiles over the retained tail
        (engine token event -> socket write, wall seconds)."""
        with self._wire_lock:
            xs = list(self._wire)
        if not xs:
            return None
        return {"p50_ms": round(float(np.percentile(xs, 50)) * 1e3, 3),
                "p95_ms": round(float(np.percentile(xs, 95)) * 1e3, 3),
                "mean_ms": round(float(np.mean(xs)) * 1e3, 3),
                "frames": self.total_wire_frames}

    # ------------------------------------------------------------------
    def snapshot(self, now: float,
                 instances: Sequence = (),
                 admission=None) -> dict:
        # one lock hold for the whole snapshot: every scalar inside is
        # mutually consistent (finished_total/slo_ok_total never tear)
        with self._lock:
            return self._snapshot_locked(now, instances, admission)

    def _snapshot_locked(self, now: float, instances: Sequence,
                         admission) -> dict:
        self._trim(now)
        span = self._span(now)
        snap = {
            "t": round(now, 3),
            "window_s": self.window,
            "ttft_attainment": self.ttft_attainment(now),
            "tpot_attainment": self.tpot_attainment(now),
            "p90_ttft_s": self.p90_ttft(now),
            "p90_tpot_s": self.p90_tpot(now),
            "goodput_rps": round(self.goodput(now), 4),
            "throughput_tok_s": round(len(self._tokens) / span, 2),
            "rejected_in_window": len(self._rej),
            "finished_total": self.total_finished,
            "slo_ok_total": self.total_ok,
            "rejected_total": self.total_rejected,
            "cancelled_total": self.total_cancelled,
        }
        # fault-outcome keys appear only once something fired: a
        # faults-off run snapshots identically to pre-fault builds
        if self.total_aborted:
            snap["aborted_total"] = self.total_aborted
        if self.total_failed:
            snap["failed_total"] = self.total_failed
        if self.total_recovered:
            snap["recovered_total"] = self.total_recovered
            snap["recovered_slo_ok_total"] = self.total_recovered_ok
        qw = self.queue_wait_stats(now)
        if qw is not None:
            snap["queue_wait"] = qw
        wire = self.wire_stats()
        if wire is not None:
            snap["wire"] = wire
        if admission is not None:
            snap["admission"] = admission.gauges(now)
        if instances:
            lookups = sum(i.cache_lookups for i in instances)
            hits = sum(i.cache_hits for i in instances)
            snap["cache_hit_rate"] = (hits / lookups) if lookups else 0.0
            snap["tpot_inflight_attainment"] = \
                self.tpot_inflight_attainment(now, instances)
            snap["instances"] = [self._instance_gauges(i)
                                 for i in instances]
        return snap

    @staticmethod
    def _instance_gauges(inst) -> dict:
        tail = inst.interference_log[-INTERFERENCE_TAIL:]
        mixed = [p for p, d in tail if d > 0]
        gauges = {
            "iid": inst.iid,
            "itype": inst.itype,
            "chunk": inst.chunk_size,
            "draining": inst.draining,
            "queued_prefills": len(inst.prefill_queue),
            "queued_prefill_tokens": inst.queued_prefill_tokens(),
            "decoding": len(inst.decoding),
            "pending_decode": len(inst.pending_decode),
            "hbm_util": round(inst.hbm_utilization(), 4),
            # decode-horizon pipeline state: K of the last planned
            # iteration and whether an async step is currently in
            # flight.  Token timestamps are spread across the horizon's
            # per-step durations at commit, so the in-flight TPOT
            # signals above read the lagged stream without distortion.
            "horizon": getattr(inst, "last_horizon", 1),
            "inflight": bool(getattr(inst, "has_inflight",
                                     lambda: False)()),
            # mean prefill tokens co-batched per decode-carrying
            # iteration — the interference the controller trades against
            # prefill capacity
            "interference": (float(np.mean(mixed)) if mixed else 0.0),
        }
        health = getattr(inst, "health", "ok")
        if health != "ok":             # healthy runs snapshot unchanged
            gauges["health"] = health
        # engine-executor hot-path counters (absent on SimExecutor, so
        # simulator snapshots keep their shape): host<->device readbacks
        # and blocking syncs per run, horizon batch stats, and the jit
        # cache size — a recompile storm shows up here long before it
        # shows up as latency
        ex = getattr(inst, "executor", None)
        if ex is not None and hasattr(ex, "host_readbacks"):
            ex_g = {"host_readbacks": ex.host_readbacks,
                    "host_syncs": ex.host_syncs,
                    "horizon_calls": ex.horizon_calls,
                    "horizon_tokens": ex.horizon_tokens}
            jc = getattr(ex, "jit_compiles", None)
            if jc is not None and (n := jc()) >= 0:
                ex_g["jit_compiles"] = n
            gauges["exec"] = ex_g
        hist = getattr(inst, "horizon_hist", None)
        if hist:
            gauges["horizon_hist"] = {str(k): v
                                      for k, v in sorted(hist.items())}
        pc = getattr(inst, "prefix_cache", None)
        if pc is not None and getattr(pc, "spill", None) is not None:
            gauges["spilled_blocks"] = len(pc.spill)
            gauges["spill_promoted_tokens"] = getattr(
                inst, "spill_promoted_tokens", 0)
        if getattr(inst, "replicas_in", 0):
            gauges["replicated_blocks_in"] = inst.replicas_in
        return gauges


@dataclasses.dataclass
class MetricsLog:
    """Snapshot accumulator with JSON export."""
    snapshots: List[dict] = dataclasses.field(default_factory=list)
    events: List[dict] = dataclasses.field(default_factory=list)

    def record(self, snap: dict):
        self.snapshots.append(snap)

    def record_event(self, t: float, kind: str, detail: Dict):
        self.events.append({"t": round(t, 3), "kind": kind, **detail})

    def to_json(self) -> str:
        return json.dumps({"snapshots": self.snapshots,
                           "events": self.events}, indent=2)

    def dump(self, path: str):
        with open(path, "w") as f:
            f.write(self.to_json())
