"""Seeded, deterministic fault injection for the serving stack.

The injector describes *what goes wrong and when*; the recovery
machinery lives in ``repro.core.cluster`` (``fail_instance`` /
``quarantine_instance`` / ``recover_instance``, TRANSFER retry with
backoff) and ``repro.serving.server`` (watchdog, probation-based
re-admission).  Faults are expressed in event time so the same schedule
replays identically against ``SimExecutor`` and the live
``JaxExecutor`` paths.

Fault kinds
-----------
- ``CRASH``      instance dies with total HBM/KV loss (prefix cache and
                 host spill tier included); residents are evacuated
                 through preemption-by-recompute.
- ``STALL``      transient slowdown: the instance's next dispatches run
                 ``duration`` seconds behind the cost model, which is
                 exactly what the watchdog's step-deadline check keys on.
- ``EXEC_ERROR`` the instance's next executor step raises
                 ``InjectedFault``; the cluster catches it and
                 quarantines the instance.
- ``RECOVER``    explicit revival of a dead/quarantined instance
                 (quarantined instances also re-admit via the watchdog's
                 probation timer without a scheduled RECOVER).

TRANSFER faults are not scheduled by time — the injector is consulted
at every TRANSFER landing and drops/corrupts with the configured
probabilities, consuming its private RNG in event order (deterministic
for a fixed seed and schedule).
"""
from __future__ import annotations

import dataclasses
import hashlib
import random
from typing import Iterable, List, Optional, Sequence

CRASH = "crash"
STALL = "stall"
EXEC_ERROR = "exec_error"
RECOVER = "recover"

#: transfer outcomes returned by ``FaultInjector.transfer_outcome``
DELIVER = "deliver"
DROP = "drop"
CORRUPT = "corrupt"

_INSTANCE_KINDS = (CRASH, STALL, EXEC_ERROR, RECOVER)


class InjectedFault(RuntimeError):
    """Raised by an armed executor step — the cluster's exec-error
    handler must treat it exactly like a real device failure."""


@dataclasses.dataclass
class Fault:
    """One scheduled instance fault at event time ``t``."""
    t: float
    kind: str                      # CRASH | STALL | EXEC_ERROR | RECOVER
    iid: int
    duration: float = 0.0          # STALL only: seconds of slowdown

    def __post_init__(self):
        if self.kind not in _INSTANCE_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")


class FaultInjector:
    """Deterministic fault source: a sorted schedule of instance faults
    plus per-transfer drop/corruption probabilities.

    The cluster owns the delivery mechanics: ``Cluster.attach_faults``
    pushes one FAULT event per scheduled fault onto the event heap (so
    faults fire at exactly ``t`` in event order) and calls
    ``transfer_outcome`` at each TRANSFER landing.
    """

    def __init__(self, faults: Iterable[Fault] = (), seed: int = 0,
                 transfer_drop_p: float = 0.0,
                 transfer_corrupt_p: float = 0.0):
        self.schedule: List[Fault] = sorted(faults, key=lambda f: f.t)
        self.transfer_drop_p = transfer_drop_p
        self.transfer_corrupt_p = transfer_corrupt_p
        self._rng = random.Random(seed)
        # separate stream for retry-backoff jitter so adding jitter
        # never perturbs the transfer_outcome() sequence for a seed
        self._jitter_rng = random.Random(seed ^ 0x5EED)
        # counters (observability; the cluster keeps its own too)
        self.fired = {k: 0 for k in _INSTANCE_KINDS}
        self.transfer_drops = 0
        self.transfer_corruptions = 0

    # ------------------------------------------------------------------
    @classmethod
    def random_schedule(cls, seed: int, iids: Sequence[int], t_end: float,
                        n_crashes: int = 1, n_stalls: int = 2,
                        n_exec_errors: int = 1,
                        stall_duration: float = 0.5,
                        recover_after: Optional[float] = None,
                        transfer_drop_p: float = 0.0,
                        transfer_corrupt_p: float = 0.0) -> "FaultInjector":
        """Seeded random schedule over ``iids`` within ``(0, t_end)`` —
        the chaos tests' randomized driver.  Each crash optionally gets
        a matching RECOVER ``recover_after`` seconds later."""
        rng = random.Random(seed)
        faults: List[Fault] = []
        def at() -> float:
            return rng.uniform(t_end * 0.1, t_end * 0.8)
        for _ in range(n_crashes):
            t, iid = at(), rng.choice(list(iids))
            faults.append(Fault(t, CRASH, iid))
            if recover_after is not None:
                faults.append(Fault(t + recover_after, RECOVER, iid))
        for _ in range(n_stalls):
            faults.append(Fault(at(), STALL, rng.choice(list(iids)),
                                duration=stall_duration))
        for _ in range(n_exec_errors):
            faults.append(Fault(at(), EXEC_ERROR, rng.choice(list(iids))))
        return cls(faults, seed=seed, transfer_drop_p=transfer_drop_p,
                   transfer_corrupt_p=transfer_corrupt_p)

    # ------------------------------------------------------------------
    def record(self, fault: Fault):
        self.fired[fault.kind] += 1

    def transfer_outcome(self) -> str:
        """Fate of one TRANSFER landing: DELIVER / DROP / CORRUPT.
        Consumes the injector RNG exactly once per landing so a fixed
        seed yields a fixed outcome sequence."""
        if self.transfer_drop_p <= 0.0 and self.transfer_corrupt_p <= 0.0:
            return DELIVER
        u = self._rng.random()
        if u < self.transfer_drop_p:
            self.transfer_drops += 1
            return DROP
        if u < self.transfer_drop_p + self.transfer_corrupt_p:
            self.transfer_corruptions += 1
            return CORRUPT
        return DELIVER

    def retry_jitter(self, base: float, prev: float, cap: float) -> float:
        """Decorrelated-jitter backoff delay: uniform in
        ``[base, 3 * prev]``, capped.  Transfers that failed together
        (e.g. all landings during a stall) fan out instead of retrying
        in lockstep the way a capped pure exponential would."""
        return min(cap, self._jitter_rng.uniform(
            base, max(base, prev) * 3.0))

    def arm_exec_error(self, instance) -> None:
        """One-shot: the instance's next ``step_async``/``execute``
        raises ``InjectedFault``.  Wraps the executor rather than the
        instance so the fault surfaces on the same call path a real
        device error would (works for SimExecutor and JaxExecutor)."""
        ex = instance.executor
        orig_step, orig_exec = ex.step_async, ex.execute

        def restore():
            ex.step_async, ex.execute = orig_step, orig_exec

        def boom(*a, **kw):
            restore()
            raise InjectedFault(
                f"injected executor fault on instance {instance.iid}")

        ex.step_async = boom
        ex.execute = boom


# ---------------------------------------------------------------------------
# content-hash verification for migrated KV payloads
# ---------------------------------------------------------------------------

def payload_checksum(state) -> str:
    """Deterministic content hash of a migration payload (nested
    dicts/lists of scalars, numpy/JAX arrays, bytes).  Computed at send
    and re-checked at landing so a corrupted transfer is detected and
    retried rather than silently decoded."""
    h = hashlib.blake2b(digest_size=16)
    _feed(h, state)
    return h.hexdigest()


def _feed(h, obj) -> None:
    if obj is None:
        h.update(b"\x00N")
    elif isinstance(obj, (bytes, bytearray)):
        h.update(b"\x00B")
        h.update(bytes(obj))
    elif isinstance(obj, str):
        h.update(b"\x00S")
        h.update(obj.encode())
    elif isinstance(obj, bool):
        h.update(b"\x00b1" if obj else b"\x00b0")
    elif isinstance(obj, (int, float)):
        h.update(b"\x00n")
        h.update(repr(obj).encode())
    elif isinstance(obj, dict):
        h.update(b"\x00D")
        for k in sorted(obj, key=repr):
            _feed(h, k)
            _feed(h, obj[k])
    elif isinstance(obj, (list, tuple, set, frozenset)):
        h.update(b"\x00L")
        items = sorted(obj, key=repr) if isinstance(
            obj, (set, frozenset)) else obj
        for it in items:
            _feed(h, it)
    elif hasattr(obj, "__array__") or type(obj).__name__ == "ndarray":
        import numpy as np
        arr = np.asarray(obj)
        h.update(b"\x00A")
        h.update(str(arr.dtype).encode())
        h.update(str(arr.shape).encode())
        h.update(arr.tobytes())
    elif dataclasses.is_dataclass(obj):
        h.update(b"\x00C")
        h.update(type(obj).__name__.encode())
        _feed(h, dataclasses.asdict(obj))
    else:
        h.update(b"\x00O")
        h.update(repr(obj).encode())
